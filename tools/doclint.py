"""Doc-lint: execute every fenced ``python`` code block of a markdown
file, in order, in one shared namespace — so the README quickstart can
build on earlier snippets exactly the way a reader would paste them.

Snippets run verbatim; a failing snippet fails the lint (and CI), which
is what keeps the docs from rotting.  Blocks fenced as ```python-skip
are rendered like python but not executed (reserved for genuinely
unrunnable fragments — none today).

  PYTHONPATH=src python tools/doclint.py README.md
"""

from __future__ import annotations

import os
import re
import sys

FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def extract(text: str) -> list[str]:
    """The ``python``-fenced blocks of a markdown document, in order."""
    return [m.group(1) for m in FENCE.finditer(text)]


def run_blocks(blocks: list[str], *, source: str = "README.md") -> int:
    """Execute blocks in one shared namespace; returns the count run."""
    ns: dict = {"__name__": "__doclint__"}
    for i, block in enumerate(blocks, 1):
        print(f"[doclint] {source} block {i}/{len(blocks)} "
              f"({len(block.splitlines())} lines)")
        try:
            exec(compile(block, f"{source}#block{i}", "exec"), ns)
        except Exception:
            sys.stderr.write(
                f"[doclint] FAILED in {source} block {i}:\n{block}\n")
            raise
    return len(blocks)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "README.md"
    # snippets must be hermetic: pin the autotune cache to a scratch file
    # so the lint neither reads nor pollutes a developer's real cache
    os.environ.setdefault("REPRO_CONVTUNE_CACHE",
                          os.path.join("artifacts", "doclint_convtune.json"))
    with open(path) as f:
        blocks = extract(f.read())
    if not blocks:
        raise SystemExit(f"[doclint] no ```python blocks in {path}")
    n = run_blocks(blocks, source=os.path.basename(path))
    print(f"[doclint] OK: {n} blocks executed from {path}")


if __name__ == "__main__":
    main()
