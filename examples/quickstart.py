"""Quickstart: the 3D-TrIM dataflow in three layers of the stack.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import (TrimSliceSim, fig1_curve, compare_layer, ConvLayer,
                        reference_conv2d_valid)
from repro.kernels import ops, ref

# 1. The cycle-level dataflow (paper Fig. 5): one 3x3 slice convolving an
#    8x8 ifmap; shadow registers eliminate the end-of-row re-reads.
ifmap = np.arange(1, 65, dtype=float).reshape(8, 8)
weights = np.random.default_rng(0).standard_normal((3, 3))
for mode in ("trim", "3dtrim"):
    out, stats = TrimSliceSim(3, mode).run(ifmap, weights)
    assert np.allclose(out, reference_conv2d_valid(ifmap, weights))
    print(f"{mode:7s}: {stats.memory_reads} external reads "
          f"({stats.ops} OPs -> {stats.ops_per_memory_access:.1f} OPs/access)")

# 2. The analytical model (paper Fig. 1 + Fig. 6).
print("\nTrIM ifmap access overhead vs size (Fig. 1):",
      {k: f"{v:.1f}%" for k, v in fig1_curve().items()})
row = compare_layer(ConvLayer("conv", 14, 512, 512, 3, padding=1))
print(f"VGG-16 (14,512,512,3): 3D-TrIM {row['improvement']:.2f}x better "
      "OPs/Access/Slice than TrIM")

# 3. The TPU kernel (Pallas, interpret mode on CPU): input-stationary
#    strips + VMEM carry = IRB + shadow registers.
x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 28, 28, 16)),
                jnp.float32)
w = jnp.asarray(np.random.default_rng(2).standard_normal((3, 3, 16, 32)) * .2,
                jnp.float32)
y = ops.conv2d(x, w, padding="same", impl="pallas")
err = float(jnp.max(jnp.abs(y - ref.conv2d(x, w))))
print(f"\ntrim_conv2d kernel vs oracle: shape {y.shape}, max err {err:.2e}")
