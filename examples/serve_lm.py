"""Batched serving example across model families (dense KV cache, mamba
SSM state, recurrentgemma ring buffer).

  PYTHONPATH=src python examples/serve_lm.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve

if __name__ == "__main__":
    for arch in ("qwen2.5-3b", "falcon-mamba-7b", "recurrentgemma-2b"):
        serve.main(["--arch", arch, "--smoke", "--batch", "4",
                    "--prompt-len", "12", "--gen", "20"])
