"""End-to-end CNN *training* on the trim kernels (DESIGN.md §5).

The new training scenario: a small CIFAR-shaped classifier whose every
convolution — forward, input gradient and weight gradient — executes the
3D-TrIM Pallas dataflow.  ``ops.conv2d`` carries a ``jax.custom_vjp``
whose cotangents are TrIM convolutions themselves: the input gradient a
stride-dilated, spatially-flipped conv through the ordinary forward
kernel, the weight gradient the dedicated spatially-contracting strip
kernel.  Both are planned through ``ConvPlan.build_input_grad`` /
``ConvPlan.build_weight_grad``, and ``autotune.tune_backward`` seeds the
cache so the backward shapes run on tuned plans.

The task is synthetic but learnable: each class has a fixed random
template, samples are noisy mixtures, labels the template index.  Loss
must drop over 50 steps — the training acceptance criterion.

Data + spatial parallelism (DESIGN.md §6): ``--devices N --data D
--spatial S`` forces N host CPU devices and runs every conv through the
``shard_map`` halo-exchange path — images shard over the 'data' axis,
output H-strips over 'model', with the K-1 boundary rows exchanged by
``ppermute`` before each per-shard kernel (gradients transpose the
shuffle and psum the weight cotangents).

  PYTHONPATH=src python examples/train_cnn.py [--steps 50] [--json OUT]
  PYTHONPATH=src python examples/train_cnn.py --devices 4 --data 2 \
      --spatial 2 --steps 20
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("REPRO_CONVTUNE_CACHE", os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "convtune.json"))

# --devices N must take effect before the first jax import (XLA reads
# the host-device flag at initialization; hostdevices is jax-free)
from repro.launch.hostdevices import force_host_device_count_from_argv
force_host_device_count_from_argv()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.kernels import ops
from repro.models import layers
from repro.models.base import init_params
from repro.optim import AdamWConfig, adamw

IMAGE, CIN, N_CLASSES = 32, 3, 10
CHANNELS = (8, 16)


def make_batch(rng: np.random.Generator, templates: np.ndarray,
               batch: int):
    """Noisy class templates; labels are the template indices."""
    labels = rng.integers(0, N_CLASSES, size=batch)
    x = templates[labels] + 0.4 * rng.standard_normal(
        (batch, IMAGE, IMAGE, CIN))
    return jnp.asarray(x, jnp.float32), jnp.asarray(labels, jnp.int32)


def loss_fn(params, x, y, mesh=None):
    logits = layers.simple_cnn_apply(params, x, mesh=mesh)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def tune_backward_shapes(batch: int) -> None:
    """Seed the autotune cache for every backward conv shape the model
    trains through ('same' K=3 pre-pads by 1 per side)."""
    shapes, cur = [], (batch, IMAGE, IMAGE, CIN)
    for c in CHANNELS:
        shapes.append((cur, (3, 3, cur[3], c), 1, 1))          # conv_i
        shapes.append(((cur[0], cur[1], cur[2], c),
                       (3, 3, c, c), 2, 1))                    # down_i
        cur = (cur[0], cur[1] // 2, cur[2] // 2, c)
    c = CHANNELS[-1]
    up = (batch, IMAGE // 2, IMAGE // 2, c)
    shapes.insert(3, (up, (3, 3, 1, c), 1, c))                 # depthwise
    for (x_shape, w_shape, stride, groups) in shapes:
        # the exact (possibly asymmetric) 'same' pre-padded shape the
        # kernel sees — the shape the backward lookups are keyed over
        kshape, pad = ops.kernel_input_shape(x_shape, 3, stride, "same")
        autotune.tune_backward(kshape, w_shape, stride=stride, pad=pad,
                               groups=groups)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--json", default=None, metavar="OUT.json")
    ap.add_argument("--devices", type=int, default=1,
                    help="force N host CPU devices (handled pre-import)")
    ap.add_argument("--data", type=int, default=1,
                    help="data-parallel shards (images over 'data')")
    ap.add_argument("--spatial", type=int, default=1,
                    help="spatial shards (output H-strips over 'model')")
    args = ap.parse_args()
    mesh = None
    if args.data * args.spatial > 1:
        from repro.launch.mesh import make_conv_mesh
        mesh = make_conv_mesh(args.data, args.spatial)
        if args.batch % args.data:
            raise SystemExit(f"--batch {args.batch} must divide over "
                             f"--data {args.data}")
        print(f"mesh: {args.data} x {args.spatial} devices "
              f"(data x spatial), convs on the shard_map halo path")

    rng = np.random.default_rng(0)
    templates = rng.standard_normal((N_CLASSES, IMAGE, IMAGE, CIN))

    params = init_params(
        layers.simple_cnn_params(cin=CIN, channels=CHANNELS,
                                 n_classes=N_CLASSES),
        jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=3, decay_steps=300,
                          weight_decay=0.0)
    moments = adamw.init_moments(params, opt_cfg)

    if mesh is None:
        print("tuning backward conv shapes (persisted plan cache) ...")
        tune_backward_shapes(args.batch)

    @jax.jit
    def train_step(params, moments, step, x, y):
        # mesh rides as a closure constant (it is not a jax type)
        loss, grads = jax.value_and_grad(
            lambda p, xb, yb: loss_fn(p, xb, yb, mesh))(params, x, y)
        params, moments, metrics = adamw.apply_updates(
            params, grads, moments, step, opt_cfg)
        return params, moments, loss, metrics

    losses, t0 = [], time.perf_counter()
    for i in range(args.steps):
        x, y = make_batch(rng, templates, args.batch)
        params, moments, loss, metrics = train_step(
            params, moments, jnp.int32(i), x, y)
        losses.append(float(loss))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {losses[-1]:.4f}  "
                  f"|g| {float(metrics['grad_norm']):.3f}")
    dt = time.perf_counter() - t0

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({dt / args.steps * 1e3:.0f} ms/step, all convs on trim "
          f"kernels fwd+bwd)")
    if args.steps >= 40:              # the calibrated acceptance run
        assert last < first - 0.1, (
            f"training did not learn: {first:.4f} -> {last:.4f}")
        print("OK: loss decreased")
    if args.json:
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(dict(losses=losses, steps=args.steps,
                           ms_per_step=dt / args.steps * 1e3), f)


if __name__ == "__main__":
    main()
