"""Batched CNN serving on sharded TrIM convolutions (DESIGN.md §6).

The `launch/serve.py`-style driver for the conv stack: requests queue up,
get padded into fixed-size batches (one compiled program per batch
shape), and every convolution of the forward pass runs the ``shard_map``
halo-exchange path — images shard over the mesh's 'data' axis, output
H-strips over 'model', with the K-1 boundary rows exchanged between
neighbor devices before each per-shard Pallas kernel.  The modeled
``ShardedConvPlan`` traffic of the first layer (HBM terms + the
cross-device halo bytes) is printed next to the measured throughput so
the analytical and observed costs sit side by side.

``--net vgg16|alexnet`` swaps the small CNN for a full paper topology
(every conv layer, real spatial dims and pooling; channels divided by
``--scale``) running on tuned, packed plans — the whole-network
execution engine of DESIGN.md §7 behind the same batching loop.  Packed
weights freeze a single-device layout, so ``--net`` serves single-device
(no mesh); the default simple CNN keeps the sharded path.

  PYTHONPATH=src python examples/serve_cnn.py --devices 4 --data 2 \
      --spatial 2 --requests 64 --batch 16
  PYTHONPATH=src python examples/serve_cnn.py --net vgg16 --scale 16 \
      --requests 8 --batch 4
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --devices N must take effect before the first jax import (XLA reads
# the host-device flag at initialization; hostdevices is jax-free)
from repro.launch.hostdevices import force_host_device_count_from_argv
force_host_device_count_from_argv()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FusedGroupPlan, NetworkPlan, autotune, guard,
                        scale_layers, network_layers)
from repro.core.conv_shard import ShardedConvPlan
from repro.core.roofline import sharded_conv_roofline
from repro.kernels import ops
from repro.launch.mesh import make_conv_mesh
from repro.models import layers
from repro.models.base import init_params

IMAGE, CIN, N_CLASSES = 32, 3, 10
CHANNELS = (8, 16)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1,
                    help="force N host CPU devices (handled pre-import)")
    ap.add_argument("--data", type=int, default=1,
                    help="data-parallel shards (images over 'data')")
    ap.add_argument("--spatial", type=int, default=1,
                    help="spatial shards (output H-strips over 'model')")
    ap.add_argument("--requests", type=int, default=32,
                    help="total images queued")
    ap.add_argument("--batch", type=int, default=8,
                    help="serving batch size (requests pad up to it)")
    ap.add_argument("--net", default=None,
                    choices=["vgg16", "alexnet", "mobilenet"],
                    help="serve a full paper topology on tuned, packed "
                         "plans (single-device; default: the small "
                         "sharded CNN)")
    ap.add_argument("--scale", type=int, default=16,
                    help="channel divisor for the executed --net "
                         "configuration")
    ap.add_argument("--fused", action="store_true",
                    help="serve --net on fused residency-group "
                         "megakernels (DESIGN.md §8) instead of packed "
                         "per-layer plans")
    args = ap.parse_args()
    if args.fused and not args.net:
        raise SystemExit("--fused needs --net (the small CNN serves the "
                         "sharded per-layer path)")

    mesh = None
    if args.data * args.spatial > 1:
        if args.net:
            raise SystemExit("--net serves packed single-device plans; "
                             "drop --data/--spatial")
        mesh = make_conv_mesh(args.data, args.spatial)
        if args.batch % args.data:
            raise SystemExit(f"--batch {args.batch} must divide over "
                             f"--data {args.data}")

    fplan = None
    if args.net:
        topo = scale_layers(network_layers(args.net), args.scale)
        image, cin = topo[0].ifmap, topo[0].in_channels
        autotune.tune_network(topo, n=args.batch)
        params = init_params(
            layers.cnn_params_from_layers(topo, n_classes=N_CLASSES),
            jax.random.PRNGKey(0))
        if args.fused:
            # the megakernel streams raw weight taps itself — no packing
            fplan = FusedGroupPlan.build(topo, n=args.batch)
            fs = fplan.summary()
            print(f"{args.net} fused plan @ batch {args.batch}: "
                  f"{fs['groups']} groups (max depth {fs['max_depth']}), "
                  f"executed {fs['executed_bytes']/1e6:.1f}MB vs "
                  f"per-layer {fs['per_layer_bytes']/1e6:.1f}MB "
                  f"({fs['executed_ratio']:.2f}x)")
        else:
            params = layers.cnn_pack_params(params, topo, n=args.batch)
        netplan = NetworkPlan.build(args.net, n=args.batch)
        t = netplan.hbm_bytes()
        print(f"{args.net} NetworkPlan @ batch {args.batch} (full scale): "
              f"hbm={t['total']/1e6:.1f}MB, Ops/MAcc 3dtrim "
              f"{netplan.ops_per_macc('3dtrim'):.1f} vs trim "
              f"{netplan.ops_per_macc('trim'):.1f}")
    else:
        topo, image, cin = None, IMAGE, CIN
        params = init_params(
            layers.simple_cnn_params(cin=CIN, channels=CHANNELS,
                                     n_classes=N_CLASSES),
            jax.random.PRNGKey(0))

        # the modeled sharded traffic of the first conv layer at this
        # batch
        kshape, _ = ops.kernel_input_shape(
            (args.batch, IMAGE, IMAGE, CIN), 3, 1, "same")
        plan = ShardedConvPlan.build(kshape, (3, 3, CIN, CHANNELS[0]),
                                     batch_shards=args.data,
                                     spatial_shards=args.spatial)
        traffic = plan.sharded_traffic()
        terms = sharded_conv_roofline("conv0", plan)
        print(f"conv0 plan @ batch {args.batch}: "
              f"hbm={traffic['hbm_total']}B "
              f"halo={traffic['halo']}B "
              f"({plan.halo_bytes_per_device:.0f}B/dev, "
              f"t_coll={terms.t_collective * 1e6:.2f}us, "
              f"dominant={terms.dominant})")

    @jax.jit
    def forward(p, x):
        if topo is not None:
            return layers.cnn_apply_from_layers(p, topo, x,
                                                fused=args.fused,
                                                fuse_plan=fplan)
        return layers.simple_cnn_apply(p, x, mesh=mesh)

    rng = np.random.default_rng(0)
    queue = rng.standard_normal(
        (args.requests, image, image, cin)).astype(np.float32)

    # warmup compile on the fixed batch shape
    forward(params, jnp.zeros((args.batch, image, image, cin),
                              jnp.float32)).block_until_ready()

    served, preds, t0 = 0, [], time.perf_counter()
    while served < args.requests:
        chunk = queue[served:served + args.batch]
        real = len(chunk)
        if real < args.batch:            # pad the ragged final batch
            chunk = np.concatenate(
                [chunk, np.zeros((args.batch - real, image, image, cin),
                                 np.float32)])
        logits = forward(params, jnp.asarray(chunk))
        preds.append(np.asarray(logits[:real]).argmax(-1))
        served += real
    dt = time.perf_counter() - t0

    preds = np.concatenate(preds)
    mesh_desc = (f"{args.data}x{args.spatial} (data x spatial)"
                 if mesh is not None else
                 f"single device ({args.net} x{args.scale})" if args.net
                 else "single device")
    print(f"served {served} images in {dt:.2f}s "
          f"({served / dt:.1f} img/s) on {mesh_desc}; "
          f"class histogram {np.bincount(preds, minlength=N_CLASSES)}")

    # degraded-mode report (DESIGN.md §9): silence means every conv ran
    # on its intended tier; a served batch that survived on a fallback
    # tier is labeled, never silent
    for e in guard.events():
        where = f" [{e['layer']}]" if e.get("layer") else ""
        print(f"DEGRADED: {e['tier']} -> {e['to']}{where} "
              f"({e['kind']}): {e['error'][:100]}")


if __name__ == "__main__":
    main()
