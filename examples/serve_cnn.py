"""Batched CNN serving on sharded TrIM convolutions, rebased onto the
continuous-batching engine (DESIGN.md §6/§10).

Requests enter the :class:`~repro.core.serving.ServingEngine` queue and
are served in *bucket* batches — a fixed grid of batch sizes, one
compiled program each; partial batches pad up to the bucket and the
padding rows are masked out of the results.  The engine prewarms the
autotune cache and every bucket's compiled program before the first
request, so serving never hits a cold tune.

The default small CNN keeps the ``shard_map`` halo-exchange path:
images shard over the mesh's 'data' axis, output H-strips over 'model',
with the K-1 boundary rows exchanged between neighbor devices before
each per-shard Pallas kernel.  The modeled ``ShardedConvPlan`` traffic
of the first layer is printed next to the measured throughput so the
analytical and observed costs sit side by side.

``--net vgg16|alexnet`` swaps the small CNN for a full paper topology
(every conv layer, real spatial dims and pooling; channels divided by
``--scale``) served through the engine's tuned guarded plans —
``--fused`` runs the residency-group megakernels of DESIGN.md §8.

  PYTHONPATH=src python examples/serve_cnn.py --devices 4 --data 2 \
      --spatial 2 --requests 64 --batch 16
  PYTHONPATH=src python examples/serve_cnn.py --net vgg16 --scale 16 \
      --requests 8 --batch 4
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --devices N must take effect before the first jax import (XLA reads
# the host-device flag at initialization; hostdevices is jax-free)
from repro.launch.hostdevices import force_host_device_count_from_argv
force_host_device_count_from_argv()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FusedGroupPlan, NetworkPlan, guard,
                        scale_layers, network_layers)
from repro.core.conv_shard import ShardedConvPlan
from repro.core.roofline import sharded_conv_roofline
from repro.core.serving import Replica, ServingEngine, pow2_buckets, replay
from repro.kernels import ops
from repro.launch.mesh import make_conv_mesh
from repro.models import layers
from repro.models.base import init_params

IMAGE, CIN, N_CLASSES = 32, 3, 10
CHANNELS = (8, 16)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1,
                    help="force N host CPU devices (handled pre-import)")
    ap.add_argument("--data", type=int, default=1,
                    help="data-parallel shards (images over 'data')")
    ap.add_argument("--spatial", type=int, default=1,
                    help="spatial shards (output H-strips over 'model')")
    ap.add_argument("--requests", type=int, default=32,
                    help="total images queued")
    ap.add_argument("--batch", type=int, default=8,
                    help="largest serving bucket (the grid is powers of "
                         "two up to it; requests pad up to a bucket)")
    ap.add_argument("--net", default=None,
                    choices=["vgg16", "alexnet", "mobilenet"],
                    help="serve a full paper topology on tuned guarded "
                         "plans (single-device; default: the small "
                         "sharded CNN)")
    ap.add_argument("--scale", type=int, default=16,
                    help="channel divisor for the executed --net "
                         "configuration")
    ap.add_argument("--fused", action="store_true",
                    help="serve --net on fused residency-group "
                         "megakernels (DESIGN.md §8) instead of "
                         "per-layer plans")
    args = ap.parse_args()
    if args.fused and not args.net:
        raise SystemExit("--fused needs --net (the small CNN serves the "
                         "sharded per-layer path)")

    mesh = None
    buckets = pow2_buckets(args.batch)
    if args.data * args.spatial > 1:
        if args.net:
            raise SystemExit("--net serves single-device plans; "
                             "drop --data/--spatial")
        mesh = make_conv_mesh(args.data, args.spatial)
        if args.batch % args.data:
            raise SystemExit(f"--batch {args.batch} must divide over "
                             f"--data {args.data}")
        # every bucket's batch must shard evenly over 'data'
        buckets = tuple(b for b in buckets if b % args.data == 0)

    if args.net:
        topo = scale_layers(network_layers(args.net), args.scale)
        image, cin = topo[0].ifmap, topo[0].in_channels
        params = init_params(
            layers.cnn_params_from_layers(topo, n_classes=N_CLASSES),
            jax.random.PRNGKey(0))
        if args.fused:
            fplan = FusedGroupPlan.build(topo, n=args.batch)
            fs = fplan.summary()
            print(f"{args.net} fused plan @ batch {args.batch}: "
                  f"{fs['groups']} groups (max depth {fs['max_depth']}), "
                  f"executed {fs['executed_bytes']/1e6:.1f}MB vs "
                  f"per-layer {fs['per_layer_bytes']/1e6:.1f}MB "
                  f"({fs['executed_ratio']:.2f}x)")
        netplan = NetworkPlan.build(args.net, n=args.batch)
        t = netplan.hbm_bytes()
        print(f"{args.net} NetworkPlan @ batch {args.batch} (full scale): "
              f"hbm={t['total']/1e6:.1f}MB, Ops/MAcc 3dtrim "
              f"{netplan.ops_per_macc('3dtrim'):.1f} vs trim "
              f"{netplan.ops_per_macc('trim'):.1f}")
        engine = ServingEngine.for_topology(topo, params, buckets=buckets,
                                            fused=args.fused)
    else:
        image, cin = IMAGE, CIN
        params = init_params(
            layers.simple_cnn_params(cin=CIN, channels=CHANNELS,
                                     n_classes=N_CLASSES),
            jax.random.PRNGKey(0))

        # the modeled sharded traffic of the first conv layer at the
        # largest bucket
        kshape, _ = ops.kernel_input_shape(
            (args.batch, IMAGE, IMAGE, CIN), 3, 1, "same")
        plan = ShardedConvPlan.build(kshape, (3, 3, CIN, CHANNELS[0]),
                                     batch_shards=args.data,
                                     spatial_shards=args.spatial)
        traffic = plan.sharded_traffic()
        terms = sharded_conv_roofline("conv0", plan)
        print(f"conv0 plan @ batch {args.batch}: "
              f"hbm={traffic['hbm_total']}B "
              f"halo={traffic['halo']}B "
              f"({plan.halo_bytes_per_device:.0f}B/dev, "
              f"t_coll={terms.t_collective * 1e6:.2f}us, "
              f"dominant={terms.dominant})")

        call = jax.jit(lambda p, x: layers.simple_cnn_apply(p, x,
                                                            mesh=mesh))
        rep = Replica(name="replica0",
                      fn=lambda b: np.asarray(call(params,
                                                   jnp.asarray(b))))
        engine = ServingEngine([rep], buckets,
                               input_shape=(image, image, cin))

    engine.prewarm()

    rng = np.random.default_rng(0)
    xs = rng.standard_normal(
        (args.requests, image, image, cin)).astype(np.float32)
    # the original one-shot driver drained a full queue: arrive
    # everything at t=0 and let continuous batching carve it into
    # max-bucket batches FIFO (service times measured from the real
    # forwards)
    trace = [(0.0, i, xs[i]) for i in range(args.requests)]
    results, rejected = replay(engine, trace)

    preds = np.asarray([results[i].argmax(-1)
                        for i in sorted(results)])
    s = engine.recorder.summary()
    st = engine.stats()
    mesh_desc = (f"{args.data}x{args.spatial} (data x spatial)"
                 if mesh is not None else
                 f"single device ({args.net} x{args.scale})" if args.net
                 else "single device")
    print(f"served {st['served']} images in {s['span_s']:.2f}s "
          f"({s['throughput_rps']:.1f} img/s) on {mesh_desc}; "
          f"bucket batches {st['bucket_batches']}, "
          f"cold tunes {st['cold_tunes']}, rejected {len(rejected)}; "
          f"class histogram {np.bincount(preds, minlength=N_CLASSES)}")

    # degraded-mode report (DESIGN.md §9): silence means every conv ran
    # on its intended tier; a served batch that survived on a fallback
    # tier is labeled, never silent
    for name, rep_stats in st["replicas"].items():
        for e in rep_stats["guard_events"]:
            where = f" [{e['layer']}]" if e.get("layer") else ""
            print(f"DEGRADED {name}: {e['tier']} -> {e['to']}{where} "
                  f"({e['kind']}): {e['error'][:100]}")


if __name__ == "__main__":
    main()
