"""End-to-end driver: train a ~15M-param LM for a few hundred steps on the
learnable synthetic copy task, with mid-run checkpointing.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile

from repro.launch import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="trim_lm_ckpt_")
    train.main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--batch", "16", "--seq", "64", "--task", "copy",
                "--ckpt-dir", ckpt, "--ckpt-every", "100"])
    print("checkpoints in", ckpt)
