"""The paper's own workload: VGG-16-style CNN inference running through
the trim_conv2d Pallas kernel — bias + ReLU fused into the kernel epilogue,
a MobileNet-style depthwise-separable block on the grouped-conv path, and
the per-layer OPs/Access accounting of Fig. 6 printed alongside.

Every traffic/arithmetic-intensity number comes from the same ``ConvPlan``
objects the kernels execute.

  PYTHONPATH=src python examples/cnn_inference.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import compare_layer, mobilenet_layers, vgg16_layers
from repro.core.roofline import conv_plan_roofline
from repro.models import layers

rng = jax.random.PRNGKey(0)

# a reduced VGG-16 head (channel counts /8, 32x32 input) that runs in
# seconds on CPU interpret mode; the access accounting uses full configs
x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 32, 32, 3)),
                jnp.float32)
channels = [8, 8, 16, 16, 32]
from repro.models.base import init_params
for i, c in enumerate(channels):
    p = init_params(layers.conv2d_params(3, x.shape[-1], c),
                    jax.random.fold_in(rng, i))
    x = layers.conv2d_apply(p, x, activation="relu")   # fused bias+ReLU
    if i % 2 == 1:
        x = x[:, ::2, ::2, :]          # poor man's maxpool (stride slice)
print("reduced VGG head output:", x.shape, "mean", float(x.mean()))

# depthwise-separable block (MobileNet scenario, grouped kernel path)
p = init_params(layers.depthwise_separable_params(3, x.shape[-1], 64),
                jax.random.fold_in(rng, 99))
y = layers.depthwise_separable_apply(p, x, stride=2)
print("depthwise-separable block output:", y.shape, "mean", float(y.mean()))

print("\nFull VGG-16 per-layer OPs/Access/Slice (Fig. 6a):")
for layer in vgg16_layers():
    row = compare_layer(layer)
    print(f"  {row['layer']:>18s}: 3D-TrIM {row['3d-trim']:.2f} "
          f"vs TrIM {row['trim']:.2f}  ({row['improvement']:.2f}x)")

print("\nTPU-side ConvPlan traffic + roofline (same plan the kernel runs):")
for layer in [vgg16_layers()[1]] + mobilenet_layers()[:2]:
    plan = layer.plan()
    for mode in ("3dtrim", "trim"):
        t = plan.hbm_bytes(mode)
        print(f"  {layer.name:>6s} [{mode:7s}]: input {t['input']/1e6:7.1f} MB "
              f"(halo overhead {t['overhead_pct']:4.1f}%)  "
              f"AI {plan.arithmetic_intensity(mode):7.1f} flop/B")
    terms = conv_plan_roofline(layer.name, plan)
    print(f"  {layer.name:>6s} roofline: T_comp {terms.t_compute*1e6:.0f} us "
          f"T_mem {terms.t_memory*1e6:.0f} us -> {terms.dominant}-bound, "
          f"grid {plan.grid}, tile_h {plan.tile_h}, "
          f"VMEM {plan.vmem_resident_bytes/2**20:.1f} MiB")
