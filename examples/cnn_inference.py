"""The paper's own workload: VGG-16-style CNN inference running through
the trim_conv2d Pallas kernel — bias + ReLU fused into the kernel epilogue,
a MobileNet-style depthwise-separable block on the grouped-conv path, and
the per-layer OPs/Access accounting of Fig. 6 printed alongside.

This is the closed loop of the conv execution engine (DESIGN.md §4):
each layer is autotuned once (model-guided (tile_h, tile_cout, dataflow)
search persisted in a JSON cache), weights are pre-packed into the
kernel's padded layout at load time, and the forward pass then runs
entirely on packed params and cached plans — ``ops.conv2d`` finds every
knob in the cache.

Every traffic/arithmetic-intensity number comes from the same ``ConvPlan``
objects the kernels execute.

  PYTHONPATH=src python examples/cnn_inference.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# keep the example's tuning records repo-local (and the run reproducible)
os.environ.setdefault("REPRO_CONVTUNE_CACHE", os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "convtune.json"))

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import autotune, compare_layer, mobilenet_layers, vgg16_layers
from repro.core.roofline import conv_plan_roofline
from repro.models import layers

rng = jax.random.PRNGKey(0)

# a reduced VGG-16 head (channel counts /8, 32x32 input) that runs in
# seconds on CPU interpret mode; the access accounting uses full configs
x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 32, 32, 3)),
                jnp.float32)
channels = [8, 8, 16, 16, 32]
from repro.models.base import init_params

# load time: tune each layer's plan once (persisted), pack each layer's
# weights into the kernel layout once
packed, shapes, cur = [], [], x.shape
for i, c in enumerate(channels):
    p = init_params(layers.conv2d_params(3, cur[-1], c),
                    jax.random.fold_in(rng, i))
    w_shape = p["w"].shape
    kshape, pad = (cur[0], cur[1] + 2, cur[2] + 2, cur[3]), 0  # 'same', K=3
    autotune.tune(kshape, w_shape, stride=1, pad=pad)
    packed.append(layers.conv2d_pack_params(p, x_shape=cur))
    shapes.append(cur)
    hw = (cur[1] // 2, cur[2] // 2) if i % 2 == 1 else (cur[1], cur[2])
    cur = (cur[0], *hw, c)

# inference: packed params + cached plans only
for i, p in enumerate(packed):
    x = layers.conv2d_apply(p, x, activation="relu")   # fused bias+ReLU
    if i % 2 == 1:
        x = x[:, ::2, ::2, :]          # poor man's maxpool (stride slice)
print("reduced VGG head output:", x.shape, "mean", float(x.mean()))
rec = autotune.knobs_for((1, 34, 34, 3), (3, 3, 3, 8), stride=1, pad=0)
print("layer-0 cached plan:", rec)

# depthwise-separable block (MobileNet scenario, grouped kernel path),
# same treatment: pack both convs at load time
p = init_params(layers.depthwise_separable_params(3, x.shape[-1], 64),
                jax.random.fold_in(rng, 99))
p = layers.depthwise_separable_pack_params(p, x_shape=x.shape, stride=2)
y = layers.depthwise_separable_apply(p, x, stride=2)
print("depthwise-separable block output:", y.shape, "mean", float(y.mean()))

print("\nFull VGG-16 per-layer OPs/Access/Slice (Fig. 6a):")
for layer in vgg16_layers():
    row = compare_layer(layer)
    print(f"  {row['layer']:>18s}: 3D-TrIM {row['3d-trim']:.2f} "
          f"vs TrIM {row['trim']:.2f}  ({row['improvement']:.2f}x)")

print("\nTPU-side ConvPlan traffic + roofline (same plan the kernel runs):")
for layer in [vgg16_layers()[1]] + mobilenet_layers()[:2]:
    for dataflow in ("carry", "halo"):
        plan = layer.plan(dataflow=dataflow)
        t = plan.hbm_bytes()
        print(f"  {layer.name:>6s} [{dataflow:5s}]: input "
              f"{t['input']/1e6:7.1f} MB "
              f"(halo overhead {t['overhead_pct']:4.1f}%)  "
              f"AI {plan.arithmetic_intensity():7.1f} flop/B")
    plan = layer.plan()
    terms = conv_plan_roofline(layer.name, plan)
    print(f"  {layer.name:>6s} roofline: T_comp {terms.t_compute*1e6:.0f} us "
          f"T_mem {terms.t_memory*1e6:.0f} us -> {terms.dominant}-bound, "
          f"grid {plan.grid}, tile_h {plan.tile_h}, "
          f"VMEM {plan.vmem_resident_bytes/2**20:.1f} MiB")
