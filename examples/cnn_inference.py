"""The paper's own workload: VGG-16-style CNN inference running through
the trim_conv2d Pallas kernel, with the per-layer OPs/Access accounting of
Fig. 6 printed alongside.

  PYTHONPATH=src python examples/cnn_inference.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import compare_layer, vgg16_layers
from repro.kernels import ops
from repro.kernels.trim_conv2d import hbm_traffic_model

rng = np.random.default_rng(0)

# a reduced VGG-16 head (channel counts /8, 32x32 input) that runs in
# seconds on CPU interpret mode; the access accounting uses full configs
x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
channels = [8, 8, 16, 16, 32]
for i, c in enumerate(channels):
    w = jnp.asarray(rng.standard_normal((3, 3, x.shape[-1], c)) * 0.2,
                    jnp.float32)
    x = jnp.maximum(ops.conv2d(x, w, padding="same", impl="pallas"), 0.0)
    if i % 2 == 1:
        x = x[:, ::2, ::2, :]          # poor man's maxpool (stride slice)
print("reduced VGG head output:", x.shape, "mean", float(x.mean()))

print("\nFull VGG-16 per-layer OPs/Access/Slice (Fig. 6a):")
for layer in vgg16_layers():
    row = compare_layer(layer)
    print(f"  {row['layer']:>18s}: 3D-TrIM {row['3d-trim']:.2f} "
          f"vs TrIM {row['trim']:.2f}  ({row['improvement']:.2f}x)")

print("\nTPU-side HBM traffic model (kernel strips, 224x224x64 -> 64):")
for mode in ("3dtrim", "trim"):
    t = hbm_traffic_model(1, 224, 224, 64, 64, 3, tile_h=8, mode=mode)
    print(f"  {mode:7s}: input {t['input']/1e6:.1f} MB "
          f"(halo overhead {t['overhead_pct']:.1f}%)")
