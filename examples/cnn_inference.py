"""The paper's own workload: CNN inference running through the
trim_conv2d Pallas kernel — bias + ReLU fused into the kernel epilogue,
a MobileNet-style depthwise-separable block on the grouped-conv path, and
the paper's Ops/Access accounting printed alongside.

This is the closed loop of the conv execution engine (DESIGN.md §4/§7):
each layer is autotuned once (model-guided (tile_h, tile_cout, dataflow)
search persisted in a JSON cache), weights are pre-packed into the
kernel's padded layout at load time, and the forward pass then runs
entirely on packed params and cached plans — ``ops.conv2d`` finds every
knob in the cache.

Two modes:

  PYTHONPATH=src python examples/cnn_inference.py
      the original demo: a reduced VGG-16 head + depthwise block, plus
      the full-scale per-layer Fig. 6 accounting.

  PYTHONPATH=src python examples/cnn_inference.py --net vgg16 [--scale 8]
      the whole-network engine: run the FULL topology (every conv layer,
      real spatial dims / strides / pooling, channels divided by
      ``--scale`` so CPU interpret mode stays fast) on tuned, packed
      plans, then print the ``NetworkPlan`` whole-network accounting —
      HBM traffic, residency decisions and the paper's trim-vs-3dtrim
      Ops/MAcc comparison — for the full-scale configuration.

Every traffic/arithmetic-intensity number comes from the same ``ConvPlan``
objects the kernels execute.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# keep the example's tuning records repo-local (and the run reproducible)
os.environ.setdefault("REPRO_CONVTUNE_CACHE", os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "convtune.json"))

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import (FusedGroupPlan, NetworkPlan, autotune,
                        compare_layer, guard, mobilenet_layers,
                        network_layers, scale_layers, vgg16_layers)
from repro.core.roofline import conv_plan_roofline, network_roofline
from repro.models import layers
from repro.models.base import init_params


def run_network(net: str, scale: int, batch: int,
                fused: bool = False) -> None:
    """The whole-network path: tune every layer, pack every weight, run
    the full topology, print the NetworkPlan evaluation.  ``fused``
    swaps the per-layer engine for the residency-group megakernels
    (DESIGN.md §8): raw params (the megakernel streams weight taps
    itself), one ``pallas_call`` per fused conv→[pool]→conv group."""
    full = network_layers(net)
    topo = scale_layers(full, scale)
    image = topo[0].ifmap

    t0 = time.perf_counter()
    recs = autotune.tune_network(topo, n=batch)
    tuned = sum(1 for r in recs.values() if "skipped" not in r)
    print(f"tuned {tuned}/{len(topo)} layers in "
          f"{time.perf_counter() - t0:.2f}s "
          f"(skipped: {[k for k, r in recs.items() if 'skipped' in r]})")

    params = init_params(layers.cnn_params_from_layers(topo),
                         jax.random.PRNGKey(0))
    fplan = None
    if fused:
        fplan = FusedGroupPlan.build(topo, n=batch)
        groups = [f"conv{g.start}..conv{g.start + g.depth - 1}"
                  f"(T={g.strip_rows})" if g.fused else f"conv{g.start}"
                  for g in fplan.groups]
        print(f"fused groups: {' | '.join(groups)}")
    else:
        params = layers.cnn_pack_params(params, topo, n=batch)

    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, image, image, topo[0].in_channels)), jnp.float32)
    t0 = time.perf_counter()
    y = layers.cnn_apply_from_layers(params, topo, x, fused=fused,
                                     fuse_plan=fplan)
    y.block_until_ready()
    mode = "fused megakernels" if fused else "packed+tuned"
    print(f"{net} x{scale} forward (batch {batch}, {len(topo)} convs, "
          f"{mode}): {y.shape}, mean {float(y.mean()):.4f}, "
          f"{time.perf_counter() - t0:.2f}s")

    if fused:
        # executed-traffic accounting of the same fusion at full scale
        fs = FusedGroupPlan.build(net, n=batch).summary()
        print(f"  executed HBM (full scale): fused "
              f"{fs['executed_bytes']/1e6:.1f} MB vs per-layer "
              f"{fs['per_layer_bytes']/1e6:.1f} MB -> "
              f"{fs['executed_ratio']:.2f}x less traffic "
              f"({fs['fused_layers']}/{len(full)} layers in depth>=2 "
              f"groups)")

    # the full-scale analytical evaluation of the same topology
    plan = NetworkPlan.build(net, n=batch)
    cmp, arch = plan.compare(), plan.arch_compare()
    t = plan.hbm_bytes()
    resident = [s.name for s in plan.steps if s.resident_out]
    print(f"\nNetworkPlan ({net}, full scale, batch {batch}, "
          f"residency=auto):")
    print(f"  HBM {t['total']/1e6:.1f} MB "
          f"(input {t['input']/1e6:.1f} / weights {t['weights']/1e6:.1f} "
          f"/ output {t['output']/1e6:.1f}); "
          f"resident boundaries: {resident or 'none'}")
    print(f"  Ops/MAcc (engine strips): 3dtrim "
          f"{cmp['ops_per_macc_3dtrim']:.1f} vs trim "
          f"{cmp['ops_per_macc_trim']:.1f} ({cmp['improvement']:.3f}x)")
    print(f"  Ops/MAcc (paper arch model): 3D-TrIM "
          f"{arch['ops_per_macc']['3d-trim']:.1f} vs TrIM "
          f"{arch['ops_per_macc']['trim']:.1f} -> "
          f"{arch['improvement']:.2f}x per slice")
    terms = network_roofline(net, plan)
    print(f"  roofline: T_comp {terms.t_compute*1e3:.2f} ms, "
          f"T_mem {terms.t_memory*1e3:.2f} ms -> {terms.dominant}-bound")


def run_demo() -> None:
    """The original reduced-head demo (kept as the default)."""
    rng = jax.random.PRNGKey(0)

    # a reduced VGG-16 head (channel counts /8, 32x32 input) that runs in
    # seconds on CPU interpret mode; the access accounting uses full
    # configs
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((1, 32, 32, 3)),
        jnp.float32)
    channels = [8, 8, 16, 16, 32]

    # load time: tune each layer's plan once (persisted), pack each
    # layer's weights into the kernel layout once
    packed, cur = [], x.shape
    for i, c in enumerate(channels):
        p = init_params(layers.conv2d_params(3, cur[-1], c),
                        jax.random.fold_in(rng, i))
        kshape = (cur[0], cur[1] + 2, cur[2] + 2, cur[3])  # 'same', K=3
        autotune.tune(kshape, p["w"].shape, stride=1, pad=0)
        packed.append(layers.conv2d_pack_params(p, x_shape=cur))
        hw = (cur[1] // 2, cur[2] // 2) if i % 2 == 1 else (cur[1], cur[2])
        cur = (cur[0], *hw, c)

    # inference: packed params + cached plans only
    for i, p in enumerate(packed):
        x = layers.conv2d_apply(p, x, activation="relu")  # fused bias+ReLU
        if i % 2 == 1:
            x = x[:, ::2, ::2, :]      # poor man's maxpool (stride slice)
    print("reduced VGG head output:", x.shape, "mean", float(x.mean()))
    rec = autotune.knobs_for((1, 34, 34, 3), (3, 3, 3, 8), stride=1, pad=0)
    print("layer-0 cached plan:", rec)

    # depthwise-separable block (MobileNet scenario, grouped kernel
    # path), same treatment: pack both convs at load time
    p = init_params(layers.depthwise_separable_params(3, x.shape[-1], 64),
                    jax.random.fold_in(rng, 99))
    p = layers.depthwise_separable_pack_params(p, x_shape=x.shape,
                                               stride=2)
    y = layers.depthwise_separable_apply(p, x, stride=2)
    print("depthwise-separable block output:", y.shape,
          "mean", float(y.mean()))

    print("\nFull VGG-16 per-layer OPs/Access/Slice (Fig. 6a):")
    for layer in vgg16_layers():
        row = compare_layer(layer)
        print(f"  {row['layer']:>18s}: 3D-TrIM {row['3d-trim']:.2f} "
              f"vs TrIM {row['trim']:.2f}  ({row['improvement']:.2f}x)")

    print("\nTPU-side ConvPlan traffic + roofline "
          "(same plan the kernel runs):")
    for layer in [vgg16_layers()[1]] + mobilenet_layers()[:2]:
        for dataflow in ("carry", "halo"):
            plan = layer.plan(dataflow=dataflow)
            t = plan.hbm_bytes()
            print(f"  {layer.name:>6s} [{dataflow:5s}]: input "
                  f"{t['input']/1e6:7.1f} MB "
                  f"(halo overhead {t['overhead_pct']:4.1f}%)  "
                  f"AI {plan.arithmetic_intensity():7.1f} flop/B")
        plan = layer.plan()
        terms = conv_plan_roofline(layer.name, plan)
        print(f"  {layer.name:>6s} roofline: "
              f"T_comp {terms.t_compute*1e6:.0f} us "
              f"T_mem {terms.t_memory*1e6:.0f} us -> "
              f"{terms.dominant}-bound, "
              f"grid {plan.grid}, tile_h {plan.tile_h}, "
              f"VMEM {plan.vmem_resident_bytes/2**20:.1f} MiB")


def report_degraded() -> None:
    """Print the guarded-dispatch demotion report (DESIGN.md §9): which
    tiers fell, to where, and why.  Silence means every conv ran on its
    intended tier — a degraded run is never mistaken for a healthy one."""
    evts = guard.events()
    if not evts:
        return
    print(f"\nDEGRADED MODE: {len(evts)} conv tier demotion(s) "
          f"(results remain correct via fallback):")
    for e in evts:
        where = f" [{e['layer']}]" if e.get("layer") else ""
        print(f"  {e['tier']} -> {e['to']}{where} ({e['kind']}): "
              f"{e['error'][:100]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default=None,
                    choices=["vgg16", "alexnet", "mobilenet"],
                    help="run a full topology on tuned, packed plans "
                         "(default: the reduced-head demo)")
    ap.add_argument("--scale", type=int, default=8,
                    help="divide channel counts by this for the "
                         "executed configuration (accounting stays "
                         "full-scale)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--fused", action="store_true",
                    help="execute residency groups as fused megakernels "
                         "(conv->pool->conv chains VMEM-resident, "
                         "DESIGN.md §8) instead of one pallas_call per "
                         "layer; requires --net")
    args = ap.parse_args()
    if args.fused and not args.net:
        raise SystemExit("--fused needs --net (the reduced-head demo "
                         "has no fusion plan)")
    if args.net:
        run_network(args.net, args.scale, args.batch, fused=args.fused)
    else:
        run_demo()
    report_degraded()


if __name__ == "__main__":
    main()
