"""AdamW with sharded, dtype-configurable moments (ZeRO-1 friendly).

The moment trees reuse the parameter Param declarations, so they inherit
the parameter shardings; ``moment_dtype='bfloat16'`` halves optimizer HBM
(required to fit llama3-405b on a 256-chip pod — see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_moments(params: Any, cfg: AdamWConfig) -> dict:
    z = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"mu": jax.tree.map(z, params), "nu": jax.tree.map(z, params)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, moments, step, cfg: AdamWConfig):
    """Returns (new_params, new_moments, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        upd = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(moments["mu"])
    flat_nu = jax.tree.leaves(moments["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (jax.tree.unflatten(tdef, new_p),
            {"mu": jax.tree.unflatten(tdef, new_mu),
             "nu": jax.tree.unflatten(tdef, new_nu)},
            {"grad_norm": gnorm, "lr": lr})
