"""Gradient compression for the cross-pod all-reduce.

``compressed_psum``: int8-quantized gradient reduction via shard_map —
each device quantizes its local partial gradient to int8 (per-tensor
scale), all-gathers the int8 payload (1 byte/элемент on the wire instead
of 4), and reduces locally in fp32.  Ring wire cost: S*(g-1)/g bytes vs
2*S*4*(g-1)/g for an fp32 all-reduce — an ~8x collective-bytes saving,
visible in the dry-run HLO as an s8 all-gather.

``ef_quantize``: error-feedback quantization (residual carried in the
optimizer state) for when compression is applied at the optimizer level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_allreduce(x: jax.Array, axis_name):
    """Inside shard_map: all-reduce with int8 wire format.

    Quantize -> all-gather int8 (1 B/elt on the wire) -> fp32 local reduce.
    """
    q, scale = _quantize_int8(x.astype(jnp.float32))
    qg = jax.lax.all_gather(q, axis_name)           # int8 on the wire
    sg = jax.lax.all_gather(scale, axis_name)
    out = jnp.tensordot(sg, qg.astype(jnp.float32), axes=((0,), (0,)))
    return out.astype(x.dtype)


def compressed_psum(x: jax.Array, axis_name: str, mesh):
    """All-reduce a replicated-per-shard partial ``x`` over one mesh axis
    with int8 wire format (shard_map wrapper for manual-DP train steps)."""
    fn = functools.partial(int8_allreduce, axis_name=axis_name)
    return shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)


def ef_quantize(grad: jax.Array, residual: jax.Array, bits: int = 8):
    """Error-feedback quantization: returns (q_grad, new_residual)."""
    levels = 2 ** (bits - 1) - 1
    x = grad.astype(jnp.float32) + residual.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / levels + 1e-12
    q = jnp.clip(jnp.round(x / scale), -levels, levels) * scale
    return q.astype(grad.dtype), (x - q).astype(residual.dtype)
