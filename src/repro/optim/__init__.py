from repro.optim import adamw, compress  # noqa: F401
from repro.optim.adamw import AdamWConfig  # noqa: F401
