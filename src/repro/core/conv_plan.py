"""The single source of truth for TrIM convolution planning.

Every consumer of the 3D-TrIM dataflow used to re-derive the same tile
math independently: the Pallas kernel computed strip geometry inline, the
kernel module carried its own ``hbm_traffic_model``, and ``core/model.py``
had a third analytical model.  They could silently disagree, which made
perf hillclimbing against the analytical traffic numbers untrustworthy.

This module owns all of it (DESIGN.md §3):

* :class:`ConvPlan` — geometry plan for one 2D convolution: strip tiling,
  shadow-register carry sizes, Pallas grid shape, padded HBM layouts, and
  the analytical HBM byte counts in ``mode="3dtrim"`` (carry resident in
  VMEM, zero halo traffic) vs ``mode="trim"`` (K-1 halo rows re-fetched
  per strip — the overhead the paper's shadow registers eliminate).
  ``kernels/trim_conv2d.py`` builds its ``pallas_call`` from the plan;
  ``core/roofline.py`` and ``benchmarks/*`` read traffic and arithmetic
  intensity from the same object.

  The plan carries a ``dataflow`` axis (DESIGN.md §4) selecting which of
  the two schedules the kernel executes:

  * ``"carry"`` — the paper's shadow registers: strips are
    non-overlapping and the K-1 boundary rows ride in a VMEM scratch
    across *sequential* grid steps.  Zero halo traffic
    (``mode="3dtrim"`` accounting) but the (N, group, strip) axes must
    execute in order.
  * ``"halo"`` — TrIM-style over-fetch: every strip re-reads its K-1
    predecessor rows through an overlapping BlockSpec.  Pays the
    ``mode="trim"`` halo bytes but has no cross-step state, so every
    grid axis is order-independent (parallelizable / reorderable).

  The autotuner (``core/autotune.py``) picks the dataflow per layer from
  exactly these numbers.

* **Backward planning** — training runs the two conv cotangents as TrIM
  convolutions themselves (DESIGN.md §5), and their geometry comes from
  the same single source of truth:

  * :func:`input_grad_geometry` / :meth:`ConvPlan.build_input_grad` —
    the input cotangent is a *stride-1* TrIM convolution of the
    stride-dilated, edge-padded output cotangent with the spatially
    flipped, channel-transposed weights.  ``build_input_grad`` returns
    the ordinary :class:`ConvPlan` that conv executes, so the backward
    pass inherits the full ``carry``/``halo`` dataflow axis, the strip
    math and the HBM accounting of the forward kernel.
  * :class:`WeightGradPlan` / :meth:`ConvPlan.build_weight_grad` — the
    weight cotangent is a conv of the ifmap over the cotangent with the
    *spatial* axes contracted: strips of cotangent rows stay resident
    with their overlapping ifmap window (a halo-style fetch) while the
    K x K taps accumulate into a weight-shaped output revisited across
    the (batch, strip) sweep.  The plan owns the strip/grid/padded
    layouts and the analytical HBM bytes of that schedule.

* :class:`Conv1dPlan` — the 1D image of the same plan, consumed by
  ``kernels/trim_conv1d.py``.

* :func:`slice_reads_per_channel` — the paper-level per-slice external
  read count (Fig. 1), consumed by ``core/model.py`` (Fig. 6 accounting)
  and validated cycle-by-cycle by ``core/dataflow.TrimSliceSim``.

Grouped / depthwise convolution (``groups`` > 1, the MobileNet scenario
of the paper's OPs-per-access comparison) is a first-class plan axis: the
weight tensor is ``(K, K, Cin/groups, Cout)`` and every derived quantity
(carry width, weight blocks, MACs, traffic) accounts for the reduced
per-group fan-in.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.roofline import dtype_width

# Default budget for the auto-chosen input strip: half of a ~16 MiB VMEM
# core, leaving headroom for the weight tile, accumulator and pipelining.
STRIP_VMEM_BUDGET = 8 << 20


def resolve_dtype_bytes(dtype_bytes) -> int:
    """Normalize a plan's ``dtype_bytes`` argument.

    Plain ints pass through; anything dtype-like (``"bfloat16"``, ``"s8"``,
    ``np.dtype``, an array's ``.dtype``) is priced through the shared
    :func:`repro.core.roofline.dtype_width` table so plan traffic and
    roofline HLO parsing can never disagree on a width.
    """
    if isinstance(dtype_bytes, int):
        return dtype_bytes
    return dtype_width(dtype_bytes)


# ---------------------------------------------------------------------------
# Paper-level slice model (Fig. 1) — consumed by core/model and core/dataflow
# ---------------------------------------------------------------------------

def slice_reads_per_channel(height: int, width: int, kernel: int,
                            stride: int = 1, *, shadow: bool) -> int:
    """External reads of one ifmap channel for one pass of a TrIM slice.

    The sliding-window band advances by ``stride`` rows per output row.
    With shadow registers (3D-TrIM) every real activation is read exactly
    once.  Without them (TrIM), every band advance re-reads the last
    ``K-1`` activations of each of the ``K - stride`` re-used rows.
    """
    ideal = height * width
    if shadow:
        return ideal
    out_rows = (height - kernel) // stride + 1
    band_advances = max(out_rows - 1, 0)
    reused_rows = max(kernel - stride, 0)
    rereads_per_advance = reused_rows * (kernel - 1)
    return ideal + band_advances * rereads_per_advance


# ---------------------------------------------------------------------------
# 2D plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvPlan:
    """Geometry + traffic plan for one strided (grouped) 2D convolution.

    Shapes follow the kernel convention: input ``(N, H, W, Cin)``, weights
    ``(KH, KW, Cin/groups, Cout)``, symmetric zero padding ``pad``.  All
    derived quantities — strip geometry, carry size, grid, padded layouts,
    HBM bytes — are pure functions of these fields, so a plan printed by a
    benchmark is bit-identical to the one the kernel executes.
    """

    n: int
    h: int
    w: int
    cin: int
    cout: int
    kh: int
    kw: int
    stride: int = 1
    pad: int = 0
    groups: int = 1
    dtype_bytes: int = 4
    tile_h: int = 8            # strip height in *input* rows
    tile_cout: int = 128       # C_out tile per grid step (per group)
    dataflow: str = "carry"    # "carry" (shadow regs) | "halo" (over-fetch)
    vmem_budget: int = STRIP_VMEM_BUDGET

    def __post_init__(self):
        if self.dataflow not in ("carry", "halo"):
            raise ValueError(
                f"dataflow={self.dataflow!r} must be 'carry' or 'halo'")
        if self.cin % self.groups or self.cout % self.groups:
            raise ValueError(
                f"groups={self.groups} must divide cin={self.cin} and "
                f"cout={self.cout}")
        if self.tile_h % self.stride:
            raise ValueError(
                f"tile_h={self.tile_h} must be a multiple of the stride "
                f"{self.stride}")
        if self.tile_h < 1 or self.tile_cout < 1:
            raise ValueError(
                f"tile_h={self.tile_h} / tile_cout={self.tile_cout} "
                "must be >= 1")
        if self.h_out < 1 or self.w_out < 1:
            raise ValueError("empty output: input smaller than kernel")
        # Canonicalize oversized strips (DESIGN.md §6): any tile_h beyond
        # the full-height strip (one strip covering h_out + delta output
        # rows) is clamped to it, so plans built with tile_h > H_out are
        # identical — same padding, same grid, same traffic — instead of
        # billing/padding ever more rows that neither dataflow reads.
        full = (self.h_out + self.delta) * self.stride
        if self.tile_h > full:
            object.__setattr__(self, "tile_h", full)

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, x_shape, w_shape, *, stride: int = 1, pad: int = 0,
              groups: int = 1, dtype_bytes: int = 4,
              tile_h: int | None = None, tile_cout: int | None = None,
              dataflow: str = "carry",
              vmem_budget: int = STRIP_VMEM_BUDGET) -> "ConvPlan":
        """Plan from array shapes, auto-choosing tiles when not given.

        ``tile_cout`` defaults to an MXU-friendly 128 when it divides the
        per-group C_out, else the whole per-group C_out.  ``tile_h`` is the
        largest stride multiple whose resident strip fits ``vmem_budget``.
        """
        n, h, w, cin = x_shape
        kh, kw, cin_pg, cout = w_shape
        if cin_pg * groups != cin:
            raise ValueError(
                f"weights expect cin/groups={cin_pg} with groups={groups}, "
                f"input has cin={cin}")
        dtype_bytes = resolve_dtype_bytes(dtype_bytes)
        s = stride
        cout_pg = cout // groups
        if tile_cout is None:
            tile_cout = min(cout_pg, 128 if cout_pg % 128 == 0 else cout_pg)
        if tile_h is None:
            h_out = (h + 2 * pad - kh) // s + 1
            wp_bytes = (w + 2 * pad + kh) * cin_pg * dtype_bytes
            tile_h = max(s, min(h_out * s, vmem_budget // max(wp_bytes, 1)))
            tile_h -= tile_h % s
            tile_h = max(tile_h, s)
        return cls(n=n, h=h, w=w, cin=cin, cout=cout, kh=kh, kw=kw,
                   stride=s, pad=pad, groups=groups,
                   dtype_bytes=dtype_bytes, tile_h=tile_h,
                   tile_cout=tile_cout, dataflow=dataflow,
                   vmem_budget=vmem_budget)

    @classmethod
    def from_layer(cls, layer, *, n: int = 1, dtype_bytes: int = 4,
                   tile_h: int | None = None, tile_cout: int | None = None,
                   dataflow: str = "carry",
                   vmem_budget: int = STRIP_VMEM_BUDGET) -> "ConvPlan":
        """Plan from a ``core.model.ConvLayer`` description (duck-typed)."""
        groups = getattr(layer, "groups", 1)
        return cls.build(
            (n, layer.ifmap, layer.ifmap, layer.in_channels),
            (layer.kernel, layer.kernel, layer.in_channels // groups,
             layer.out_channels),
            stride=layer.stride, pad=layer.padding, groups=groups,
            dtype_bytes=dtype_bytes, tile_h=tile_h, tile_cout=tile_cout,
            dataflow=dataflow, vmem_budget=vmem_budget)

    @classmethod
    def build_input_grad(cls, x_shape, w_shape, *, stride: int = 1,
                         pad: int = 0, groups: int = 1,
                         dtype_bytes: int = 4, tile_h: int | None = None,
                         tile_cout: int | None = None,
                         dataflow: str = "carry",
                         vmem_budget: int = STRIP_VMEM_BUDGET
                         ) -> "ConvPlan":
        """Plan for the *input-gradient* conv of a forward problem.

        ``x_shape`` / ``w_shape`` / ``stride`` / ``pad`` describe the
        FORWARD convolution (the shapes the forward kernel saw).  The
        returned plan is the ordinary stride-1 ConvPlan that the input
        cotangent executes: input = the stride-dilated, ``K-1-pad``
        edge-padded output cotangent ``(N, ·, ·, Cout)``; weights = the
        flipped/transposed ``(KH, KW, Cout/groups, Cin)`` tensor.  Every
        dataflow/tile knob of the forward kernel applies unchanged.
        """
        geo = input_grad_geometry(x_shape, w_shape, stride=stride,
                                  pad=pad, groups=groups)
        return cls.build(geo["g_padded_shape"], geo["wt_shape"], stride=1,
                         pad=0, groups=groups, dtype_bytes=dtype_bytes,
                         tile_h=tile_h, tile_cout=tile_cout,
                         dataflow=dataflow, vmem_budget=vmem_budget)

    @classmethod
    def build_weight_grad(cls, x_shape, w_shape, *, stride: int = 1,
                          pad: int = 0, groups: int = 1,
                          dtype_bytes: int = 4,
                          tile_go: int | None = None,
                          tile_cout: int | None = None,
                          vmem_budget: int = STRIP_VMEM_BUDGET
                          ) -> "WeightGradPlan":
        """Plan for the *weight-gradient* conv of a forward problem.

        Arguments describe the FORWARD convolution; the returned
        :class:`WeightGradPlan` owns the strip/grid/traffic math of the
        spatially-contracted conv (ifmap over cotangent) the weight
        cotangent kernel executes.
        """
        n, h, w, cin = x_shape
        kh, kw, cin_pg, cout = w_shape
        if cin_pg * groups != cin:
            raise ValueError(
                f"weights expect cin/groups={cin_pg} with groups={groups}, "
                f"input has cin={cin}")
        dtype_bytes = resolve_dtype_bytes(dtype_bytes)
        h_out = (h + 2 * pad - kh) // stride + 1
        cout_pg = cout // groups
        if tile_cout is None:
            tile_cout = cout_pg
        if tile_go is None:
            wp = w + 2 * pad
            row_bytes = wp * cin_pg * dtype_bytes
            tile_go = max(1, min(
                h_out, (vmem_budget // max(row_bytes, 1) - kh)
                // max(stride, 1) + 1))
        return WeightGradPlan(
            n=n, h=h, w=w, cin=cin, cout=cout, kh=kh, kw=kw,
            stride=stride, pad=pad, groups=groups,
            dtype_bytes=dtype_bytes, tile_go=min(tile_go, h_out),
            tile_cout=min(tile_cout, cout_pg), vmem_budget=vmem_budget)

    # -- problem geometry --------------------------------------------------

    @property
    def cin_per_group(self) -> int:
        return self.cin // self.groups

    @property
    def cout_per_group(self) -> int:
        return self.cout // self.groups

    @property
    def h_out(self) -> int:
        return (self.h + 2 * self.pad - self.kh) // self.stride + 1

    @property
    def w_out(self) -> int:
        return (self.w + 2 * self.pad - self.kw) // self.stride + 1

    # -- strip geometry (DESIGN.md §2) -------------------------------------

    @property
    def th_out(self) -> int:
        """Output rows produced per strip."""
        return self.tile_h // self.stride

    @property
    def delta(self) -> int:
        """Top rows of the padded output that are sliced off."""
        return (self.kh - 1) // self.stride

    @property
    def row_offset(self) -> int:
        """Static in-window row offset ``(KH-1) mod stride``."""
        return (self.kh - 1) % self.stride

    @property
    def g_tiles(self) -> int:
        """Number of input strips (grid steps along H)."""
        return math.ceil((self.h_out + self.delta) / self.th_out)

    @property
    def rows_padded(self) -> int:
        """Input rows after bottom padding to a whole number of strips."""
        return self.g_tiles * self.tile_h

    @property
    def pad_bottom(self) -> int:
        """Bottom zero padding (negative: the input is cropped)."""
        return self.rows_padded - self.h - self.pad

    @property
    def wp(self) -> int:
        """Padded input width."""
        return self.w + 2 * self.pad

    @property
    def co_tiles(self) -> int:
        """C_out tiles per group (grid steps along C_out)."""
        return math.ceil(self.cout_per_group / self.tile_cout)

    @property
    def cout_padded_per_group(self) -> int:
        return self.co_tiles * self.tile_cout

    # -- pallas_call layout ------------------------------------------------

    @property
    def grid(self) -> tuple[int, int, int, int]:
        """(N, groups, strips, C_out tiles) — C_out innermost so a strip is
        fetched once and reused by every C_out tile (shared-IRB image)."""
        return (self.n, self.groups, self.g_tiles, self.co_tiles)

    @property
    def padded_input_shape(self) -> tuple[int, int, int, int]:
        return (self.n, self.rows_padded, self.wp, self.cin)

    @property
    def padded_weight_shape(self) -> tuple[int, int, int, int]:
        return (self.kh, self.kw, self.cin_per_group,
                self.groups * self.cout_padded_per_group)

    @property
    def padded_output_shape(self) -> tuple[int, int, int, int]:
        return (self.n, self.g_tiles * self.th_out, self.w_out,
                self.groups * self.cout_padded_per_group)

    @property
    def in_block(self) -> tuple[int, int, int, int]:
        return (1, self.tile_h, self.wp, self.cin_per_group)

    @property
    def w_block(self) -> tuple[int, int, int, int]:
        return (self.kh, self.kw, self.cin_per_group, self.tile_cout)

    @property
    def out_block(self) -> tuple[int, int, int, int]:
        return (1, self.th_out, self.w_out, self.tile_cout)

    @property
    def carry_shape(self) -> tuple[int, int, int]:
        """Shadow-register scratch: the K-1 boundary rows carried across
        strips (per group).  Only allocated by the ``"carry"`` dataflow."""
        return (max(self.kh - 1, 1), self.wp, self.cin_per_group)

    # -- halo dataflow layout (overlapping strips, no carry) ---------------

    @property
    def halo_in_block(self) -> tuple[int, int, int, int]:
        """Input window of one halo grid step: the strip *plus* its K-1
        predecessor rows, fetched through an overlapping BlockSpec."""
        return (1, self.tile_h + self.kh - 1, self.wp, self.cin_per_group)

    @property
    def halo_padded_input_shape(self) -> tuple[int, int, int, int]:
        """Padded input with K-1 extra zero rows on top so strip 0's
        overlapping window starts at element row 0."""
        return (self.n, self.kh - 1 + self.rows_padded, self.wp, self.cin)

    @property
    def vmem_resident_bytes(self) -> int:
        """Resident set of one grid step (window + carry + weights + acc).

        ``"carry"``: a ``tile_h`` strip plus the K-1 carry scratch.
        ``"halo"``: one overlapping window of ``tile_h + K - 1`` rows, no
        scratch — same working set to within one row (the ``max(K-1, 1)``
        floor of the scratch allocation).
        """
        db = self.dtype_bytes
        if self.dataflow == "halo":
            window = (self.tile_h + self.kh - 1) * self.wp \
                * self.cin_per_group * db
        else:
            strip = self.tile_h * self.wp * self.cin_per_group * db
            carry = self.carry_shape[0] * self.wp * self.cin_per_group * db
            window = strip + carry
        wtile = self.kh * self.kw * self.cin_per_group * self.tile_cout * db
        acc = self.th_out * self.w_out * self.tile_cout * 4   # fp32
        return window + wtile + acc

    # -- arithmetic --------------------------------------------------------

    @property
    def macs(self) -> int:
        return (self.n * self.h_out * self.w_out * self.cout
                * self.kh * self.kw * self.cin_per_group)

    @property
    def flops(self) -> int:
        return 2 * self.macs

    # -- analytical HBM traffic -------------------------------------------

    @property
    def traffic_mode(self) -> str:
        """The accounting mode this plan's dataflow actually pays:
        ``"carry"`` moves the ``"3dtrim"`` bytes, ``"halo"`` the
        ``"trim"`` bytes."""
        return "3dtrim" if self.dataflow == "carry" else "trim"

    def halo_rows(self, mode: str | None = None) -> int:
        """Input rows re-fetched from HBM across one (N, group) sweep.

        ``"3dtrim"``: the K-1 boundary rows live in the VMEM carry scratch
        — zero halo.  ``"trim"``: every strip after the first re-fetches
        its K-1 predecessor rows, the overhead of Fig. 1 at strip level.
        ``None`` uses the plan's own ``dataflow`` accounting.
        """
        mode = self.traffic_mode if mode is None else mode
        if mode == "3dtrim":
            return 0
        if mode == "trim":
            return (self.g_tiles - 1) * (self.kh - 1)
        raise ValueError(f"unknown mode {mode!r}")

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """Analytical HBM bytes moved by the kernel's schedule.

        ``input`` in ``"3dtrim"`` mode equals exactly the padded-input
        array size (each strip fetched once, shared by all C_out tiles);
        ``weights`` are re-streamed once per strip; ``output`` counts the
        useful (un-padded) result.  ``mode=None`` accounts the plan's own
        ``dataflow`` (carry -> "3dtrim", halo -> "trim").
        """
        db = self.dtype_bytes
        halo = self.halo_rows(mode)
        in_bytes = self.n * (self.rows_padded + halo) * self.wp \
            * self.cin * db
        w_bytes = (self.kh * self.kw * self.cin_per_group * self.cout
                   * db * self.g_tiles)
        out_bytes = self.n * self.h_out * self.w_out * self.cout * db
        return dict(input=in_bytes, weights=w_bytes, output=out_bytes,
                    total=in_bytes + w_bytes + out_bytes,
                    overhead_pct=100.0 * halo / max(self.rows_padded, 1))

    def arithmetic_intensity(self, mode: str | None = None) -> float:
        """FLOPs per HBM byte — the roofline x-coordinate.  ``mode=None``
        uses the plan's own ``dataflow`` accounting."""
        return self.flops / max(self.hbm_bytes(mode)["total"], 1)

    def as_dict(self) -> dict:
        t = self.hbm_bytes()
        return dict(grid=self.grid, tile_h=self.tile_h,
                    tile_cout=self.tile_cout, dataflow=self.dataflow,
                    th_out=self.th_out,
                    g_tiles=self.g_tiles, co_tiles=self.co_tiles,
                    carry_shape=self.carry_shape,
                    vmem_resident_bytes=self.vmem_resident_bytes,
                    flops=self.flops, hbm_total=t["total"],
                    arithmetic_intensity=self.arithmetic_intensity())


# ---------------------------------------------------------------------------
# Backward geometry (DESIGN.md §5)
# ---------------------------------------------------------------------------

def input_grad_geometry(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
                        groups: int = 1) -> dict:
    """Geometry of the input-gradient conv for one forward problem.

    The input cotangent of ``y = conv(x, w, stride, pad)`` is itself a
    *stride-1, valid* convolution:

        dx = conv(dilate_s(dy) edge-padded by K-1-pad, flip_hw(w)^T)

    where the bottom/right padding carries ``(dim + 2*pad - K) % stride``
    extra zeros so the result lands exactly back on ``x``'s shape.
    Requires ``pad <= K-1`` on both axes (true for 'same' and 'valid').

    Returns a dict with the dilated cotangent shape (``g_dilated_shape``),
    the padded conv input (``g_padded_shape``), the per-axis pad tuples
    (``pad_h``/``pad_w``) and the transposed weight shape (``wt_shape``
    = ``(KH, KW, Cout/groups, Cin)``).
    """
    n, h, w, cin = x_shape
    kh, kw, cin_pg, cout = w_shape
    if cin_pg * groups != cin:
        raise ValueError(
            f"weights expect cin/groups={cin_pg} with groups={groups}, "
            f"input has cin={cin}")
    if pad > kh - 1 or pad > kw - 1:
        raise ValueError(
            f"input-grad conv requires pad <= K-1, got pad={pad} "
            f"for K=({kh}, {kw})")
    s = stride
    h_out = (h + 2 * pad - kh) // s + 1
    w_out = (w + 2 * pad - kw) // s + 1
    hd = (h_out - 1) * s + 1
    wd = (w_out - 1) * s + 1
    r_h = (h + 2 * pad - kh) % s
    r_w = (w + 2 * pad - kw) % s
    pad_h = (kh - 1 - pad, kh - 1 - pad + r_h)
    pad_w = (kw - 1 - pad, kw - 1 - pad + r_w)
    return dict(
        h_out=h_out, w_out=w_out, stride=s,
        g_dilated_shape=(n, hd, wd, cout),
        g_padded_shape=(n, hd + sum(pad_h), wd + sum(pad_w), cout),
        pad_h=pad_h, pad_w=pad_w,
        wt_shape=(kh, kw, cout // groups, cin),
    )


@dataclass(frozen=True)
class WeightGradPlan:
    """Geometry + traffic plan for one weight-gradient conv.

    The weight cotangent contracts the *spatial* axes:

        dw[ki, kj, ci, co] = sum_{n, oy, ox}
            x_pad[n, oy*s + ki, ox*s + kj, ci] * dy[n, oy, ox, co]

    The kernel schedule (``kernels/trim_conv2d.trim_conv2d_weight_grad``)
    keeps ``tile_go`` cotangent rows resident per grid step together with
    their overlapping ifmap window of ``(tile_go-1)*s + KH`` rows (a
    halo-style fetch — successive windows share ``KH - s`` rows), runs the
    K x K taps as dense MXU matmuls ``(Cin/g, TGo*W_out) x (TGo*W_out,
    TCout)``, and accumulates into a weight-shaped fp32 output block
    revisited across the sequential (batch, strip) sweep — the
    shadow-register idea applied to a weight-stationary drain.

    All fields describe the FORWARD problem (``h``/``w`` already include
    any 'same' pre-padding folded by the caller; ``pad`` is the residual
    symmetric padding, normally 0).
    """

    n: int
    h: int
    w: int
    cin: int
    cout: int
    kh: int
    kw: int
    stride: int = 1
    pad: int = 0
    groups: int = 1
    dtype_bytes: int = 4
    tile_go: int = 8           # cotangent rows resident per grid step
    tile_cout: int = 128       # C_out tile per grid step (per group)
    vmem_budget: int = STRIP_VMEM_BUDGET

    def __post_init__(self):
        if self.cin % self.groups or self.cout % self.groups:
            raise ValueError(
                f"groups={self.groups} must divide cin={self.cin} and "
                f"cout={self.cout}")
        if self.tile_go < 1:
            raise ValueError(f"tile_go={self.tile_go} must be >= 1")
        if self.h_out < 1 or self.w_out < 1:
            raise ValueError("empty output: input smaller than kernel")
        # same canonical clamp as ConvPlan.tile_h: a cotangent strip
        # taller than the whole cotangent is the full-height strip
        if self.tile_go > self.h_out:
            object.__setattr__(self, "tile_go", self.h_out)

    # -- problem geometry --------------------------------------------------

    @property
    def cin_per_group(self) -> int:
        return self.cin // self.groups

    @property
    def cout_per_group(self) -> int:
        return self.cout // self.groups

    @property
    def h_out(self) -> int:
        """Cotangent rows (the forward output height)."""
        return (self.h + 2 * self.pad - self.kh) // self.stride + 1

    @property
    def w_out(self) -> int:
        return (self.w + 2 * self.pad - self.kw) // self.stride + 1

    @property
    def wp(self) -> int:
        """Padded ifmap width (as the forward kernel sees it)."""
        return self.w + 2 * self.pad

    # -- strip geometry ----------------------------------------------------

    @property
    def go_tiles(self) -> int:
        """Cotangent strips (grid steps along the output-row axis)."""
        return math.ceil(self.h_out / self.tile_go)

    @property
    def go_rows_padded(self) -> int:
        return self.go_tiles * self.tile_go

    @property
    def window_rows(self) -> int:
        """Ifmap rows resident per grid step (overlapping halo window)."""
        return (self.tile_go - 1) * self.stride + self.kh

    @property
    def x_rows_padded(self) -> int:
        """Ifmap rows after bottom zero-padding so the last strip's
        window is in bounds (padded rows only ever meet zero cotangent
        rows, so they contribute nothing)."""
        return (self.go_rows_padded - 1) * self.stride + self.kh

    @property
    def co_tiles(self) -> int:
        return math.ceil(self.cout_per_group / self.tile_cout)

    @property
    def cout_padded_per_group(self) -> int:
        return self.co_tiles * self.tile_cout

    # -- pallas_call layout ------------------------------------------------

    @property
    def grid(self) -> tuple[int, int, int, int]:
        """(groups, C_out tiles, N, strips) — (N, strip) innermost so the
        revisited weight-shaped output block sees its whole accumulation
        sweep on consecutive grid steps."""
        return (self.groups, self.co_tiles, self.n, self.go_tiles)

    @property
    def padded_x_shape(self) -> tuple[int, int, int, int]:
        return (self.n, self.x_rows_padded, self.wp, self.cin)

    @property
    def padded_g_shape(self) -> tuple[int, int, int, int]:
        return (self.n, self.go_rows_padded, self.w_out,
                self.groups * self.cout_padded_per_group)

    @property
    def x_block(self) -> tuple[int, int, int, int]:
        """Unblocked (element-offset) window: the strip's cotangent rows'
        receptive field."""
        return (1, self.window_rows, self.wp, self.cin_per_group)

    @property
    def g_block(self) -> tuple[int, int, int, int]:
        return (1, self.tile_go, self.w_out, self.tile_cout)

    @property
    def out_block(self) -> tuple[int, int, int, int]:
        return (self.kh, self.kw, self.cin_per_group, self.tile_cout)

    @property
    def padded_out_shape(self) -> tuple[int, int, int, int]:
        return (self.kh, self.kw, self.cin_per_group,
                self.groups * self.cout_padded_per_group)

    @property
    def vmem_resident_bytes(self) -> int:
        """Resident set of one grid step: ifmap window + cotangent strip
        + the fp32 weight-shaped accumulator block."""
        db = self.dtype_bytes
        window = self.window_rows * self.wp * self.cin_per_group * db
        gstrip = self.tile_go * self.w_out * self.tile_cout * db
        acc = self.kh * self.kw * self.cin_per_group * self.tile_cout * 4
        return window + gstrip + acc

    # -- arithmetic / analytical HBM traffic --------------------------------

    @property
    def macs(self) -> int:
        """Same MAC count as the forward conv (each forward MAC has
        exactly one weight-grad image)."""
        return (self.n * self.h_out * self.w_out * self.cout
                * self.kh * self.kw * self.cin_per_group)

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """Analytical HBM bytes of the kernel's schedule.  The ifmap is
        streamed window-by-window — successive windows overlap by
        ``KH - stride`` rows (the halo this schedule pays) — and the whole
        sweep repeats per C_out tile; the cotangent is read once per
        C_out-tile sweep; the output is the padded weight block written
        once.  ``mode`` is accepted for interface parity with
        :class:`ConvPlan` (the schedule is fixed)."""
        db = self.dtype_bytes
        in_bytes = (self.n * self.go_tiles * self.window_rows * self.wp
                    * self.cin * db * self.co_tiles)
        # each (group, co) sweep reads only its own cotangent channel
        # slice, so the full padded cotangent moves exactly once
        g_bytes = (self.n * self.go_rows_padded * self.w_out
                   * self.groups * self.cout_padded_per_group * db)
        out_bytes = self.kh * self.kw * self.cin_per_group \
            * self.groups * self.cout_padded_per_group * 4
        ideal = self.n * self.x_rows_padded * self.wp * self.cin * db
        return dict(input=in_bytes, weights=g_bytes, output=out_bytes,
                    total=in_bytes + g_bytes + out_bytes,
                    overhead_pct=100.0 * max(in_bytes - ideal, 0)
                    / max(ideal, 1))

    def arithmetic_intensity(self, mode: str | None = None) -> float:
        return self.flops / max(self.hbm_bytes(mode)["total"], 1)

    def as_dict(self) -> dict:
        t = self.hbm_bytes()
        return dict(grid=self.grid, tile_go=self.tile_go,
                    tile_cout=self.tile_cout, go_tiles=self.go_tiles,
                    co_tiles=self.co_tiles, window_rows=self.window_rows,
                    vmem_resident_bytes=self.vmem_resident_bytes,
                    flops=self.flops, hbm_total=t["total"],
                    arithmetic_intensity=self.arithmetic_intensity())


# ---------------------------------------------------------------------------
# 1D plan (depthwise causal conv — Mamba / RG-LRU temporal mixing)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Conv1dPlan:
    """Plan for the depthwise causal conv1d kernel: chunks of ``tile_l``
    timesteps with a ``K-1`` carry, channel axis tiled for the VPU lanes."""

    b: int
    length: int
    d: int
    k: int
    dtype_bytes: int = 4
    tile_l: int = 512
    tile_d: int = 1024

    @classmethod
    def build(cls, x_shape, w_shape, *, dtype_bytes: int = 4,
              tile_l: int | None = None,
              tile_d: int | None = None) -> "Conv1dPlan":
        b, length, d = x_shape
        k, _ = w_shape
        dtype_bytes = resolve_dtype_bytes(dtype_bytes)
        if tile_l is None:
            tile_l = min(length, 512)
        if tile_d is None:
            tile_d = min(d, 1024 if d % 128 == 0 else d)
        return cls(b=b, length=length, d=d, k=k, dtype_bytes=dtype_bytes,
                   tile_l=tile_l, tile_d=tile_d)

    @property
    def g_tiles(self) -> int:
        return math.ceil(self.length / self.tile_l)

    @property
    def d_tiles(self) -> int:
        return math.ceil(self.d / self.tile_d)

    @property
    def length_padded(self) -> int:
        return self.g_tiles * self.tile_l

    @property
    def grid(self) -> tuple[int, int, int]:
        """(B, channel tiles, chunks) — chunks innermost so the carry is
        valid within one (batch, channel) sweep."""
        return (self.b, self.d_tiles, self.g_tiles)

    @property
    def padded_input_shape(self) -> tuple[int, int, int]:
        return (self.b, self.length_padded, self.d)

    @property
    def in_block(self) -> tuple[int, int, int]:
        return (1, self.tile_l, self.tile_d)

    @property
    def w_block(self) -> tuple[int, int]:
        return (self.k, self.tile_d)

    @property
    def carry_shape(self) -> tuple[int, int]:
        return (max(self.k - 1, 1), self.tile_d)

    @property
    def flops(self) -> int:
        return 2 * self.b * self.length * self.d * self.k

    def hbm_bytes(self, mode: str = "3dtrim") -> dict:
        db = self.dtype_bytes
        if mode == "3dtrim":
            halo = 0
        elif mode == "trim":
            halo = self.b * self.d * (self.g_tiles - 1) * (self.k - 1)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        in_bytes = (self.b * self.length_padded * self.d + halo) * db
        w_bytes = self.k * self.d * db * self.b * self.g_tiles
        out_bytes = self.b * self.length * self.d * db
        return dict(input=in_bytes, weights=w_bytes, output=out_bytes,
                    total=in_bytes + w_bytes + out_bytes)

    def arithmetic_intensity(self, mode: str = "3dtrim") -> float:
        return self.flops / max(self.hbm_bytes(mode)["total"], 1)
