"""Whole-network execution + evaluation planning (DESIGN.md §7).

Everything below `ConvPlan` models ONE convolution at a time.  The
paper's headline claim, however, is a *network-level* number: 3D-TrIM
delivers up to 3.37x more operations per memory access (Ops/MAcc) than
TrIM on full CNN topologies like VGG-16 and AlexNet (arXiv:2502.18983
SV; the per-layer accounting follows TrIM's analytical-modelling
companion paper, arXiv:2408.01254).  This module chains the per-layer
plans into that network view:

* :class:`LayerStep` — one conv layer of a topology: its
  :class:`~repro.core.conv_plan.ConvPlan` (or
  :class:`~repro.core.conv_shard.ShardedConvPlan` when the network is
  sharded over a device mesh) plus the *inter-layer* decisions that the
  single-layer plan cannot see: whether the ifmap arrives from on-chip
  residency instead of HBM, whether the (pooled) ofmap stays on-chip
  for the next layer, and the pooling factor folded into the epilogue.

* :class:`NetworkPlan` — the chained topology.  It decides inter-layer
  residency (``residency="auto"``: an ofmap stays on-chip iff the
  pooled activation fits the residency budget; ``"never"`` /
  ``"always"`` override), aggregates whole-network HBM traffic, MACs
  and the paper's Ops/MAcc metric for ``mode="trim"`` vs ``"3dtrim"``,
  and carries the cross-device halo terms of sharded plans as a
  separate wire-traffic column.

* :func:`network_layers` / :func:`scale_layers` / :func:`infer_pools`
  — topology helpers shared with the execution path
  (``models/layers.py cnn_*_from_layers``) and the benchmarks.

Counting conventions (DESIGN.md §7, tying back to §1): the Ops/MAcc
denominator counts **ifmap reads + weight reads** in elements
(accesses = bytes / dtype_bytes); output writes and psums are excluded,
exactly as in the paper's metric.  One OP = one multiply or add
(MAC = 2 OPs).  Residency and pooling folding therefore change the
HBM *traffic* totals and the input side of Ops/MAcc, never the OPs.

`autotune.tune_network` tunes every layer of a topology in one sweep so
the execution engine (``examples/cnn_inference.py --net vgg16``) runs
the whole forward pass on tuned, packed plans.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from dataclasses import replace as _dc_replace

from repro.core import roofline
from repro.core.conv_plan import STRIP_VMEM_BUDGET, ConvPlan
from repro.core.conv_shard import ShardedConvPlan
from repro.core.model import (ConvLayer, GraphNode, alexnet_layers,
                              mobilenet_layers, resnet18_graph, unet_graph,
                              vgg16_layers)

NETWORKS = {"vgg16": vgg16_layers, "alexnet": alexnet_layers,
            "mobilenet": mobilenet_layers}

# DAG topologies: name -> builder returning list[GraphNode] (topological
# order).  Linear chains from NETWORKS are also valid NetworkGraph inputs
# via linear_graph_nodes().
GRAPHS = {"resnet18": resnet18_graph, "unet": unet_graph}

# Default budget for keeping an inter-layer activation on chip: the same
# half-VMEM budget ConvPlan uses for its resident strip — the other half
# of the core is already committed to the consumer's working set.
RESIDENCY_BUDGET = STRIP_VMEM_BUDGET


def network_layers(network) -> list[ConvLayer]:
    """Resolve a topology: a name from :data:`NETWORKS` ("vgg16",
    "alexnet", "mobilenet") or an explicit ``list[ConvLayer]`` passed
    through unchanged."""
    if isinstance(network, str):
        if network not in NETWORKS:
            raise ValueError(
                f"unknown network {network!r}; have {sorted(NETWORKS)}")
        return NETWORKS[network]()
    return list(network)


def scale_layers(layers, scale: int) -> list[ConvLayer]:
    """Shrink a topology's channel counts by ``scale`` (spatial dims and
    kernels unchanged) — the reduced configuration the CPU examples
    execute while the accounting uses the full-scale plans.  The first
    layer's input channels (the image) are kept; grouped layers keep
    ``groups == channels`` (depthwise stays depthwise)."""
    if scale <= 1:
        return list(layers)
    out: list[ConvLayer] = []
    prev_out: int | None = None
    for l in layers:
        cin = l.in_channels if prev_out is None else prev_out
        cout = max(1, l.out_channels // scale)
        if l.groups == l.in_channels and l.groups > 1:
            groups = cin                 # depthwise stays depthwise
        else:
            groups = math.gcd(l.groups, cin)   # must still divide cin
        if groups > 1:
            cout = -(-cout // groups) * groups  # round up to a multiple
        out.append(ConvLayer(name=l.name, ifmap=l.ifmap, in_channels=cin,
                             out_channels=cout, kernel=l.kernel,
                             stride=l.stride, padding=l.padding,
                             groups=groups))
        prev_out = cout
    return out


class PoolInferenceError(ValueError):
    """Spatial dims at a chain boundary cannot be explained by a
    plausible max pool — only a strided/dilated conv join (or, for
    ``reason="upsample"``, an explicit upsampling node) could produce
    them.  Subclasses ``ValueError`` so existing chainability handling
    keeps working; carries the boundary as structured fields so callers
    (and the unet wiring this was found on) can report *which* edge is
    miswired instead of silently planning a different network."""

    #: largest pool stride / window-overhang infer_pools will accept as a
    #: genuine pool rather than a disguised strided join.  Every real
    #: topology boundary in the zoo is within (VGG 2x2/s2, AlexNet
    #: 3x3/s2, sub-2x 3x3/s1, ResNet/U-Net 2x2/s2).
    MAX_STRIDE = 4
    MAX_OVERHANG = 2

    def __init__(self, msg: str, *, producer: str, consumer: str,
                 out_size: int, in_size: int, reason: str,
                 stride: int | None = None, window: int | None = None):
        super().__init__(msg)
        self.producer = producer
        self.consumer = consumer
        self.out_size = out_size
        self.in_size = in_size
        self.reason = reason
        self.stride = stride
        self.window = window


def pool_between(layer: ConvLayer, nxt: ConvLayer) -> tuple[int, int]:
    """Pooling ``(stride, window)`` between two consecutive conv layers,
    inferred from the topology's spatial dims: ``stride = out // next_in``
    and ``window = out - stride * (next_in - 1)`` — this recovers VGG's
    2x2/s2 and AlexNet's overlapping 3x3/s2 max pooling exactly.
    ``(1, 1)`` means no pooling at this boundary; a sub-2x boundary
    (e.g. 5 -> 3) resolves to a genuine stride-1 overlapping pool.

    Raises :class:`PoolInferenceError` when the dims admit no plausible
    pool: a growing boundary (``out < in`` — only an upsampling join
    explains it) or one whose inferred stride/window exceed the
    :attr:`PoolInferenceError.MAX_STRIDE` /
    ``stride + MAX_OVERHANG`` plausibility caps (only a strided or
    dilated join explains it).  Any ``o >= i`` pair *can* be written as
    ``(s, w) = (o // i, o - s*(i-1))``, so without the caps a miswired
    edge would silently plan a wildly subsampling "pool" that the
    topology never contained."""
    o, i = layer.out_size, nxt.ifmap
    if o == i:
        return 1, 1
    if o < i:
        raise PoolInferenceError(
            f"layer {layer.name} ofmap {o} smaller than {nxt.name} "
            f"ifmap {i}: not a chainable topology (only an upsampling "
            f"join can explain these dims — add an explicit 'upsample' "
            f"GraphNode)",
            producer=layer.name, consumer=nxt.name, out_size=o, in_size=i,
            reason="upsample")
    s = o // i
    w = o - s * (i - 1)
    if s > PoolInferenceError.MAX_STRIDE \
            or w > s + PoolInferenceError.MAX_OVERHANG:
        raise PoolInferenceError(
            f"boundary {layer.name}({o}) -> {nxt.name}({i}) implies a "
            f"{w}x{w}/s{s} pool — beyond the plausibility caps "
            f"(stride <= {PoolInferenceError.MAX_STRIDE}, window <= "
            f"stride + {PoolInferenceError.MAX_OVERHANG}); only a "
            f"strided or dilated conv join can explain these dims",
            producer=layer.name, consumer=nxt.name, out_size=o, in_size=i,
            reason="strided-join", stride=s, window=w)
    assert pooled_out_size(o, s, w) == i, (o, i, s, w)
    return s, w


def infer_pools(layers) -> list[tuple[int, int]]:
    """Per-layer pooling ``(stride, window)`` list (last layer: (1, 1))."""
    out = [pool_between(a, b) for a, b in zip(layers, layers[1:])]
    return out + [(1, 1)]


def pooled_out_size(h_out: int, stride: int, window: int) -> int:
    """Spatial size after the (stride, window) max pool — the single
    place the pooled-size rule lives (LayerStep.out_size and the
    residency decision in NetworkPlan.build both read it).  ``(1, 1)``
    is the no-pool identity; ``(1, window > 1)`` is a genuine stride-1
    overlapping pool (a sub-2x boundary like 5 -> 3 via 3x3/s1)."""
    if stride == 1 and window == 1:
        return h_out
    return (h_out - window) // stride + 1


def layer_kernel_problem(layer: ConvLayer, *, n: int = 1):
    """The conv problem ``ops.conv2d`` actually executes for one
    topology layer: ``(x_shape, pad, w_shape, padding)`` with
    ``x_shape`` the kernel-seen input (the ``padding`` mode's pre-pad
    folded in), ``pad`` the residual symmetric padding (0) and
    ``padding`` the ``ops.conv2d`` argument (``"same"`` for
    ``layer.padding > 0``, else ``"valid"``).

    This is the single place the layer -> executed-problem mapping
    lives: ``autotune.tune_network`` keys its records over these shapes,
    ``NetworkPlan(use_autotune_cache=True)`` looks them up over the same
    shapes, and ``models/layers.py cnn_*_from_layers`` run the same
    ``padding`` mode — so records can never be written under one key and
    read under another.

    Raises ``ValueError`` when the layer's symmetric paper padding is
    not reproduced by that mode (executed output size would differ from
    ``layer.out_size``) — the execution engine supports
    'same'-equivalent or zero padding, and anything else must fail
    loudly instead of silently running a different network.
    """
    from repro.kernels.ops import kernel_input_shape
    padding = "same" if layer.padding else "valid"
    x_shape, pad = kernel_input_shape(
        (n, layer.ifmap, layer.ifmap, layer.in_channels), layer.kernel,
        layer.stride, padding)
    out = (x_shape[1] + 2 * pad - layer.kernel) // layer.stride + 1
    if out != layer.out_size:
        raise ValueError(
            f"layer {layer.name}: padding={layer.padding} is not "
            f"{padding!r}-equivalent (executed output {out} != planned "
            f"{layer.out_size}); the execution engine runs 'same' or "
            f"zero padding only")
    w_shape = (layer.kernel, layer.kernel,
               layer.in_channels // layer.groups, layer.out_channels)
    return x_shape, pad, w_shape, padding


# ---------------------------------------------------------------------------
# DAG topology helpers
# ---------------------------------------------------------------------------

def linear_graph_nodes(network) -> list[GraphNode]:
    """A linear topology (name or ``list[ConvLayer]``) as graph nodes:
    one conv node per layer, chained in order, with the inter-layer max
    pools folded onto each conv as its epilogue — exactly the view
    :class:`NetworkPlan` takes, so ``NetworkGraph.build`` on these nodes
    reduces to the chain plan (tested as a hypothesis invariant)."""
    layers = network_layers(network)
    pools = infer_pools(layers)
    nodes: list[GraphNode] = []
    prev: str | None = None
    for l, (ps, pw) in zip(layers, pools):
        nodes.append(GraphNode(l.name, "conv", (prev,) if prev else (),
                               l, pool=ps, pool_window=pw))
        prev = l.name
    return nodes


def graph_nodes(graph) -> list[GraphNode]:
    """Resolve a DAG topology: a name from :data:`GRAPHS` ("resnet18",
    "unet"), a name from :data:`NETWORKS` or an explicit
    ``list[ConvLayer]`` (converted by :func:`linear_graph_nodes`), or an
    explicit ``list[GraphNode]`` passed through unchanged."""
    if isinstance(graph, str):
        if graph in GRAPHS:
            return GRAPHS[graph]()
        if graph in NETWORKS:
            return linear_graph_nodes(graph)
        raise ValueError(f"unknown network {graph!r}; have "
                         f"{sorted(GRAPHS) + sorted(NETWORKS)}")
    nodes = list(graph)
    if nodes and isinstance(nodes[0], ConvLayer):
        return linear_graph_nodes(nodes)
    return nodes


def scale_graph(graph, scale: int) -> list[GraphNode]:
    """Channel-shrink a DAG topology by ``scale`` (spatial dims and
    kernels unchanged) — the graph analogue of :func:`scale_layers`.
    Channels are recomputed in topological order (concat sums its
    inputs, joins pass through), so add/concat joins stay consistent
    after scaling."""
    nodes = graph_nodes(graph)
    if scale <= 1:
        return nodes
    ch: dict[str, int] = {}
    out: list[GraphNode] = []
    for nd in nodes:
        if nd.op == "conv":
            l = nd.layer
            cin = ch[nd.inputs[0]] if nd.inputs else l.in_channels
            cout = max(1, l.out_channels // scale)
            if l.groups == l.in_channels and l.groups > 1:
                groups = cin                 # depthwise stays depthwise
            else:
                groups = math.gcd(l.groups, cin)
            if groups > 1:
                cout = -(-cout // groups) * groups
            out.append(_dc_replace(nd, layer=_dc_replace(
                l, in_channels=cin, out_channels=cout, groups=groups)))
            ch[nd.name] = cout
        else:
            out.append(nd)
            if nd.op == "concat":
                ch[nd.name] = sum(ch[s] for s in nd.inputs)
            else:
                ch[nd.name] = ch[nd.inputs[0]]
    return out


# ---------------------------------------------------------------------------
# One chained layer
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerStep:
    """One conv layer of a :class:`NetworkPlan`.

    ``plan`` is the single-layer :class:`ConvPlan` (or
    :class:`ShardedConvPlan`); the step adds the inter-layer decisions:

    * ``resident_in`` — the ifmap arrives from the previous layer's
      on-chip residency: its HBM input bytes (including any
      ``mode="trim"`` halo re-fetch) are not billed.
    * ``resident_out`` — the (pooled) ofmap stays on-chip as the next
      layer's ifmap: its HBM output bytes are not billed.
    * ``pool`` / ``pool_window`` — max-pooling folded into the epilogue;
      with ``fold_pooling`` the output bytes billed are the *pooled*
      activation (the elements the network actually keeps), else the
      full ofmap the plan writes.
    """

    index: int
    name: str
    layer: ConvLayer
    plan: ConvPlan
    pool: int = 1
    pool_window: int = 1
    resident_in: bool = False
    resident_out: bool = False
    fold_pooling: bool = True

    @property
    def out_size(self) -> int:
        """Spatial size of the (pooled) activation this step hands on."""
        return pooled_out_size(self.plan.h_out, self.pool,
                               self.pool_window)

    @property
    def out_elements(self) -> int:
        return self.plan.n * self.out_size ** 2 * self.plan.cout

    @property
    def out_bytes(self) -> int:
        """HBM bytes of the activation this step writes (0 if resident)."""
        if self.resident_out:
            return 0
        if self.fold_pooling:
            return self.out_elements * self.plan.dtype_bytes
        return self.plan.hbm_bytes()["output"]

    @property
    def macs(self) -> int:
        return self.plan.macs

    @property
    def ops(self) -> int:
        return 2 * self.macs

    @property
    def halo_bytes(self) -> int:
        """Cross-device halo-exchange bytes (sharded plans only) — wire
        traffic, kept out of the HBM Ops/MAcc denominator."""
        if isinstance(self.plan, ShardedConvPlan):
            return self.plan.halo_bytes_oneway
        return 0

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """This step's HBM byte terms under the network's residency and
        pooling decisions.  ``mode`` follows :meth:`ConvPlan.hbm_bytes`
        (``None`` accounts the plan's own dataflow)."""
        t = self.plan.hbm_bytes(mode)
        inp = 0 if self.resident_in else t["input"]
        out = self.out_bytes
        return dict(input=inp, weights=t["weights"], output=out,
                    halo=self.halo_bytes,
                    total=inp + t["weights"] + out)

    def accesses(self, mode: str | None = None) -> int:
        """Paper-metric memory accesses: ifmap + weight reads, in
        elements (DESIGN.md §1/§7 — output writes and psums excluded)."""
        t = self.hbm_bytes(mode)
        return (t["input"] + t["weights"]) // self.plan.dtype_bytes

    def ops_per_macc(self, mode: str | None = None) -> float:
        """Operations per memory access of this layer (paper metric)."""
        return self.ops / max(self.accesses(mode), 1)


# ---------------------------------------------------------------------------
# The chained network
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NetworkPlan:
    """Per-layer ConvPlans chained across a full CNN topology.

    Build with :meth:`build`; every aggregate below is a pure function
    of the per-layer plans plus the residency/pooling decisions, so the
    network numbers printed by ``benchmarks/paper_eval.py`` are exactly
    the sums of the plans the kernels execute.

    Example (doctested by the README quickstart)::

        plan = NetworkPlan.build("vgg16")
        plan.ops_per_macc("3dtrim") / plan.ops_per_macc("trim")  # > 1
    """

    name: str
    steps: tuple
    residency: str = "auto"

    @classmethod
    def build(cls, network="vgg16", *, n: int = 1,
              dtype_bytes: int | None = None,
              dataflow: str = "carry", residency: str = "auto",
              residency_budget: int = RESIDENCY_BUDGET,
              fold_pooling: bool = True,
              batch_shards: int = 1, spatial_shards: int = 1,
              use_autotune_cache: bool = False, dtype: str = "float32",
              backend: str | None = None) -> "NetworkPlan":
        """Plan a whole topology.

        ``network`` is a name ("vgg16" | "alexnet" | "mobilenet") or an
        explicit ``list[ConvLayer]``.  ``residency`` decides inter-layer
        on-chip chaining: ``"auto"`` keeps an ofmap resident iff its
        pooled activation fits ``residency_budget``; ``"never"`` spills
        every boundary (whole-network traffic then reduces exactly to
        the sum of the per-layer plans when ``fold_pooling=False``);
        ``"always"`` forces every interior boundary resident.  With
        ``batch_shards``/``spatial_shards`` every layer is planned as a
        :class:`ShardedConvPlan` and the cross-device halo bytes ride
        along as a separate wire-traffic term.  With
        ``use_autotune_cache=True`` each layer's tile/dataflow knobs are
        filled from the persisted autotune records
        (:func:`repro.core.autotune.tune_network` writes them).
        """
        if residency not in ("auto", "never", "always"):
            raise ValueError(f"residency={residency!r} must be "
                             "'auto', 'never' or 'always'")
        if dtype_bytes is None:
            dtype_bytes = roofline.dtype_width(dtype)
        layers = network_layers(network)
        if not layers:
            raise ValueError("empty topology")
        for a, b in zip(layers, layers[1:]):
            if a.out_channels != b.in_channels:
                raise ValueError(
                    f"layer {a.name} ofmap channels {a.out_channels} != "
                    f"{b.name} ifmap channels {b.in_channels}")
        pools = infer_pools(layers)
        plans = [_plan_layer(layer, n=n, dtype_bytes=dtype_bytes,
                             dataflow=dataflow,
                             use_autotune_cache=use_autotune_cache,
                             dtype=dtype, backend=backend,
                             batch_shards=batch_shards,
                             spatial_shards=spatial_shards)
                 for layer in layers]

        steps = []
        last = len(layers) - 1
        for i, (layer, plan, (ps, pw)) in enumerate(
                zip(layers, plans, pools)):
            pooled_bytes = (n * pooled_out_size(plan.h_out, ps, pw) ** 2
                            * plan.cout * dtype_bytes)
            if i == last:
                keep = False            # the result leaves the accelerator
            elif residency == "never":
                keep = False
            elif residency == "always":
                keep = True
            else:
                keep = pooled_bytes <= residency_budget
            steps.append(LayerStep(
                index=i, name=layer.name, layer=layer, plan=plan,
                pool=ps, pool_window=pw,
                resident_in=bool(steps) and steps[-1].resident_out,
                resident_out=keep, fold_pooling=fold_pooling))
        nm = network if isinstance(network, str) else "custom"
        return cls(name=nm, steps=tuple(steps), residency=residency)

    # -- aggregates --------------------------------------------------------

    @property
    def n_layers(self) -> int:
        return len(self.steps)

    @property
    def macs(self) -> int:
        return sum(s.macs for s in self.steps)

    @property
    def ops(self) -> int:
        return 2 * self.macs

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """Whole-network HBM byte terms (input / weights / output /
        total) plus the cross-device ``halo`` wire term, under the
        plan's residency and pooling decisions.  With
        ``residency="never"`` and ``fold_pooling=False`` the totals
        reduce exactly to the sum of the per-layer
        ``ConvPlan.hbm_bytes()`` (tested)."""
        tot = dict(input=0, weights=0, output=0, halo=0, total=0)
        for s in self.steps:
            t = s.hbm_bytes(mode)
            for k in tot:
                tot[k] += t.get(k, 0)
        return tot

    def accesses(self, mode: str | None = None) -> int:
        """Whole-network paper-metric accesses (ifmap + weight reads)."""
        return sum(s.accesses(mode) for s in self.steps)

    def ops_per_macc(self, mode: str | None = None) -> float:
        """The paper's network-level Ops/MAcc (arXiv:2502.18983 SV):
        total operations over total external reads."""
        return self.ops / max(self.accesses(mode), 1)

    def compare(self) -> dict:
        """The trim-vs-3dtrim comparison this subsystem exists for:
        per-layer and whole-network Ops/MAcc in both accounting modes
        with the 3dtrim/trim improvement ratio."""
        rows = []
        for s in self.steps:
            a3, at = s.ops_per_macc("3dtrim"), s.ops_per_macc("trim")
            rows.append(dict(
                layer=s.name, label=s.layer.label(), macs=s.macs,
                g_tiles=s.plan.g_tiles, dataflow=s.plan.dataflow,
                resident_in=s.resident_in, resident_out=s.resident_out,
                pool=s.pool,
                ops_per_macc_3dtrim=a3, ops_per_macc_trim=at,
                improvement=a3 / max(at, 1e-12)))
        n3, nt = self.ops_per_macc("3dtrim"), self.ops_per_macc("trim")
        return dict(
            network=self.name, residency=self.residency,
            layers=rows, macs=self.macs, ops=self.ops,
            ops_per_macc_3dtrim=n3, ops_per_macc_trim=nt,
            improvement=n3 / max(nt, 1e-12))

    def arch_compare(self, hw_a=None, hw_b=None) -> dict:
        """The paper's own §V network comparison: whole-network Ops/MAcc
        of the 3D-TrIM ASIC configuration vs the TrIM configuration,
        using the Fig. 6 architectural access model
        (:func:`repro.core.model.layer_accesses` — shadow registers,
        filter passes, kernel tiling and slice counts included).  This
        is the accounting that reproduces the claimed "up to 3.37x"
        per-layer improvements; :meth:`compare` is the TPU execution
        engine's strip-level image of the same tradeoff."""
        return arch_compare_steps(self.name, self.steps, hw_a, hw_b)

    def as_rows(self, mode: str | None = None) -> list[dict]:
        """Flat per-layer dict rows (the ``--json`` artifact shape)."""
        rows = []
        for s in self.steps:
            t = s.hbm_bytes(mode)
            rows.append(dict(
                layer=s.name, label=s.layer.label(),
                mode=mode or s.plan.traffic_mode,
                dataflow=s.plan.dataflow, macs=s.macs,
                hbm_input=t["input"], hbm_weights=t["weights"],
                hbm_output=t["output"], halo=t["halo"],
                hbm_total=t["total"],
                accesses=s.accesses(mode),
                ops_per_macc=s.ops_per_macc(mode),
                resident_in=s.resident_in,
                resident_out=s.resident_out, pool=s.pool))
        return rows


def arch_compare_steps(name: str, steps, hw_a=None, hw_b=None) -> dict:
    """The paper's §V architectural network comparison over any iterable
    of conv steps (``LayerStep``-shaped: ``.name`` + ``.layer``) — shared
    by :meth:`NetworkPlan.arch_compare` (linear chains) and
    :meth:`NetworkGraph.arch_compare` (DAGs, conv nodes only: joins do
    no MACs and the Fig. 6 access model has no term for them)."""
    from repro.core.model import TRIM, TRIM_3D, layer_accesses
    hw_a = TRIM_3D if hw_a is None else hw_a
    hw_b = TRIM if hw_b is None else hw_b
    steps = tuple(steps)
    rows, tot = [], {hw_a.name: 0, hw_b.name: 0}
    for s in steps:
        a = layer_accesses(s.layer, hw_a)
        b = layer_accesses(s.layer, hw_b)
        tot[hw_a.name] += a.total
        tot[hw_b.name] += b.total
        rows.append(dict(
            layer=s.name, label=s.layer.label(), ops=s.layer.ops,
            accesses={hw_a.name: a.total, hw_b.name: b.total},
            ops_per_macc={hw_a.name: a.ops_per_access,
                          hw_b.name: b.ops_per_access},
            ops_per_macc_per_slice={
                hw_a.name: a.ops_per_access_per_slice,
                hw_b.name: b.ops_per_access_per_slice},
            improvement=a.ops_per_access_per_slice
            / b.ops_per_access_per_slice))
    ops = sum(s.layer.ops for s in steps)
    net_a = ops / max(tot[hw_a.name], 1)
    net_b = ops / max(tot[hw_b.name], 1)
    return dict(
        network=name, layers=rows, ops=ops, accesses=tot,
        ops_per_macc={hw_a.name: net_a, hw_b.name: net_b},
        ops_per_macc_per_slice={hw_a.name: net_a / hw_a.slices,
                                hw_b.name: net_b / hw_b.slices},
        improvement=(net_a / hw_a.slices) / (net_b / hw_b.slices))


def _plan_layer(layer: ConvLayer, *, n: int, dtype_bytes: int,
                dataflow: str, use_autotune_cache: bool, dtype: str,
                backend: str | None, batch_shards: int = 1,
                spatial_shards: int = 1):
    """The single-layer plan for one topology layer — the one place
    :meth:`NetworkPlan.build` and :meth:`NetworkGraph.build` construct
    plans, so a graph's conv nodes are planned exactly like the chain's
    layers (the linear-reduction invariant depends on this)."""
    knobs = dict(tile_h=None, tile_cout=None, dataflow=dataflow)
    if use_autotune_cache:
        rec = _cached_knobs(layer, n=n, dtype=dtype, backend=backend,
                            batch_shards=batch_shards,
                            spatial_shards=spatial_shards)
        if rec is not None:
            knobs = dict(tile_h=rec["tile_h"], tile_cout=rec["tile_cout"],
                         dataflow=rec["dataflow"])
    x_shape = (n, layer.ifmap, layer.ifmap, layer.in_channels)
    w_shape = (layer.kernel, layer.kernel,
               layer.in_channels // layer.groups, layer.out_channels)
    build_kw = dict(stride=layer.stride, pad=layer.padding,
                    groups=layer.groups, dtype_bytes=dtype_bytes, **knobs)
    if batch_shards > 1 or spatial_shards > 1:
        return ShardedConvPlan.build(x_shape, w_shape,
                                     batch_shards=batch_shards,
                                     spatial_shards=spatial_shards,
                                     **build_kw)
    return ConvPlan.build(x_shape, w_shape, **build_kw)


# ---------------------------------------------------------------------------
# DAG network plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EdgeState:
    """One producer -> consumer edge of a :class:`NetworkGraph` with its
    residency verdict.

    ``bytes`` is the (pooled) activation the edge carries — the quantity
    the residency pass charges against the VMEM budget and the HBM bytes
    a join consumer re-reads when the edge is not resident (a *conv*
    consumer's re-fetch is billed through its own plan, which adds the
    ``mode="trim"`` halo re-reads on top).  ``boundaries`` is the
    half-open interval of topological boundaries ``[producer_pos,
    consumer_pos)`` the tensor occupies while resident — a skip edge
    spans many boundaries, which is exactly how residual liveness turns
    the per-boundary budget check into an interval-overlap problem."""

    producer: str
    consumer: str
    bytes: int
    resident: bool
    boundaries: tuple[int, int]

    @property
    def state(self) -> str:
        return "resident" if self.resident else "refetch"

    @property
    def span(self) -> int:
        return self.boundaries[1] - self.boundaries[0]

    @property
    def refetch_bytes(self) -> int:
        return 0 if self.resident else self.bytes


@dataclass(frozen=True)
class JoinStep:
    """One non-conv node (pool / add / concat / upsample) of a
    :class:`NetworkGraph`.  Joins perform no MACs — their whole cost is
    activation traffic: the in-edges they must re-read from HBM plus the
    output they spill.  The interface mirrors :class:`LayerStep` where
    the network aggregates need it (``macs`` / ``ops`` / ``hbm_bytes`` /
    ``accesses`` / ``halo_bytes``); ``plan`` is ``None`` so the roofline
    treats joins as memory-only work."""

    index: int
    name: str
    op: str
    n: int
    out_size: int
    channels: int
    dtype_bytes: int
    in_bytes: tuple
    resident_ins: tuple
    resident_out: bool

    plan = None          # no ConvPlan: memory-only node

    @property
    def resident_in(self) -> bool:
        """True iff every in-edge arrives from VMEM residency."""
        return all(self.resident_ins)

    @property
    def out_elements(self) -> int:
        return self.n * self.out_size ** 2 * self.channels

    @property
    def out_bytes(self) -> int:
        if self.resident_out:
            return 0
        return self.out_elements * self.dtype_bytes

    @property
    def macs(self) -> int:
        return 0

    @property
    def ops(self) -> int:
        return 0

    @property
    def halo_bytes(self) -> int:
        return 0

    def hbm_bytes(self, mode: str | None = None) -> dict:
        inp = sum(b for b, r in zip(self.in_bytes, self.resident_ins)
                  if not r)
        out = self.out_bytes
        return dict(input=inp, weights=0, output=out, halo=0,
                    total=inp + out)

    def accesses(self, mode: str | None = None) -> int:
        """Activation re-reads in elements — joins add to the Ops/MAcc
        denominator (a re-fetched skip ifmap is an ifmap read) without
        adding MACs, which is the honest cost of a spilled skip edge."""
        return self.hbm_bytes(mode)["input"] // self.dtype_bytes

    def ops_per_macc(self, mode: str | None = None) -> float:
        return 0.0

    def label(self) -> str:
        return f"[{self.op} {self.out_size}x{self.out_size}" \
               f"x{self.channels}]"


@dataclass(frozen=True)
class NetworkGraph:
    """A DAG topology planned for residency — the generalization of
    :class:`NetworkPlan` from chains to graphs (ResNet residual blocks,
    U-Net encoder-decoders).

    The residency pass decides **per edge** whether a producer's
    activation stays VMEM-resident until that consumer or is re-fetched
    from HBM.  A tensor with a resident edge to consumer position ``j``
    occupies every topological boundary in ``[producer, j)``, so skip
    edges extend liveness intervals and the half-VMEM budget check
    becomes interval overlap: at every boundary the resident tensors'
    bytes must sum within ``residency_budget``.  ``"auto"`` admits edges
    greedily in consumer order; ``"never"`` / ``"always"`` override.  A
    tensor is *spilled* (written to HBM) iff any of its consumer edges
    is non-resident or it is a network output.

    On a linear chain every edge spans exactly one boundary, each
    boundary holds one tensor, and the pass reduces exactly to
    :class:`NetworkPlan`'s per-boundary ``pooled_bytes <= budget`` rule
    (hypothesis-tested invariant).

    Aggregation reuses the chain machinery: conv nodes become
    :class:`LayerStep`s (same plans, built by the same helper), joins
    become :class:`JoinStep`s, and ``compare()`` / ``arch_compare()``
    report whole-network HBM bytes and Ops/MAcc in both accounting
    modes."""

    name: str
    nodes: tuple
    steps: tuple
    edges: tuple
    residency: str
    residency_budget: int

    @classmethod
    def build(cls, graph="resnet18", *, n: int = 1,
              dtype_bytes: int | None = None, dataflow: str = "carry",
              residency: str = "auto",
              residency_budget: int = RESIDENCY_BUDGET,
              fold_pooling: bool = True,
              use_autotune_cache: bool = False, dtype: str = "float32",
              backend: str | None = None) -> "NetworkGraph":
        """Plan a DAG topology.  ``graph`` is a name from
        :data:`GRAPHS` ("resnet18" | "unet"), a linear name from
        :data:`NETWORKS`, an explicit ``list[GraphNode]`` in topological
        order, or a ``list[ConvLayer]`` (converted to a chain graph).
        Graphs are planned single-device; shard grids stay on
        :class:`NetworkPlan`."""
        if residency not in ("auto", "never", "always"):
            raise ValueError(f"residency={residency!r} must be "
                             "'auto', 'never' or 'always'")
        if dtype_bytes is None:
            dtype_bytes = roofline.dtype_width(dtype)
        nodes = graph_nodes(graph)
        if not nodes:
            raise ValueError("empty topology")

        # -- validate topology, compute per-node (size, channels) ------
        pos: dict[str, int] = {}
        out_size: dict[str, int] = {}
        channels: dict[str, int] = {}
        sources = 0
        for i, nd in enumerate(nodes):
            if nd.name in pos:
                raise ValueError(f"duplicate node name {nd.name!r}")
            for src in nd.inputs:
                if src not in pos:
                    raise ValueError(
                        f"node {nd.name}: input {src!r} is not an "
                        f"earlier node — nodes must be topological")
            if nd.op == "conv":
                l = nd.layer
                if len(nd.inputs) > 1:
                    raise ValueError(
                        f"conv node {nd.name}: exactly one input")
                if nd.inputs:
                    src = nd.inputs[0]
                    if (out_size[src] != l.ifmap
                            or channels[src] != l.in_channels):
                        raise ValueError(
                            f"node {nd.name}: expects {l.ifmap}^2"
                            f"x{l.in_channels}, producer {src} hands "
                            f"{out_size[src]}^2x{channels[src]}")
                else:
                    sources += 1
                sz = pooled_out_size(l.out_size, nd.pool, nd.pool_window)
                chn = l.out_channels
            elif nd.op == "pool":
                (src,) = nd.inputs
                if nd.pool_window > out_size[src]:
                    raise ValueError(
                        f"pool {nd.name}: window {nd.pool_window} > "
                        f"input size {out_size[src]}")
                sz = pooled_out_size(out_size[src], nd.pool,
                                     nd.pool_window)
                chn = channels[src]
            elif nd.op == "upsample":
                (src,) = nd.inputs
                sz = out_size[src] * nd.scale
                chn = channels[src]
            else:                        # add / concat
                if len(nd.inputs) < 2:
                    raise ValueError(
                        f"{nd.op} node {nd.name}: needs >= 2 inputs")
                sizes = {out_size[s] for s in nd.inputs}
                if len(sizes) != 1:
                    raise ValueError(
                        f"node {nd.name}: mismatched spatial dims "
                        f"{sorted(sizes)}")
                sz = sizes.pop()
                chs = [channels[s] for s in nd.inputs]
                if nd.op == "add" and len(set(chs)) != 1:
                    raise ValueError(
                        f"add node {nd.name}: mismatched channels {chs}")
                chn = chs[0] if nd.op == "add" else sum(chs)
            pos[nd.name] = i
            out_size[nd.name] = sz
            channels[nd.name] = chn
        if sources != 1:
            raise ValueError(
                f"graph needs exactly one source conv node "
                f"(empty inputs), got {sources}")

        # -- per-conv plans (same helper the chain build uses) ---------
        plans = {nd.name: _plan_layer(nd.layer, n=n,
                                      dtype_bytes=dtype_bytes,
                                      dataflow=dataflow,
                                      use_autotune_cache=use_autotune_cache,
                                      dtype=dtype, backend=backend)
                 for nd in nodes if nd.op == "conv"}
        tensor_bytes = {nm: n * out_size[nm] ** 2 * channels[nm]
                        * dtype_bytes for nm in pos}

        # -- residency: greedy interval packing over boundaries --------
        edge_list: list[tuple[str, str]] = []
        seen = set()
        for nd in nodes:
            for src in nd.inputs:
                if (src, nd.name) not in seen:
                    seen.add((src, nd.name))
                    edge_list.append((src, nd.name))
        occ = [0] * max(len(nodes) - 1, 0)
        upto: dict[str, int] = {}
        res: dict[tuple[str, str], bool] = {}
        for prod, cons in sorted(edge_list,
                                 key=lambda e: (pos[e[1]], pos[e[0]])):
            b = tensor_bytes[prod]
            start = upto.get(prod, pos[prod])
            span = range(start, pos[cons])
            if residency == "never":
                keep = False
            elif residency == "always":
                keep = True
            else:
                keep = all(occ[k] + b <= residency_budget for k in span)
            if keep:
                if residency != "always":
                    for k in span:
                        occ[k] += b
                upto[prod] = max(start, pos[cons])
            res[(prod, cons)] = keep

        # -- steps ------------------------------------------------------
        consumers: dict[str, list[str]] = {nm: [] for nm in pos}
        for prod, cons in edge_list:
            consumers[prod].append(cons)
        steps: list = []
        for i, nd in enumerate(nodes):
            outs = consumers[nd.name]
            spilled = (not outs) or any(not res[(nd.name, c)]
                                        for c in outs)
            if nd.op == "conv":
                r_in = bool(nd.inputs) and res[(nd.inputs[0], nd.name)]
                steps.append(LayerStep(
                    index=i, name=nd.name, layer=nd.layer,
                    plan=plans[nd.name], pool=nd.pool,
                    pool_window=nd.pool_window, resident_in=r_in,
                    resident_out=not spilled, fold_pooling=fold_pooling))
            else:
                steps.append(JoinStep(
                    index=i, name=nd.name, op=nd.op, n=n,
                    out_size=out_size[nd.name],
                    channels=channels[nd.name], dtype_bytes=dtype_bytes,
                    in_bytes=tuple(tensor_bytes[s] for s in nd.inputs),
                    resident_ins=tuple(res[(s, nd.name)]
                                       for s in nd.inputs),
                    resident_out=not spilled))
        edges = tuple(EdgeState(
            producer=prod, consumer=cons, bytes=tensor_bytes[prod],
            resident=res[(prod, cons)],
            boundaries=(pos[prod], pos[cons]))
            for prod, cons in edge_list)
        nm = graph if isinstance(graph, str) else "custom"
        return cls(name=nm, nodes=tuple(nodes), steps=tuple(steps),
                   edges=edges, residency=residency,
                   residency_budget=residency_budget)

    # -- aggregates --------------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def conv_steps(self) -> tuple:
        return tuple(s for s in self.steps if isinstance(s, LayerStep))

    @property
    def macs(self) -> int:
        return sum(s.macs for s in self.steps)

    @property
    def ops(self) -> int:
        return 2 * self.macs

    @property
    def spilled_edge_bytes(self) -> int:
        """HBM bytes of the edges that re-fetch (reporting; the billed
        traffic rides inside the consumer steps)."""
        return sum(e.refetch_bytes for e in self.edges)

    def boundary_occupancy(self) -> list[int]:
        """Resident bytes held across each topological boundary — the
        liveness-interval view of the residency decisions (every entry
        is <= ``residency_budget`` under ``"auto"``; tested)."""
        occ = [0] * max(len(self.nodes) - 1, 0)
        pos = {nd.name: i for i, nd in enumerate(self.nodes)}
        upto: dict[str, int] = {}
        for e in sorted(self.edges,
                        key=lambda e: (pos[e.consumer], pos[e.producer])):
            if not e.resident:
                continue
            start = upto.get(e.producer, e.boundaries[0])
            for k in range(start, e.boundaries[1]):
                occ[k] += e.bytes
            upto[e.producer] = max(start, e.boundaries[1])
        return occ

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """Whole-network HBM byte terms under the graph's residency
        decisions.  With ``residency="never"`` and
        ``fold_pooling=False`` the conv terms reduce exactly to the sum
        of per-layer ``ConvPlan.hbm_bytes()`` plus the joins' activation
        traffic (tested)."""
        tot = dict(input=0, weights=0, output=0, halo=0, total=0)
        for s in self.steps:
            t = s.hbm_bytes(mode)
            for k in tot:
                tot[k] += t.get(k, 0)
        return tot

    def accesses(self, mode: str | None = None) -> int:
        """Whole-network paper-metric accesses: ifmap + weight reads,
        including join re-reads of spilled activations."""
        return sum(s.accesses(mode) for s in self.steps)

    def ops_per_macc(self, mode: str | None = None) -> float:
        return self.ops / max(self.accesses(mode), 1)

    def compare(self) -> dict:
        """trim-vs-3dtrim Ops/MAcc over the whole DAG: per-conv rows
        plus the network totals (join traffic in the denominator) and
        the edge-residency summary."""
        rows = []
        for s in self.conv_steps:
            a3, at = s.ops_per_macc("3dtrim"), s.ops_per_macc("trim")
            rows.append(dict(
                layer=s.name, label=s.layer.label(), macs=s.macs,
                g_tiles=s.plan.g_tiles, dataflow=s.plan.dataflow,
                resident_in=s.resident_in, resident_out=s.resident_out,
                pool=s.pool,
                ops_per_macc_3dtrim=a3, ops_per_macc_trim=at,
                improvement=a3 / max(at, 1e-12)))
        n3, nt = self.ops_per_macc("3dtrim"), self.ops_per_macc("trim")
        n_res = sum(1 for e in self.edges if e.resident)
        return dict(
            network=self.name, residency=self.residency,
            layers=rows, macs=self.macs, ops=self.ops,
            n_edges=len(self.edges), n_resident_edges=n_res,
            spilled_edge_bytes=self.spilled_edge_bytes,
            ops_per_macc_3dtrim=n3, ops_per_macc_trim=nt,
            improvement=n3 / max(nt, 1e-12))

    def arch_compare(self, hw_a=None, hw_b=None) -> dict:
        """The paper's §V architectural comparison over the graph's conv
        nodes (joins carry no MACs and no Fig. 6 term)."""
        return arch_compare_steps(self.name, self.conv_steps, hw_a, hw_b)

    def as_rows(self, mode: str | None = None) -> list[dict]:
        """Flat per-node dict rows (the ``--json`` artifact shape);
        join nodes report their op label and pure activation traffic."""
        rows = []
        for s in self.steps:
            t = s.hbm_bytes(mode)
            conv = isinstance(s, LayerStep)
            rows.append(dict(
                layer=s.name,
                label=s.layer.label() if conv else s.label(),
                mode=(mode or s.plan.traffic_mode) if conv else "-",
                dataflow=s.plan.dataflow if conv else "-",
                macs=s.macs,
                hbm_input=t["input"], hbm_weights=t["weights"],
                hbm_output=t["output"], halo=t["halo"],
                hbm_total=t["total"],
                accesses=s.accesses(mode),
                ops_per_macc=s.ops_per_macc(mode),
                resident_in=s.resident_in,
                resident_out=s.resident_out,
                pool=s.pool if conv else 1))
        return rows

    def edge_rows(self) -> list[dict]:
        """Per-edge residency rows (the ``--json`` "edge" kind)."""
        return [dict(producer=e.producer, consumer=e.consumer,
                     bytes=e.bytes, state=e.state, span=e.span,
                     boundaries=list(e.boundaries)) for e in self.edges]


def _cached_knobs(layer: ConvLayer, *, n: int, dtype: str,
                  backend: str | None, batch_shards: int,
                  spatial_shards: int) -> dict | None:
    """The autotune record for one topology layer, looked up under the
    same kernel-seen key ``ops.conv2d`` uses — derived by
    :func:`layer_kernel_problem`, the shared mapping ``tune_network``
    writes records with (the sharded namespace when a shard grid is
    given)."""
    from repro.core import autotune
    from repro.kernels.ops import MAX_NATIVE_K
    if layer.kernel > MAX_NATIVE_K:
        return None                      # kernel-tiled path: no cache
    try:
        x_shape, pad, w_shape, _ = layer_kernel_problem(layer, n=n)
    except ValueError:
        return None          # not executable as planned: nothing cached
    if batch_shards > 1 or spatial_shards > 1:
        return autotune.sharded_knobs_for(
            x_shape, w_shape, batch_shards=batch_shards,
            spatial_shards=spatial_shards, stride=layer.stride, pad=pad,
            groups=layer.groups, dtype=dtype, backend=backend)
    return autotune.knobs_for(x_shape, w_shape, stride=layer.stride,
                              pad=pad, groups=layer.groups, dtype=dtype,
                              backend=backend)
