"""Cycle-stepped functional simulator of the TrIM / 3D-TrIM dataflow.

This is the faithful-reproduction artifact for the paper's Figs. 3-5: a
K x K weight-stationary slice in which

  * activations are injected *vertically* into the rightmost PE column,
  * shift *horizontally* (right -> left) one PE per step,
  * and are re-injected *diagonally* from the Input Recycling Buffer (IRB)
    when the sliding-window band advances one row.

The IRB holds two structures (Fig. 4):

  * ``K-1`` shift registers — capture activations as they exit the leftmost
    PE column, and replay them one band later to the PE row above.  An
    activation at row-offset ``c`` only ever reaches column 0 if
    ``c <= W - K``, so the **last K-1 activations of every row never enter
    the shift registers**.
  * ``(K-1) x (K-1)`` shadow registers — the 3D-TrIM contribution: they
    capture exactly those end-of-row activations and replay them (and keep
    shifting them shadow-to-shadow for the next bands, Fig. 5).  In
    ``mode="trim"`` the shadow path is disabled and every end-of-row
    activation is **re-read from external memory**, reproducing TrIM's
    overhead (Fig. 1).

The simulator counts every external memory read and is validated against
both the analytical model (`core.conv_plan.slice_reads_per_channel`) and a
direct convolution oracle.

Functional timing note: real hardware staggers the K columns in time
(column j computes window ``x`` at cycle ``x + 2j``, psums flow top->bottom
through the product/psum registers of Fig. 3b).  The simulator advances one
*injection step* per cycle, in which every PE sees exactly the activation
the hardware would route to it; the per-PE value streams — and therefore
the memory-access counts — are identical to the staggered schedule.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.conv_plan import slice_reads_per_channel


@dataclass
class SliceStats:
    """Counters of the data movement in one slice pass."""

    memory_reads: int = 0          # external (off-chip) reads
    shift_reg_supplies: int = 0    # diagonal re-injections via shift registers
    shadow_supplies: int = 0       # diagonal re-injections via shadow registers
    horizontal_shifts: int = 0     # PE -> PE right-to-left moves
    macs: int = 0

    @property
    def ops(self) -> int:
        return 2 * self.macs

    @property
    def ops_per_memory_access(self) -> float:
        return self.ops / max(self.memory_reads, 1)


@dataclass
class StepSnapshot:
    """One injection step of the schedule — used to validate Fig. 5."""

    band: int
    step: int                      # injection index c within the band
    pe_values: np.ndarray          # (K, K) activation registers, NaN = empty
    sources: list                  # (row, source) for this step's injections
    shift_regs: list               # contents per reused row
    shadow_regs: list              # contents per reused row


class TrimSliceSim:
    """One K x K TrIM / 3D-TrIM slice, valid convolution, stride 1."""

    def __init__(self, kernel_size: int = 3, mode: str = "3dtrim",
                 record_trace: bool = False):
        if mode not in ("trim", "3dtrim"):
            raise ValueError(f"unknown mode {mode!r}")
        self.k = kernel_size
        self.mode = mode
        self.record_trace = record_trace
        self.trace: list[StepSnapshot] = []

    # -- injection source resolution ------------------------------------
    def _inject(self, band: int, row: int, c: int, ifmap: np.ndarray,
                shift_regs: list[dict], shadow_regs: list[dict],
                stats: SliceStats, sources: list) -> float:
        """Return activation ifmap[band + row, c], from the correct source."""
        k, w = self.k, ifmap.shape[1]
        value = ifmap[band + row, c]
        is_new_row = band == 0 or row == k - 1
        if is_new_row:
            stats.memory_reads += 1
            sources.append((row, "memory"))
            return value
        # Reused row: band>0, row < K-1.  Previous band saw this ifmap row
        # at row index row+1; its traversal filled shift/shadow registers.
        if c <= w - k:
            assert shift_regs[row].get(c) == value, "shift register miss"
            stats.shift_reg_supplies += 1
            sources.append((row, "shift"))
            return shift_regs[row].pop(c)
        # End-of-row activation (the last K-1 of the row).
        if self.mode == "3dtrim":
            assert shadow_regs[row].get(c) == value, "shadow register miss"
            stats.shadow_supplies += 1
            sources.append((row, "shadow"))
            return shadow_regs[row][c]
        stats.memory_reads += 1          # TrIM: re-read from memory
        sources.append((row, "memory-reread"))
        return value

    # -- main loop --------------------------------------------------------
    def run(self, ifmap: np.ndarray, weights: np.ndarray):
        """Convolve ``ifmap`` (H, W) with ``weights`` (K, K), stride 1, valid.

        Returns ``(output, stats)`` with output of shape (H-K+1, W-K+1).
        """
        k = self.k
        h, w = ifmap.shape
        assert weights.shape == (k, k)
        assert h >= k and w >= 2 * k, "ifmap too small for the IRB layout"
        out_h, out_w = h - k + 1, w - k + 1
        output = np.zeros((out_h, out_w), dtype=np.float64)
        stats = SliceStats()

        # IRB state for the *next* band, keyed by column index c.
        # shift_regs[r][c] / shadow_regs[r][c] feed PE row r of band b+1.
        shift_regs: list[dict] = [dict() for _ in range(k - 1)]
        shadow_regs: list[dict] = [dict() for _ in range(k - 1)]

        for band in range(out_h):
            pes = np.full((k, k), np.nan)
            next_shift: list[dict] = [dict() for _ in range(k - 1)]
            next_shadow: list[dict] = [dict() for _ in range(k - 1)]
            for c in range(w):
                # Horizontal movement: everything shifts one PE left; the
                # value exiting column 0 is captured by the IRB (Slice 0
                # forwards it; other slices of the core would discard it).
                exiting = pes[:, 0].copy()
                pes[:, :-1] = pes[:, 1:]
                stats.horizontal_shifts += int(np.isfinite(pes[:, :-1]).sum())
                exit_c = c - k  # column index of the value leaving column 0
                if exit_c >= 0:
                    for row in range(1, k):  # rows 1..K-1 are reused next band
                        next_shift[row - 1][exit_c] = exiting[row]
                # Vertical / diagonal injection into the rightmost column.
                sources: list = []
                for row in range(k):
                    pes[row, k - 1] = self._inject(
                        band, row, c, ifmap, shift_regs, shadow_regs,
                        stats, sources)
                    # Shadow capture: end-of-row values never reach column 0,
                    # so they are latched as they enter (3D-TrIM only).
                    if c > w - k and row >= 1:
                        next_shadow[row - 1][c] = pes[row, k - 1]
                # Compute: once the array holds a full window, all K x K PEs
                # multiply-accumulate for output column x = c - K + 1.
                x = c - k + 1
                if 0 <= x < out_w:
                    output[band, x] = float((pes * weights).sum())
                    stats.macs += k * k
                if self.record_trace:
                    self.trace.append(StepSnapshot(
                        band=band, step=c, pe_values=pes.copy(),
                        sources=sources,
                        shift_regs=[dict(s) for s in next_shift],
                        shadow_regs=[dict(s) for s in next_shadow]))
            # Final flush: after the last window, the value at column 0
            # (column index W-K) performs one more exit into the IRB.
            for row in range(1, k):
                next_shift[row - 1][w - k] = pes[row, 0]
            shift_regs, shadow_regs = next_shift, next_shadow
        return output, stats

    def expected_memory_reads(self, h: int, w: int) -> int:
        """Analytical prediction for the reads counted by :meth:`run` —
        read straight from the shared planning model (conv_plan)."""
        return slice_reads_per_channel(
            h, w, self.k, 1, shadow=(self.mode == "3dtrim"))


# ---------------------------------------------------------------------------
# Core-level simulation: P_O slices sharing one IRB (3D-TrIM) vs private
# buffers (TrIM).  Demonstrates the buffer-sharing contribution.
# ---------------------------------------------------------------------------

def core_conv(ifmap: np.ndarray, weight_stack: np.ndarray,
              mode: str = "3dtrim", shared_irb: bool | None = None):
    """Convolve one ifmap with ``P_O`` kernels (weight_stack: (P_O, K, K)).

    With a shared IRB (3D-TrIM) the external reads are those of a single
    slice: slice 0 fetches, the IRB broadcasts to the others.  Without
    sharing (TrIM orientation) every slice fetches independently.
    Returns ``(outputs (P_O, OH, OW), total_memory_reads)``.
    """
    if shared_irb is None:
        shared_irb = mode == "3dtrim"
    p_o, k, _ = weight_stack.shape
    outputs, reads = [], 0
    for s in range(p_o):
        sim = TrimSliceSim(kernel_size=k, mode=mode)
        out, stats = sim.run(ifmap, weight_stack[s])
        outputs.append(out)
        if s == 0 or not shared_irb:
            reads += stats.memory_reads
    return np.stack(outputs), reads


def reference_conv2d_valid(ifmap: np.ndarray, weights: np.ndarray
                           ) -> np.ndarray:
    """Plain nested-loop oracle for the slice simulator."""
    k = weights.shape[0]
    h, w = ifmap.shape
    out = np.zeros((h - k + 1, w - k + 1))
    for y in range(out.shape[0]):
        for x in range(out.shape[1]):
            out[y, x] = float((ifmap[y:y + k, x:x + k] * weights).sum())
    return out
