"""Sharded TrIM convolution planning (DESIGN.md §6).

3D-TrIM's thesis is that the K-1 row overlap between compute slices
should be explicit and minimized — on chip, the shadow registers carry it
between strips for free.  The multi-device analogue is spatial sharding:
each device owns an H-strip of the ifmap, and the same K-1 boundary rows
become *real* inter-chip traffic, exchanged between neighbors before the
per-shard kernel runs.  :class:`ShardedConvPlan` extends
:class:`~repro.core.conv_plan.ConvPlan` with exactly that accounting:

* **Mesh axis mapping** — ``batch -> batch_axis`` (data parallelism over
  images) and ``H-strips -> spatial_axis`` (spatial parallelism over
  output rows), resolved from a mesh + the conv rules in
  ``distributed/sharding.py`` by :func:`resolve_conv_mesh`.

* **Per-device strip geometry** — shard ``d`` owns output rows
  ``[d * h_out_local, (d+1) * h_out_local)`` (``h_out_local =
  ceil(h_out / spatial_shards)``; trailing shards may own fewer or zero
  real rows — they compute padding that is sliced off, the same
  pad-to-whole-strips treatment ConvPlan applies on chip).  Its input
  slab is the aligned ``slab_rows = h_out_local * stride`` rows of the
  globally padded ifmap.

* **Halo exchange** — before the local kernel runs, each interior
  boundary moves the K-1 boundary rows *down* by ``ppermute``: shard
  ``d`` receives the first ``K-1`` rows of shard ``d+1``'s slab (the
  rows its last output windows reach into).  Because slabs are
  stride-aligned by construction (``slab_rows = h_out_local * stride``),
  this single direction is sufficient — no boundary output row is ever
  recomputed.  Under the vjp the same seam is crossed again in reverse:
  the input-grad halo exchange is the *transpose shuffle* of the
  forward ``ppermute``, moving the K-1 boundary rows of window
  cotangent back up.  ``halo_bytes`` bills that round trip —
  ``2 * (K-1) * Wp * Cin * dtype * (shards-1) * N`` — as a first-class
  roofline term (fed to ``T_collective`` by
  ``core.roofline.sharded_conv_roofline``);
  ``halo_bytes_oneway`` is the forward-only (inference) half.

* **Reduction at shards=1** — ``sharded_traffic()`` returns the global
  ConvPlan byte terms plus the halo term; with one device the halo term
  is zero and every number reduces exactly to ``ConvPlan.hbm_bytes()``.

The per-device kernel invocation is planned by :meth:`local_plan` — an
ordinary :class:`ConvPlan` over the assembled local window, so the
sharded path inherits the carry/halo dataflow axis, the tile knobs and
the canonical oversize-strip clamp of the single-device subsystem.
``kernels/trim_conv2d_sharded.py`` executes this plan under
``shard_map``.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from repro.core.conv_plan import ConvPlan


def resolve_conv_mesh(mesh, rules: dict | None = None):
    """Resolve the conv mesh mapping: ``(batch_axis, batch_shards,
    spatial_axis, spatial_shards)``.

    ``rules`` maps the logical conv axes ``"batch"`` / ``"strips"`` to
    mesh axis names (default: ``distributed.sharding.CONV_RULES``, i.e.
    ``batch -> 'data'``, ``strips -> 'model'``).  A rule axis missing
    from the mesh resolves to ``(None, 1)`` — the dimension stays
    unsharded.  Tuple rules pick the first axis present in the mesh.
    """
    if rules is None:
        from repro.distributed.sharding import CONV_RULES
        rules = CONV_RULES
    shape = dict(mesh.shape)

    def pick(name):
        ax = rules.get(name)
        if isinstance(ax, (tuple, list)):
            ax = next((a for a in ax if a in shape), None)
        if ax not in shape:
            ax = None
        return ax, (int(shape[ax]) if ax is not None else 1)

    batch_axis, batch_shards = pick("batch")
    spatial_axis, spatial_shards = pick("strips")
    if batch_axis is not None and batch_axis == spatial_axis:
        raise ValueError(
            f"conv rules map batch and strips to the same mesh axis "
            f"{batch_axis!r}")
    return batch_axis, batch_shards, spatial_axis, spatial_shards


@dataclass(frozen=True)
class ShardedConvPlan(ConvPlan):
    """ConvPlan + mesh axis mapping and cross-device halo accounting.

    The inherited fields/properties describe the *global* problem; the
    sharding fields add the device grid.  ``batch_shards`` must divide
    ``n``; ``spatial_shards`` may exceed ``h_out`` (trailing shards then
    own zero real output rows and compute only padding — correct, just
    wasteful, exactly like an oversized on-chip strip).
    """

    batch_shards: int = 1
    spatial_shards: int = 1
    batch_axis: str | None = "data"
    spatial_axis: str | None = "model"

    def __post_init__(self):
        super().__post_init__()
        if self.batch_shards < 1 or self.spatial_shards < 1:
            raise ValueError(
                f"shard counts must be >= 1, got batch={self.batch_shards} "
                f"spatial={self.spatial_shards}")
        if self.n % self.batch_shards:
            raise ValueError(
                f"batch_shards={self.batch_shards} must divide n={self.n}")

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, x_shape, w_shape, *, batch_shards: int = 1,
              spatial_shards: int = 1, batch_axis: str | None = "data",
              spatial_axis: str | None = "model",
              **kw) -> "ShardedConvPlan":
        """Sharded plan from array shapes; ``**kw`` are the ordinary
        :meth:`ConvPlan.build` knobs (stride/pad/groups/tiles/dataflow)."""
        base = ConvPlan.build(x_shape, w_shape, **kw)
        fields = {f.name: getattr(base, f.name)
                  for f in dataclasses.fields(ConvPlan)}
        return cls(**fields, batch_shards=batch_shards,
                   spatial_shards=spatial_shards, batch_axis=batch_axis,
                   spatial_axis=spatial_axis)

    @classmethod
    def from_mesh(cls, x_shape, w_shape, mesh, *, rules: dict | None = None,
                  **kw) -> "ShardedConvPlan":
        """Sharded plan with the shard grid resolved from a mesh + conv
        rules (the resolution ``ops.conv2d(..., mesh=)`` performs)."""
        ba, bs, sa, ss = resolve_conv_mesh(mesh, rules)
        return cls.build(x_shape, w_shape, batch_shards=bs,
                         spatial_shards=ss, batch_axis=ba, spatial_axis=sa,
                         **kw)

    # -- device grid -------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.batch_shards * self.spatial_shards

    @property
    def n_local(self) -> int:
        """Images per device (data parallelism)."""
        return self.n // self.batch_shards

    # -- per-shard strip geometry ------------------------------------------

    @property
    def h_out_local(self) -> int:
        """Output rows owned per spatial shard (last shards may own
        fewer real rows; the remainder is sliced padding)."""
        return math.ceil(self.h_out / self.spatial_shards)

    @property
    def slab_rows(self) -> int:
        """Input rows resident per shard *before* the halo exchange —
        the aligned ``h_out_local * stride`` slab of the padded ifmap."""
        return self.h_out_local * self.stride

    @property
    def halo_rows_down(self) -> int:
        """Rows received from the next shard per exchange (its first
        K-1 slab rows — the paper's shadow-register overlap)."""
        return 0 if self.spatial_shards == 1 else self.kh - 1

    @property
    def local_in_rows(self) -> int:
        """Rows of the assembled per-device input window: the slab plus
        the K-1 tail rows its last output windows reach into.  Slabs are
        stride-aligned, so no top overlap is needed.  At
        ``spatial_shards == 1`` the tail is local data (no exchange)."""
        return self.slab_rows + (self.kh - 1)

    @property
    def local_x_shape(self) -> tuple[int, int, int, int]:
        """Shape of the assembled per-device input (already W-padded;
        the local kernel runs with pad=0)."""
        return (self.n_local, self.local_in_rows, self.wp, self.cin)

    @property
    def local_out_rows(self) -> int:
        """Output rows the local kernel computes — exactly the owned
        rows (slab alignment means no boundary row is recomputed)."""
        return self.h_out_local

    def shard_strips(self) -> list[tuple[int, int]]:
        """Per-shard ``(first_output_row, real_rows)`` — the strips tile
        the global output exactly (no row unassigned, none owned twice;
        trailing shards may own zero rows)."""
        out = []
        for d in range(self.spatial_shards):
            start = d * self.h_out_local
            rows = max(0, min(self.h_out_local, self.h_out - start))
            out.append((start, rows))
        return out

    def local_plan(self, *, tile_h: int | None = None,
                   tile_cout: int | None = None) -> ConvPlan:
        """The ordinary ConvPlan of one device's kernel invocation —
        the plan ``trim_conv2d`` executes per shard.  The plan's own
        tile knobs carry over by default; an oversized global ``tile_h``
        clamps canonically to the local full-height strip."""
        return ConvPlan.build(
            self.local_x_shape,
            (self.kh, self.kw, self.cin_per_group, self.cout),
            stride=self.stride, pad=0, groups=self.groups,
            dtype_bytes=self.dtype_bytes,
            tile_h=self.tile_h if tile_h is None else tile_h,
            tile_cout=self.tile_cout if tile_cout is None else tile_cout,
            dataflow=self.dataflow, vmem_budget=self.vmem_budget)

    # -- cross-device halo traffic (the first-class roofline term) ---------

    @property
    def halo_bytes_oneway(self) -> int:
        """Cross-device bytes of the *forward* neighbor halo exchange:
        each of the ``spatial_shards - 1`` interior boundaries moves
        ``halo_rows_down`` rows down, for every image — the inference
        wire cost."""
        return ((self.spatial_shards - 1) * self.n * self.halo_rows_down
                * self.wp * self.cin * self.dtype_bytes)

    @property
    def halo_bytes(self) -> int:
        """Total cross-device bytes of one halo-exchange round trip:
        the forward ``ppermute`` down plus its vjp transpose shuffle
        back up — ``2 * (K-1) * Wp * Cin * dtype * (shards-1) * N``,
        zero at shards=1 (the single-device carry)."""
        return 2 * self.halo_bytes_oneway

    @property
    def halo_bytes_per_device(self) -> float:
        return self.halo_bytes / self.n_devices

    @property
    def local_macs(self) -> int:
        """MACs per device, including the padded tail rows of ragged
        shards."""
        return (self.n_local * self.local_out_rows * self.w_out
                * self.cout * self.kh * self.kw * self.cin_per_group)

    @property
    def local_flops(self) -> int:
        return 2 * self.local_macs

    def sharded_traffic(self, mode: str | None = None) -> dict:
        """Global HBM byte terms (exactly :meth:`ConvPlan.hbm_bytes` —
        the slabs partition the padded input) plus the cross-device
        ``halo`` term.  At ``batch_shards == spatial_shards == 1`` this
        reduces *exactly* to the single-device ConvPlan numbers with
        ``halo == 0``.  Per-device HBM granularity (local strip padding,
        per-shard weight re-streaming) lives in :meth:`local_plan`."""
        t = self.hbm_bytes(mode)
        return dict(input=t["input"], weights=t["weights"],
                    output=t["output"], hbm_total=t["total"],
                    halo=self.halo_bytes,
                    total=t["total"] + self.halo_bytes,
                    overhead_pct=t["overhead_pct"])

    def as_dict(self) -> dict:
        d = super().as_dict()
        t = self.sharded_traffic()
        d.update(batch_shards=self.batch_shards,
                 spatial_shards=self.spatial_shards,
                 n_devices=self.n_devices,
                 h_out_local=self.h_out_local,
                 slab_rows=self.slab_rows,
                 halo_rows_down=self.halo_rows_down,
                 halo_bytes=t["halo"], sharded_total=t["total"])
        return d
