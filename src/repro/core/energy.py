"""Energy / area model reproducing Table I of the paper.

The paper normalizes competing ASICs to 22 nm with DeepScaleTool [19, 20].
We recover the effective DeepScaleTool scaling factors from the paper's own
raw/normalized pairs (they are consistent across rows) and encode them, so
``table1()`` reproduces the published table and can score new design points.

A small Horowitz-style energy model (`energy_per_inference`) converts the
access counts of `core.model` into energy, quantifying the architectural
claim that one external access costs 2-3 orders of magnitude more than a
MAC [3].
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import model as acc_model

# DeepScaleTool factors to 22 nm, recovered from Table I raw/normalized
# pairs ([18]/[11]: 7 nm, [12]: 65 nm).  freq_scale multiplies throughput,
# area/power scale multiply their raw values.
_SCALE_TO_22NM = {
    7:  dict(freq=0.852, area=19.98, power=2.283),
    22: dict(freq=1.0, area=1.0, power=1.0),
    65: dict(freq=1.571, area=0.108, power=0.458),
}


@dataclass(frozen=True)
class ASICDesign:
    name: str
    pes: int
    tech_nm: int
    freq_ghz: float
    peak_tops: float
    area_mm2: float
    power_w: float

    def normalized(self) -> dict:
        s = _SCALE_TO_22NM[self.tech_nm]
        tops = self.peak_tops * s["freq"]
        area = self.area_mm2 * s["area"]
        power = self.power_w * s["power"]
        return {
            "name": self.name,
            "pes": self.pes,
            "tech_nm": self.tech_nm,
            "freq_ghz": self.freq_ghz,
            "peak_tops": self.peak_tops,
            "norm_tops": tops,
            "norm_area_mm2": area,
            "norm_power_w": power,
            "norm_energy_eff_tops_per_w": tops / power,
            "norm_area_eff_tops_per_mm2": tops / area,
        }


TABLE1_DESIGNS = [
    ASICDesign("tpu-v4i [18]", 65536, 7, 1.05, 138.0, 400.0, 175.0),
    ASICDesign("eyeriss [12]", 168, 65, 0.2, 0.07, 12.25, 0.24),
    ASICDesign("multi-precision SA [11]", 256, 7, 2.0, 1.02, 3.81, 5.12),
    ASICDesign("3d-trim (this work)", 576, 22, 1.0, 1.15, 0.26, 0.25),
]


def table1() -> list[dict]:
    return [d.normalized() for d in TABLE1_DESIGNS]


def peak_tops(pes: int, freq_ghz: float) -> float:
    """Peak throughput: every PE performs one MAC (= 2 OPs) per cycle."""
    return pes * 2 * freq_ghz / 1e3


# ---------------------------------------------------------------------------
# Horowitz-style energy accounting [3] (45 nm reference points, pJ)
# ---------------------------------------------------------------------------

ENERGY_PJ = {
    "dram_access": 640.0,     # external memory, per 32-bit word
    "sram_access": 5.0,       # large on-chip buffer
    "register": 0.06,         # local register move (shift / shadow)
    "mac_int8": 0.23,         # 8-bit multiply-accumulate
    "mac_fp32": 4.6,          # fp32 mult (3.7) + add (0.9)
}


def energy_per_layer(layer: acc_model.ConvLayer,
                     hw: acc_model.HWConfig, *,
                     dtype_bytes: int = 1,
                     mac: str = "mac_int8") -> dict:
    """Energy (uJ) split between external accesses and compute.

    ``core.model.layer_accesses`` counts *element* accesses; the DRAM
    reference energy is per 32-bit word, so a transfer is billed at
    ``dtype_bytes / 4`` of it — an int8 element (the paper's silicon,
    the default) moves a quarter of the bytes an f32 element does.
    ``mac`` picks the MAC energy (``"mac_int8"`` / ``"mac_fp32"``),
    which together with ``dtype_bytes`` prices a whole network in either
    precision (the ``--energy`` report of ``benchmarks/paper_eval.py``).
    """
    acc = acc_model.layer_accesses(layer, hw)
    e_mem = acc.total * ENERGY_PJ["dram_access"] * (dtype_bytes / 4.0)
    e_mac = layer.macs * ENERGY_PJ[mac]
    # every MAC implies ~3 register moves (activation shift, psum, product)
    e_reg = layer.macs * 3 * ENERGY_PJ["register"]
    return {
        "layer": layer.label(),
        "hw": hw.name,
        "memory_uJ": e_mem / 1e6,
        "compute_uJ": (e_mac + e_reg) / 1e6,
        "total_uJ": (e_mem + e_mac + e_reg) / 1e6,
        "memory_fraction": e_mem / (e_mem + e_mac + e_reg),
    }


_NETWORK_LAYER_FNS = {
    "vgg16": acc_model.vgg16_layers,
    "alexnet": acc_model.alexnet_layers,
    "mobilenet": acc_model.mobilenet_layers,
}


def energy_per_inference(network: str = "vgg16",
                         hw: acc_model.HWConfig = acc_model.TRIM_3D, *,
                         dtype_bytes: int = 1,
                         mac: str = "mac_int8") -> dict:
    """Modeled energy for one inference of a whole network.

    ``tops_per_watt`` is the modeled efficiency of the access pattern:
    total OPs (2 per MAC) divided by total modeled energy — 1 OP/pJ is
    exactly 1 TOPS/W, so the figure is directly comparable to the
    paper's Table I silicon numbers.
    """
    try:
        layers = _NETWORK_LAYER_FNS[network]()
    except KeyError:
        # DAG topologies: the access-count model is per conv layer, so
        # a graph's energy is the sum over its conv nodes (joins move
        # activations but drive no MAC/register energy terms here)
        from repro.core.netplan import GRAPHS, graph_nodes
        if network not in GRAPHS:
            raise ValueError(
                f"unknown network {network!r}; choose from "
                f"{sorted(_NETWORK_LAYER_FNS) + sorted(GRAPHS)}") \
                from None
        layers = [nd.layer for nd in graph_nodes(network)
                  if nd.op == "conv"]
    per = [energy_per_layer(l, hw, dtype_bytes=dtype_bytes, mac=mac)
           for l in layers]
    total_uJ = sum(p["total_uJ"] for p in per)
    ops = 2 * sum(l.macs for l in layers)
    return {
        "network": network,
        "hw": hw.name,
        "dtype_bytes": dtype_bytes,
        "mac": mac,
        "total_uJ": total_uJ,
        "memory_uJ": sum(p["memory_uJ"] for p in per),
        "tops_per_watt": ops / (total_uJ * 1e6),   # OPs / pJ == TOPS/W
        "layers": per,
    }
