"""3D-TrIM core: conv planning, dataflow simulator, analytical models,
tiling, roofline."""

from repro.core.conv_plan import (  # noqa: F401
    ConvPlan, Conv1dPlan, WeightGradPlan, input_grad_geometry,
    slice_reads_per_channel,
)
from repro.core.conv_shard import (  # noqa: F401
    ShardedConvPlan, resolve_conv_mesh,
)
from repro.core.netplan import (  # noqa: F401
    EdgeState, JoinStep, LayerStep, NetworkGraph, NetworkPlan,
    PoolInferenceError, graph_nodes, infer_pools, linear_graph_nodes,
    network_layers, scale_graph, scale_layers,
)
from repro.core.fuse_plan import (  # noqa: F401
    FusedGroup, FusedGroupPlan, FusedStage, GraphFusePlan, build_group,
    graph_segments,
)
from repro.core.model import (  # noqa: F401
    ConvLayer, GraphNode, HWConfig, TRIM, TRIM_3D,
    ifmap_reads_per_channel, ifmap_overhead_pct, fig1_curve,
    layer_accesses, compare_layer, fig6, vgg16_layers, alexnet_layers,
    mobilenet_layers, resnet18_graph, unet_graph,
)
from repro.core.dataflow import (  # noqa: F401
    TrimSliceSim, SliceStats, core_conv, reference_conv2d_valid,
)
from repro.core.tiling import (  # noqa: F401
    subkernel_decomposition, plan_conv_tiles, ConvTilePlan,
)
from repro.core.serving import (  # noqa: F401
    BucketGrid, QueueFull, Replica, ServingEngine, pow2_buckets, replay,
)
