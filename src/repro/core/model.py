"""Analytical memory-access model for TrIM / 3D-TrIM / GeMM(im2col).

Reproduces the paper's analytical results:

* Fig. 1 — ifmap memory-access overhead of TrIM vs ifmap size (K=3):
  TrIM's shift registers hold ``W - K - 1`` entries per reused row, so the
  last ``K-1`` activations of every ifmap row fall off and must be re-read
  from external memory on every band advance.  3D-TrIM's shadow registers
  hold exactly those values -> zero overhead.

* Fig. 6 — OPs / memory-access / slice for every conv layer of VGG-16 and
  AlexNet, comparing the 3D-TrIM ASIC configuration (P_I=8 cores x P_O=8
  slices = 64 slices) against the TrIM configuration (7 x 24 = 168 slices).

Counting conventions (documented assumptions — see DESIGN.md §1):
  * "memory accesses" = external (off-chip) ifmap reads + weight reads.
    Psums are accumulated in on-chip buffers in both architectures and are
    not part of the paper's OPs/Access metric.
  * An ifmap channel that is broadcast to several consumers at the same
    time (TrIM: the same channel feeding the 7 filter-parallel cores;
    3D-TrIM: one channel feeding the P_O slices of a core through the
    shared IRB) is counted as ONE external read.
  * One OP = one multiply or one add, so a MAC = 2 OPs (this makes the
    576-PE / 1 GHz design peak at 1.15 TOPS as reported).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

from repro.core.conv_plan import ConvPlan, slice_reads_per_channel


# ---------------------------------------------------------------------------
# Layer / hardware descriptions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvLayer:
    """One 2D convolution layer (square spatial dims)."""

    name: str
    ifmap: int          # I  (ifmap height = width)
    in_channels: int    # C
    out_channels: int   # F
    kernel: int         # K
    stride: int = 1     # S
    padding: int = 0    # P (symmetric zero padding; zeros are never *read*)
    groups: int = 1     # feature groups (== C for depthwise)

    @property
    def out_size(self) -> int:
        return (self.ifmap + 2 * self.padding - self.kernel) // self.stride + 1

    @property
    def macs(self) -> int:
        return (self.out_size ** 2) * (self.in_channels // self.groups) \
            * self.out_channels * (self.kernel ** 2)

    @property
    def ops(self) -> int:
        return 2 * self.macs

    def label(self) -> str:
        g = f",g{self.groups}" if self.groups > 1 else ""
        return (f"({self.ifmap},{self.in_channels},"
                f"{self.out_channels},{self.kernel}{g})")

    def plan(self, *, n: int = 1, dtype_bytes: int = 4,
             tile_h: int | None = None,
             tile_cout: int | None = None,
             dataflow: str = "carry") -> ConvPlan:
        """The TPU-kernel ``ConvPlan`` for this layer — same object the
        Pallas kernel executes and the roofline/benchmarks read."""
        return ConvPlan.from_layer(self, n=n, dtype_bytes=dtype_bytes,
                                   tile_h=tile_h, tile_cout=tile_cout,
                                   dataflow=dataflow)


@dataclass(frozen=True)
class HWConfig:
    """A TrIM-family accelerator configuration.

    ``filter_parallel``  — number of filters processed concurrently.
    ``channel_parallel`` — number of ifmap channels processed concurrently.
    ``shadow_registers`` — True for 3D-TrIM (end-of-row activations kept in
                           shadow registers, ifmap overhead nullified).
    ``native_k``         — largest kernel the slices support natively;
                           larger kernels are decomposed into ceil(K/3)^2
                           3x3 sub-kernels (paper §III kernel tiling).
    """

    name: str
    filter_parallel: int
    channel_parallel: int
    shadow_registers: bool
    slices: int
    native_k: int = 3
    frequency_ghz: float = 1.0

    @property
    def pes(self) -> int:
        return self.slices * 9

    @property
    def peak_tops(self) -> float:
        return self.pes * 2 * self.frequency_ghz / 1e3


# The two configurations compared in the paper (§III).
TRIM_3D = HWConfig(name="3d-trim", filter_parallel=8, channel_parallel=8,
                   shadow_registers=True, slices=64)
TRIM = HWConfig(name="trim", filter_parallel=7, channel_parallel=24,
                shadow_registers=False, slices=168)


# ---------------------------------------------------------------------------
# ifmap access model (Fig. 1)
# ---------------------------------------------------------------------------

def ifmap_reads_per_channel(height: int, width: int, kernel: int,
                            stride: int = 1, *, shadow: bool) -> int:
    """External reads of one ifmap channel for one pass of the array.

    Alias of ``core.conv_plan.slice_reads_per_channel`` — the single place
    this math lives; kept under its historical name for the Fig. 1/6 API.
    """
    return slice_reads_per_channel(height, width, kernel, stride,
                                   shadow=shadow)


def ifmap_overhead_pct(size: int, kernel: int = 3, stride: int = 1) -> float:
    """TrIM ifmap access overhead (%) vs the ideal single-read — Fig. 1."""
    ideal = size * size
    trim = ifmap_reads_per_channel(size, size, kernel, stride, shadow=False)
    return 100.0 * (trim - ideal) / ideal


def fig1_curve(sizes=(14, 28, 56, 112, 224), kernel: int = 3) -> dict:
    """Overhead curve of Fig. 1: TrIM % overhead per ifmap size, K=3."""
    return {s: ifmap_overhead_pct(s, kernel) for s in sizes}


# ---------------------------------------------------------------------------
# Kernel tiling (paper §III: K>3 decomposed into 3x3 sub-kernels)
# ---------------------------------------------------------------------------

def num_subkernels(kernel: int, native_k: int = 3) -> int:
    if kernel <= native_k:
        return 1
    t = math.ceil(kernel / native_k)
    return t * t


# ---------------------------------------------------------------------------
# Per-layer access + OPs/Access/Slice model (Fig. 6)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerAccesses:
    layer: ConvLayer
    hw: HWConfig
    ifmap_reads: int
    weight_reads: int

    @property
    def total(self) -> int:
        return self.ifmap_reads + self.weight_reads

    @property
    def ops_per_access(self) -> float:
        return self.layer.ops / self.total

    @property
    def ops_per_access_per_slice(self) -> float:
        return self.ops_per_access / self.hw.slices


def layer_accesses(layer: ConvLayer, hw: HWConfig) -> LayerAccesses:
    """External memory accesses for one conv layer on one configuration."""
    k, s = layer.kernel, layer.stride
    tiles = num_subkernels(k, hw.native_k)
    sub_k = k if tiles == 1 else hw.native_k

    # Filter passes: every pass over a new batch of filters re-streams the
    # ifmap channels it consumes (psums for only ``filter_parallel`` ofmaps
    # fit on chip).  With feature groups, a filter only consumes its own
    # group's C/groups channels.
    filter_passes = math.ceil(layer.out_channels // layer.groups
                              / hw.filter_parallel)

    # Per-channel reads for one pass of one (sub-)kernel.
    rpc = ifmap_reads_per_channel(layer.ifmap, layer.ifmap, sub_k, s,
                                  shadow=hw.shadow_registers)
    # Each sub-kernel occupies its own core/slice with its own IRB, so a
    # channel is streamed once per sub-kernel.
    ifmap_reads = layer.in_channels * rpc * tiles * filter_passes

    # Weights are loaded once per (filter, channel, tap).  Tiled kernels are
    # zero-padded up to tiles * native_k^2 taps.
    taps = k * k if tiles == 1 else tiles * hw.native_k ** 2
    weight_reads = layer.out_channels * (layer.in_channels // layer.groups) \
        * taps

    return LayerAccesses(layer=layer, hw=hw, ifmap_reads=ifmap_reads,
                         weight_reads=weight_reads)


def compare_layer(layer: ConvLayer, hw_a: HWConfig = TRIM_3D,
                  hw_b: HWConfig = TRIM) -> dict:
    """Fig. 6 bar pair for one layer: OPs/Access/Slice of both configs."""
    a = layer_accesses(layer, hw_a)
    b = layer_accesses(layer, hw_b)
    return {
        "layer": layer.label(),
        hw_a.name: a.ops_per_access_per_slice,
        hw_b.name: b.ops_per_access_per_slice,
        "improvement": a.ops_per_access_per_slice / b.ops_per_access_per_slice,
    }


# ---------------------------------------------------------------------------
# CNN topologies used in the paper
# ---------------------------------------------------------------------------

def vgg16_layers() -> list[ConvLayer]:
    """The 13 conv layers of the VGG-16 feature extractor (same padding)."""
    spec = [
        (224, 3, 64), (224, 64, 64),
        (112, 64, 128), (112, 128, 128),
        (56, 128, 256), (56, 256, 256), (56, 256, 256),
        (28, 256, 512), (28, 512, 512), (28, 512, 512),
        (14, 512, 512), (14, 512, 512), (14, 512, 512),
    ]
    return [ConvLayer(name=f"conv{i+1}", ifmap=i_sz, in_channels=c,
                      out_channels=f, kernel=3, stride=1, padding=1)
            for i, (i_sz, c, f) in enumerate(spec)]


def alexnet_layers() -> list[ConvLayer]:
    """The 5 conv layers of AlexNet."""
    return [
        ConvLayer("conv1", 227, 3, 96, kernel=11, stride=4, padding=0),
        ConvLayer("conv2", 27, 96, 256, kernel=5, stride=1, padding=2),
        ConvLayer("conv3", 13, 256, 384, kernel=3, stride=1, padding=1),
        ConvLayer("conv4", 13, 384, 384, kernel=3, stride=1, padding=1),
        ConvLayer("conv5", 13, 384, 256, kernel=3, stride=1, padding=1),
    ]


def mobilenet_layers() -> list[ConvLayer]:
    """Representative MobileNetV1 depthwise-separable stages: each stage is
    a depthwise 3x3 (groups == C) followed by a pointwise 1x1 — the
    low-reuse workload the paper's OPs/Access comparison targets."""
    layers: list[ConvLayer] = []
    for i, (i_sz, c, f, s) in enumerate([
            (112, 32, 64, 1), (112, 64, 128, 2),
            (56, 128, 256, 2), (28, 256, 512, 2)]):
        layers.append(ConvLayer(f"dw{i+1}", i_sz, c, c, kernel=3, stride=s,
                                padding=1, groups=c))
        layers.append(ConvLayer(f"pw{i+1}", i_sz // s, c, f, kernel=1))
    return layers


# ---------------------------------------------------------------------------
# DAG topologies (NetworkGraph nodes — core.netplan generalizes the linear
# chains above to these)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GraphNode:
    """One node of a DAG topology (``core.netplan.NetworkGraph``).

    ``op`` is one of:

    * ``"conv"``     — a :class:`ConvLayer` (``layer`` required); ``pool``
      / ``pool_window`` fold a max-pool epilogue onto the conv, exactly
      like a chained ``LayerStep`` (linear chains converted by
      ``netplan.linear_graph_nodes`` use this).
    * ``"pool"``     — a standalone ``pool_window``^2 / stride-``pool``
      max pool.  DAG topologies keep pools explicit so a skip edge can
      tap the *pre*-pool activation.
    * ``"add"``      — elementwise residual join (all inputs same shape).
    * ``"concat"``   — channel concatenation (same spatial dims).
    * ``"upsample"`` — nearest-neighbour spatial upsampling by ``scale``.

    ``inputs`` name producer nodes; a conv node with no inputs reads the
    network input (exactly one such source node per graph).  Joins
    perform no MACs — their cost is pure activation traffic, which is
    the quantity the residency pass arbitrates.
    """

    name: str
    op: str
    inputs: tuple[str, ...] = ()
    layer: ConvLayer | None = None
    pool: int = 1
    pool_window: int = 1
    scale: int = 1

    def __post_init__(self):
        if self.op not in ("conv", "pool", "add", "concat", "upsample"):
            raise ValueError(f"node {self.name}: unknown op {self.op!r}")
        if (self.layer is not None) != (self.op == "conv"):
            raise ValueError(f"node {self.name}: op={self.op!r} "
                             f"{'requires' if self.op == 'conv' else 'forbids'}"
                             " a ConvLayer")
        if self.op != "conv" and not self.inputs:
            raise ValueError(f"node {self.name}: op={self.op!r} needs inputs")


def resnet18_graph(image: int = 224, base: int = 64) -> list[GraphNode]:
    """ResNet-18 feature extractor as a DAG: a 7x7/s2 stem, a 2x2/s2 max
    pool, then four stages of two basic blocks (3x3 + 3x3 + residual
    add); the first block of stages 2-4 strides by 2 with a 1x1/s2
    projection conv on the skip edge.  ``base``/``image`` shrink the
    topology for the CPU tests (defaults are the paper-scale ImageNet
    configuration)."""
    stem = ConvLayer("conv1", image, 3, base, kernel=7, stride=2, padding=3)
    nodes = [GraphNode("conv1", "conv", (), stem),
             GraphNode("pool1", "pool", ("conv1",), pool=2, pool_window=2)]
    prev, size, cin = "pool1", stem.out_size // 2, base
    for stage in range(1, 5):
        cout = base << (stage - 1)
        for b in range(2):
            stride = 2 if (stage > 1 and b == 0) else 1
            tag = f"l{stage}b{b}"
            c1 = ConvLayer(f"{tag}_conv1", size, cin, cout, kernel=3,
                           stride=stride, padding=1)
            c2 = ConvLayer(f"{tag}_conv2", c1.out_size, cout, cout,
                           kernel=3, stride=1, padding=1)
            nodes.append(GraphNode(c1.name, "conv", (prev,), c1))
            nodes.append(GraphNode(c2.name, "conv", (c1.name,), c2))
            skip = prev
            if stride != 1 or cin != cout:
                ds = ConvLayer(f"{tag}_down", size, cin, cout, kernel=1,
                               stride=stride)
                nodes.append(GraphNode(ds.name, "conv", (prev,), ds))
                skip = ds.name
            nodes.append(GraphNode(f"{tag}_add", "add", (c2.name, skip)))
            prev, size, cin = f"{tag}_add", c1.out_size, cout
    return nodes


def unet_graph(image: int = 64, base: int = 16, in_channels: int = 3,
               out_channels: int = 4, depth: int = 2) -> list[GraphNode]:
    """A small U-Net: ``depth`` encoder levels (two 3x3 convs + 2x2/s2
    pool each), a two-conv bottleneck, then mirrored decoder levels
    (nearest x2 upsample, channel-halving 3x3, concat with the encoder
    skip, two 3x3 convs) and a 1x1 head.  Skip edges tap the *pre*-pool
    encoder activations, so their liveness spans the whole U."""
    if image % (1 << depth):
        raise ValueError(f"image {image} not divisible by 2^{depth}")
    nodes: list[GraphNode] = []
    prev: str | None = None

    def conv(name, ifmap, ci, co, k=3, p=1):
        l = ConvLayer(name, ifmap, ci, co, kernel=k, stride=1, padding=p)
        nodes.append(GraphNode(name, "conv",
                               (prev,) if prev else (), l))
        return name

    size, cin, skips = image, in_channels, []
    for lv in range(depth):
        c = base << lv
        prev = conv(f"enc{lv}a", size, cin, c)
        prev = conv(f"enc{lv}b", size, c, c)
        skips.append((prev, size, c))
        nodes.append(GraphNode(f"pool{lv}", "pool", (prev,),
                               pool=2, pool_window=2))
        prev, size, cin = f"pool{lv}", size // 2, c
    c = base << depth
    prev = conv("mid_a", size, cin, c)
    prev = conv("mid_b", size, c, c)
    cin = c
    for lv in reversed(range(depth)):
        c = base << lv
        nodes.append(GraphNode(f"up{lv}", "upsample", (prev,), scale=2))
        prev, size = f"up{lv}", size * 2
        prev = conv(f"dec{lv}r", size, cin, c)
        skip, _, _ = skips[lv]
        nodes.append(GraphNode(f"cat{lv}", "concat", (prev, skip)))
        prev = f"cat{lv}"
        prev = conv(f"dec{lv}a", size, 2 * c, c)
        prev = conv(f"dec{lv}b", size, c, c)
        cin = c
    conv("out", size, cin, out_channels, k=1, p=0)
    return nodes


def fig6(network: str = "vgg16") -> list[dict]:
    layers = {"vgg16": vgg16_layers, "alexnet": alexnet_layers,
              "mobilenet": mobilenet_layers}[network]()
    return [compare_layer(l) for l in layers]


# ---------------------------------------------------------------------------
# GeMM (im2col) baseline — the redundancy the Conv-based dataflows avoid
# ---------------------------------------------------------------------------

def im2col_ifmap_reads(layer: ConvLayer) -> int:
    """im2col materializes every window: K^2 redundancy at the memory level."""
    return (layer.out_size ** 2) * (layer.kernel ** 2) * layer.in_channels


def gemm_accesses(layer: ConvLayer, filter_parallel: int = 8) -> int:
    filter_passes = math.ceil(layer.out_channels / filter_parallel)
    return (im2col_ifmap_reads(layer) * filter_passes
            + layer.out_channels * layer.in_channels * layer.kernel ** 2)
