"""Tiling planners: kernel tiling (paper §III) and TPU VMEM block planning.

Two distinct concerns live here:

* ``subkernel_decomposition`` — the paper's kernel-tiling trick: a K x K
  kernel with K > native_k is split into ceil(K/3)^2 sub-kernels of at most
  3 x 3 taps, each assigned to a different core; the adder trees accumulate
  the partial results.  We use the same decomposition arithmetically in
  ``kernels/ops.py`` for K > 8 (MXU-unfriendly kernels).

* ``plan_conv_tiles`` — the TPU analogue of sizing the IRB: choose VMEM
  block shapes (spatial strip x C_in tile x C_out tile) so that the
  resident set (ifmap strip + weight tile + psum block) fits the ~16 MiB
  VMEM of a TPU core while keeping the MXU matmul dimensions aligned to
  multiples of the 128-lane hardware tiling.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024      # per-core VMEM budget (v5e-like)
MXU_ALIGN = 128                    # lane alignment for MXU operands


def subkernel_decomposition(k: int, native_k: int = 3
                            ) -> list[tuple[int, int, int, int]]:
    """Split a K x K kernel into (row_off, col_off, kh, kw) sub-kernels.

    Matches §III: "a 5x5 kernel can be split into four 3x3 sub-kernels" —
    we return the un-padded tap extents (3,3), (3,2), (2,3), (2,2) whose
    union tiles the 5x5; zero-padding to 3x3 is a hardware detail that the
    arithmetic decomposition does not need.
    """
    if k <= native_k:
        return [(0, 0, k, k)]
    subs = []
    for r0 in range(0, k, native_k):
        for c0 in range(0, k, native_k):
            subs.append((r0, c0, min(native_k, k - r0), min(native_k, k - c0)))
    return subs


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _round_down_pow2(x: int) -> int:
    return 1 << max(x.bit_length() - 1, 0)


@dataclass(frozen=True)
class ConvTilePlan:
    """Block shapes for the trim_conv2d Pallas kernel."""

    tile_h: int          # spatial strip height (output rows per block)
    tile_cin: int        # input-channel tile
    tile_cout: int       # output-channel tile
    halo: int            # K - 1 rows kept resident across strips ("shadow")
    vmem_bytes: int      # resident-set estimate

    def grid(self, h_out: int, cin: int, cout: int) -> tuple[int, int, int]:
        return (math.ceil(cout / self.tile_cout),
                math.ceil(h_out / self.tile_h),
                math.ceil(cin / self.tile_cin))


def plan_conv_tiles(h: int, w: int, cin: int, cout: int, k: int,
                    dtype_bytes: int = 4,
                    vmem_budget: int = VMEM_BYTES) -> ConvTilePlan:
    """Choose (TH, TCin, TCout) so the resident set fits VMEM.

    Resident set per grid step (the TPU image of the IRB contract):
      ifmap strip   (TH + K - 1, W + K - 1, TCin)   — fetched once, reused
                     by every C_out tile (index map ignores the C_out axis)
      weight tile   (K, K, TCin, TCout)             — stationary
      psum block    (TH, W, TCout) fp32             — adder-tree analogue
    """
    halo = k - 1
    tile_cin = min(_round_up(cin, MXU_ALIGN), 256) if cin >= MXU_ALIGN \
        else _round_up(cin, 8)
    tile_cout = min(_round_up(cout, MXU_ALIGN), 256) if cout >= MXU_ALIGN \
        else _round_up(cout, 8)

    def resident(th: int, tci: int, tco: int) -> int:
        strip = (th + halo) * (w + halo) * tci * dtype_bytes
        wtile = k * k * tci * tco * dtype_bytes
        psum = th * w * tco * 4
        return strip + wtile + psum

    tile_h = h
    while tile_h > 1 and resident(tile_h, tile_cin, tile_cout) > vmem_budget:
        tile_h = _round_down_pow2(tile_h - 1)
    while (resident(tile_h, tile_cin, tile_cout) > vmem_budget
           and tile_cin > 8):
        tile_cin //= 2
    return ConvTilePlan(tile_h=tile_h, tile_cin=min(tile_cin, cin) if cin >= 8
                        else tile_cin,
                        tile_cout=tile_cout, halo=halo,
                        vmem_bytes=resident(tile_h, tile_cin, tile_cout))
