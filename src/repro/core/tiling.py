"""Tiling planners: kernel tiling (paper §III) and TPU VMEM block planning.

Two distinct concerns live here:

* ``subkernel_decomposition`` — the paper's kernel-tiling trick: a K x K
  kernel with K > native_k is split into ceil(K/3)^2 sub-kernels of at most
  3 x 3 taps, each assigned to a different core; the adder trees accumulate
  the partial results.  We use the same decomposition arithmetically in
  ``kernels/ops.py`` for K > 8 (MXU-unfriendly kernels).

* ``plan_conv_tiles`` — compatibility facade over
  ``core.conv_plan.ConvPlan``, which is the single owner of strip/tile/
  traffic math.  It sizes the resident set (ifmap strip + carry + weight
  tile + psum block) against the VMEM of a TPU core.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024      # per-core VMEM budget (v5e-like)
MXU_ALIGN = 128                    # lane alignment for MXU operands


def subkernel_decomposition(k: int, native_k: int = 3
                            ) -> list[tuple[int, int, int, int]]:
    """Split a K x K kernel into (row_off, col_off, kh, kw) sub-kernels.

    Matches §III: "a 5x5 kernel can be split into four 3x3 sub-kernels" —
    we return the un-padded tap extents (3,3), (3,2), (2,3), (2,2) whose
    union tiles the 5x5; zero-padding to 3x3 is a hardware detail that the
    arithmetic decomposition does not need.
    """
    if k <= native_k:
        return [(0, 0, k, k)]
    subs = []
    for r0 in range(0, k, native_k):
        for c0 in range(0, k, native_k):
            subs.append((r0, c0, min(native_k, k - r0), min(native_k, k - c0)))
    return subs


@dataclass(frozen=True)
class ConvTilePlan:
    """Block shapes for the trim_conv2d Pallas kernel."""

    tile_h: int          # spatial strip height (output rows per block)
    tile_cin: int        # input-channel tile
    tile_cout: int       # output-channel tile
    halo: int            # K - 1 rows kept resident across strips ("shadow")
    vmem_bytes: int      # resident-set estimate

    def grid(self, h_out: int, cin: int, cout: int) -> tuple[int, int, int]:
        return (math.ceil(cout / self.tile_cout),
                math.ceil(h_out / self.tile_h),
                math.ceil(cin / self.tile_cin))


def plan_conv_tiles(h: int, w: int, cin: int, cout: int, k: int,
                    dtype_bytes: int = 4,
                    vmem_budget: int = VMEM_BYTES) -> ConvTilePlan:
    """Choose (TH, TCin, TCout) so the resident set fits VMEM.

    Facade over ``ConvPlan.build`` for the strip/C_out geometry; when the
    full channel slice still overflows the budget (huge C_in/C_out), the
    C_in then C_out tiles are halved until the resident set fits — the
    sizing contract callers rely on.
    """
    from repro.core.conv_plan import ConvPlan
    plan = ConvPlan.build((1, h, w, cin), (k, k, cin, cout),
                          dtype_bytes=dtype_bytes,
                          vmem_budget=vmem_budget // 2)
    tile_cin, tile_cout = cin, plan.tile_cout

    def resident(tci: int, tco: int) -> int:
        strip = plan.tile_h * plan.wp * tci * dtype_bytes
        carry = plan.carry_shape[0] * plan.wp * tci * dtype_bytes
        wtile = k * k * tci * tco * dtype_bytes
        acc = plan.th_out * plan.w_out * tco * 4        # fp32 psums
        return strip + carry + wtile + acc

    while resident(tile_cin, tile_cout) > vmem_budget and tile_cin > 8:
        tile_cin //= 2
    while resident(tile_cin, tile_cout) > vmem_budget and tile_cout > 8:
        tile_cout //= 2
    return ConvTilePlan(tile_h=plan.tile_h, tile_cin=tile_cin,
                        tile_cout=tile_cout, halo=k - 1,
                        vmem_bytes=resident(tile_cin, tile_cout))
