"""Per-layer conv autotuner with a persistent JSON cache (DESIGN.md §4).

The companion TrIM paper (arXiv:2408.01254) shows tile-shape choice
dominates achievable efficiency per layer, and "Computing-In-Memory
Dataflow for Minimal Buffer Traffic" (arXiv:2508.14375) picks its dataflow
per layer from an analytical buffer-traffic model.  This module is that
selection layer for the TPU kernel: it searches the
``(tile_h, tile_cout, dataflow)`` space of :class:`~repro.core.conv_plan.
ConvPlan`, scores candidates by the plan's own roofline step time
(``max(T_comp, T_mem)`` over the plan's analytical HBM bytes), optionally
refines the leaders by wall-clock measurement of the real kernel, and
persists the winner in a JSON cache that ``ops.conv2d`` consults on every
call.

Cache location: ``$REPRO_CONVTUNE_CACHE`` if set, else
``~/.cache/repro/convtune.json``.  Schema (version 1)::

    {"version": 1,
     "entries": {"<key>": {"tile_h": int, "tile_cout": int,
                           "dataflow": "carry"|"halo",
                           "source": "model"|"measured",
                           "model_step_time_s": float,
                           "measured_us": float|null}}}

Keys are ``conv2d:n..h..w..cin..cout..k..s..p..g..:<dtype>:<backend>`` —
one entry per (shape, stride, pad, groups, dtype, backend) problem, so a
cache tuned on TPU never feeds knobs to an interpret-mode CPU run and
vice versa.

Robustness (DESIGN.md §9): ``store`` takes a ``.lock`` sidecar file
lock and re-reads + merges the on-disk entries before the atomic
``os.replace``, so concurrent processes sharing a cache path (e.g. CI
jobs) never drop each other's records.  An unreadable or
wrong-schema-version cache file is *quarantined* — renamed to
``convtune.json.corrupt-<pid>`` with a warning — never silently reset,
so a corruption event stays diagnosable.  Consult-site lookups validate
each record structurally AND against the current plan geometry
(``ConvPlan.build`` with the record's knobs); a malformed record is a
miss, warned once per (path, key).
"""

from __future__ import annotations

import contextlib
import json
import os
import time
import warnings

from repro.core.conv_plan import ConvPlan, input_grad_geometry
from repro.core.roofline import conv_plan_roofline, dtype_width
from repro.core.tiling import VMEM_BYTES


def _resolve_bytes(dtype_bytes, dtype: str) -> int:
    """Width of the tuned problem's activations: an explicit
    ``dtype_bytes`` wins, otherwise it is derived from ``dtype`` via the
    shared :func:`repro.core.roofline.dtype_width` table (so a bf16 or
    int8 tune never scores with f32 traffic)."""
    return dtype_width(dtype) if dtype_bytes is None else dtype_bytes

try:
    import fcntl
except ImportError:          # non-POSIX: cooperative locking unavailable
    fcntl = None

DATAFLOWS = ("carry", "halo")
CACHE_ENV = "REPRO_CONVTUNE_CACHE"
AUTOTUNE_ENV = "REPRO_CONV_AUTOTUNE"      # set to "0" to disable lookups
_SCHEMA_VERSION = 1

# path -> entries dict; "missing file" memoized as {} so the hot-path
# lookup in ops.conv2d costs one dict probe, not a stat per call.
_MEM: dict[str, dict] = {}

# (path, key) pairs already warned about — one warning per bad record,
# not one per conv call.
_WARNED: set = set()

# patchable alias: the fault harness (repro.testing.faults) swaps this
# to simulate a crash after the temp write but before the publish
_publish = os.replace


# ---------------------------------------------------------------------------
# Cache file
# ---------------------------------------------------------------------------

def cache_path(path: str | None = None) -> str:
    """Resolve the cache file: explicit arg > $REPRO_CONVTUNE_CACHE >
    ~/.cache/repro/convtune.json."""
    if path:
        return path
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "convtune.json")


def reset_memory_cache() -> None:
    """Drop the in-process cache memo (tests / after external writes)."""
    _MEM.clear()
    _WARNED.clear()


def _quarantine(path: str, reason: str) -> None:
    """Move an unusable cache file aside (never silently discard it)."""
    dest = f"{path}.corrupt-{os.getpid()}"
    try:
        os.replace(path, dest)
    except OSError:
        dest = "<unmovable>"
    warnings.warn(
        f"autotune cache {path} is unusable ({reason}); quarantined to "
        f"{dest} and starting a fresh cache", RuntimeWarning,
        stacklevel=3)


def _read_disk(path: str) -> dict:
    """Fresh (un-memoized) read of the on-disk entries.

    A missing file is an empty cache.  Corrupt JSON, a non-dict
    document, or an empty file is quarantined.  A ``version`` other than
    ours is also quarantined: version 1 is the first schema, so there is
    nothing to migrate from — a future reader that understands newer
    versions should migrate here instead; until then the file is
    preserved under its ``.corrupt-<pid>`` name for inspection rather
    than silently dropped.
    """
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, ValueError) as e:
        _quarantine(path, f"unreadable: {type(e).__name__}: {e}")
        return {}
    if not isinstance(data, dict) or not isinstance(
            data.get("entries", {}), dict):
        _quarantine(path, "not a cache document")
        return {}
    version = data.get("version")
    if version != _SCHEMA_VERSION:
        _quarantine(path, f"schema version {version!r} != "
                          f"{_SCHEMA_VERSION} (no migration path)")
        return {}
    return dict(data["entries"]) if "entries" in data else {}


def _entries(path: str) -> dict:
    if path not in _MEM:
        _MEM[path] = _read_disk(path)
    return _MEM[path]


@contextlib.contextmanager
def _locked(path: str):
    """Hold the cache's ``.lock`` sidecar (blocking flock) — serializes
    the read-merge-replace in :func:`store` across processes.  The
    sidecar (not the cache file itself) carries the lock so the atomic
    ``os.replace`` of the data file never invalidates a held fd."""
    if fcntl is None:
        yield
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path + ".lock", "w") as lk:
        fcntl.flock(lk, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lk, fcntl.LOCK_UN)


def lookup(key: str, path: str | None = None) -> dict | None:
    """Cached record for ``key``, or None."""
    return _entries(cache_path(path)).get(key)


def store(key: str, record: dict, path: str | None = None) -> str:
    """Insert/overwrite one record and persist the cache atomically.

    Under the ``.lock`` sidecar: re-read the on-disk entries and merge
    them over the in-memory memo (disk wins per key — last writer wins,
    no lost updates), apply this record, write a temp file, and publish
    with an atomic rename."""
    path = cache_path(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with _locked(path):
        merged = {**_MEM.get(path, {}), **_read_disk(path)}
        merged[key] = dict(record)
        _MEM[path] = merged
        tmp = f"{path}.tmp-{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": _SCHEMA_VERSION, "entries": merged}, f,
                      indent=1, sort_keys=True)
        try:
            _publish(tmp, path)
        except BaseException:
            # a simulated (or real) crash-before-publish must not leave
            # the temp file looking like a cache
            with contextlib.suppress(OSError):
                os.remove(tmp)
            raise
    return path


# ---------------------------------------------------------------------------
# Keys
# ---------------------------------------------------------------------------

def make_key(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
             groups: int = 1, dtype: str = "float32",
             backend: str | None = None, op: str = "conv2d") -> str:
    """Cache key for one conv problem.  ``x_shape`` is the shape the
    kernel actually sees (i.e. *after* any 'same' pre-padding, with
    ``pad`` the residual symmetric padding).  ``op`` namespaces the
    record: ``"conv2d"`` for forward (and the input-grad conv, which IS
    a forward problem over its transformed shapes), ``"conv2d_wgrad"``
    for the weight-gradient kernel — backward records can never collide
    with forward ones even when the raw shape tuple matches."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    n, h, w, cin = x_shape
    kh, kw, _, cout = w_shape
    return (f"{op}:n{n}h{h}w{w}cin{cin}cout{cout}k{kh}x{kw}"
            f"s{stride}p{pad}g{groups}:{dtype}:{backend}")


def _valid_record(rec, stride: int) -> bool:
    return (isinstance(rec, dict)
            and isinstance(rec.get("tile_h"), int)
            and isinstance(rec.get("tile_cout"), int)
            and rec.get("dataflow") in DATAFLOWS
            and rec["tile_h"] >= stride and rec["tile_h"] % stride == 0
            and rec["tile_cout"] >= 1)


def _reject(key: str, reason: str, path: str | None) -> None:
    """Treat a bad record as a miss; warn once per (path, key) so a
    hand-edited/truncated record is visible without flooding the hot
    path (one conv may be called millions of times)."""
    tag = (cache_path(path), key)
    if tag in _WARNED:
        return
    _WARNED.add(tag)
    warnings.warn(
        f"ignoring malformed autotune record {key!r}: {reason} "
        "(treated as a cache miss; delete or re-tune the entry)",
        RuntimeWarning, stacklevel=3)


def knobs_for(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
              groups: int = 1, dtype: str = "float32",
              backend: str | None = None, op: str = "conv2d",
              path: str | None = None) -> dict | None:
    """The cached (validated) knobs for a problem, or None — the lookup
    ``ops.conv2d`` performs by default.  Honors ``REPRO_CONV_AUTOTUNE=0``.

    Validation is structural (required keys/types/knob invariants) AND
    geometric: the record's knobs must build a :class:`ConvPlan` for the
    *current* problem.  Either failure is a miss + one warning — a
    truncated or hand-edited record degrades to the default plan instead
    of raising ``KeyError`` inside the dispatch path.
    """
    if os.environ.get(AUTOTUNE_ENV, "1") == "0":
        return None
    key = make_key(x_shape, w_shape, stride=stride, pad=pad,
                   groups=groups, dtype=dtype, backend=backend, op=op)
    rec = lookup(key, path)
    if rec is None:
        return None
    if not _valid_record(rec, stride):
        _reject(key, f"bad shape/type/knobs: {rec!r}", path)
        return None
    try:        # knob sanity against the current plan geometry
        plan = ConvPlan.build(x_shape, w_shape, stride=stride, pad=pad,
                              groups=groups, dtype_bytes=dtype_width(dtype),
                              tile_h=rec["tile_h"],
                              tile_cout=rec["tile_cout"],
                              dataflow=rec["dataflow"])
        if plan.vmem_resident_bytes > VMEM_BYTES:
            raise ValueError(
                f"resident {plan.vmem_resident_bytes} > VMEM "
                f"{VMEM_BYTES} (the tuner only writes feasible plans)")
    except ValueError as e:
        _reject(key, f"knobs infeasible for current geometry: {e}", path)
        return None
    return rec


def _valid_wgrad_record(rec) -> bool:
    return (isinstance(rec, dict)
            and isinstance(rec.get("tile_go"), int)
            and isinstance(rec.get("tile_cout"), int)
            and rec["tile_go"] >= 1 and rec["tile_cout"] >= 1)


def weight_grad_knobs_for(x_shape, w_shape, *, stride: int = 1,
                          pad: int = 0, groups: int = 1,
                          dtype: str = "float32",
                          backend: str | None = None,
                          path: str | None = None) -> dict | None:
    """Cached (validated) knobs for the weight-gradient kernel of one
    forward problem, or None — the lookup the conv backward pass
    performs by default.  Honors ``REPRO_CONV_AUTOTUNE=0``."""
    if os.environ.get(AUTOTUNE_ENV, "1") == "0":
        return None
    key = make_key(x_shape, w_shape, stride=stride, pad=pad,
                   groups=groups, dtype=dtype, backend=backend,
                   op="conv2d_wgrad")
    rec = lookup(key, path)
    if rec is None:
        return None
    if not _valid_wgrad_record(rec):
        _reject(key, f"bad shape/type/knobs: {rec!r}", path)
        return None
    try:
        plan = ConvPlan.build_weight_grad(x_shape, w_shape, stride=stride,
                                          pad=pad, groups=groups,
                                          tile_go=rec["tile_go"],
                                          tile_cout=rec["tile_cout"])
        if plan.vmem_resident_bytes > VMEM_BYTES:
            raise ValueError(
                f"resident {plan.vmem_resident_bytes} > VMEM "
                f"{VMEM_BYTES} (the tuner only writes feasible plans)")
    except ValueError as e:
        _reject(key, f"knobs infeasible for current geometry: {e}", path)
        return None
    return rec


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

def candidate_knobs(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
                    groups: int = 1, dtype_bytes: int = 4,
                    vmem_bytes: int = VMEM_BYTES) -> list[ConvPlan]:
    """VMEM-feasible candidate plans over (tile_h, tile_cout, dataflow).

    Strip-height ticks cover powers of two plus the two structurally
    special points: the auto default and the full-height strip
    ``(h_out + delta) * stride`` that collapses the grid to one strip per
    (image, group) — zero carry/halo traffic and the fewest grid steps.
    """
    base = ConvPlan.build(x_shape, w_shape, stride=stride, pad=pad,
                          groups=groups, dtype_bytes=dtype_bytes)
    s = base.stride
    full_h = (base.h_out + base.delta) * s
    h_ticks = sorted({t for t in (s, 2 * s, 4 * s, 8 * s, 16 * s, 32 * s,
                                  base.tile_h, full_h) if t <= full_h})
    cout_pg = base.cout_per_group
    c_ticks = sorted({t for t in (32, 64, 128, 256, base.tile_cout,
                                  cout_pg) if t <= cout_pg})
    plans = []
    for dataflow in DATAFLOWS:
        for th in h_ticks:
            for tc in c_ticks:
                try:
                    plan = ConvPlan.build(
                        x_shape, w_shape, stride=stride, pad=pad,
                        groups=groups, dtype_bytes=dtype_bytes,
                        tile_h=th, tile_cout=tc, dataflow=dataflow)
                except ValueError:
                    continue
                if plan.vmem_resident_bytes <= vmem_bytes:
                    plans.append(plan)
    return plans


def _model_score(plan: ConvPlan) -> tuple:
    """Deterministic comparison key: modeled step time, then total HBM
    bytes, then prefer the order-independent halo grid on exact ties
    (its axes parallelize; the model cannot see that), then fewer grid
    steps."""
    terms = conv_plan_roofline("tune", plan)
    steps = plan.g_tiles * plan.co_tiles
    return (terms.step_time_s, plan.hbm_bytes()["total"],
            0 if plan.dataflow == "halo" else 1, steps, plan.tile_cout)


def _as_record(plan: ConvPlan, *, source: str,
               measured_us: float | None = None) -> dict:
    return dict(tile_h=plan.tile_h, tile_cout=plan.tile_cout,
                dataflow=plan.dataflow, source=source,
                model_step_time_s=conv_plan_roofline("tune",
                                                     plan).step_time_s,
                measured_us=measured_us)


def _measure_plan(plan: ConvPlan, *, stride, pad, groups,
                  dtype: str = "float32", warmup: int = 1,
                  iters: int = 2) -> float:
    """Wall-clock the real kernel for one candidate (us per call)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.trim_conv2d import trim_conv2d
    rng = np.random.default_rng(0)
    dt = jnp.dtype(dtype)
    scale = None
    if jnp.issubdtype(dt, jnp.integer):
        # the int8 route: integer operands + a unit dequant scale row
        # (the knobs are timing-relevant, the calibration is not)
        x = jnp.asarray(rng.integers(-128, 128,
                                     (plan.n, plan.h, plan.w, plan.cin)), dt)
        w = jnp.asarray(rng.integers(-128, 128,
                                     (plan.kh, plan.kw, plan.cin_per_group,
                                      plan.cout)), dt)
        scale = jnp.ones((plan.cout,), jnp.float32)
    else:
        x = jnp.asarray(rng.standard_normal(
            (plan.n, plan.h, plan.w, plan.cin)), dt)
        w = jnp.asarray(rng.standard_normal(
            (plan.kh, plan.kw, plan.cin_per_group, plan.cout)) * 0.1, dt)

    def call():
        trim_conv2d(x, w, None, scale, stride=stride, pad=pad,
                    groups=groups, tile_h=plan.tile_h,
                    tile_cout=plan.tile_cout,
                    dataflow=plan.dataflow).block_until_ready()

    for _ in range(warmup):
        call()
    t0 = time.perf_counter()
    for _ in range(iters):
        call()
    return (time.perf_counter() - t0) / iters * 1e6


def tune(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
         groups: int = 1, dtype: str = "float32",
         dtype_bytes: int | None = None,
         backend: str | None = None, op: str = "conv2d",
         measure: bool = False,
         measure_top_k: int = 4, write: bool = True,
         path: str | None = None) -> dict:
    """Tune one conv problem and (by default) persist the winner.

    Model-guided: candidates are ranked by the plan's analytical roofline
    step time.  With ``measure=True`` the ``measure_top_k`` leaders are
    wall-clocked through the actual kernel and the fastest wins — this is
    how grid-step overheads the byte model cannot see (e.g. per-step
    interpreter cost, pipeline ramp) get captured.
    """
    plans = candidate_knobs(x_shape, w_shape, stride=stride, pad=pad,
                            groups=groups,
                            dtype_bytes=_resolve_bytes(dtype_bytes, dtype))
    if not plans:
        raise ValueError(f"no feasible candidates for {x_shape}/{w_shape}")
    ranked = sorted(plans, key=_model_score)
    if measure:
        leaders = ranked[:measure_top_k]
        timed = [(_measure_plan(p, stride=stride, pad=pad, groups=groups,
                                dtype=dtype),
                  i, p) for i, p in enumerate(leaders)]
        us, _, best = min(timed)
        record = _as_record(best, source="measured", measured_us=us)
    else:
        record = _as_record(ranked[0], source="model")
    if write:
        store(make_key(x_shape, w_shape, stride=stride, pad=pad,
                       groups=groups, dtype=dtype, backend=backend, op=op),
              record, path)
    return record


# ---------------------------------------------------------------------------
# Backward shapes (DESIGN.md §5)
# ---------------------------------------------------------------------------

def candidate_weight_grad_knobs(x_shape, w_shape, *, stride: int = 1,
                                pad: int = 0, groups: int = 1,
                                dtype_bytes: int = 4,
                                vmem_bytes: int = VMEM_BYTES) -> list:
    """VMEM-feasible ``WeightGradPlan`` candidates over
    (tile_go, tile_cout) — cotangent-strip ticks at powers of two plus
    the full-height strip, per-group C_out tiles as in the forward
    search."""
    base = ConvPlan.build_weight_grad(x_shape, w_shape, stride=stride,
                                      pad=pad, groups=groups,
                                      dtype_bytes=dtype_bytes)
    go_ticks = sorted({t for t in (1, 2, 4, 8, 16, 32, base.tile_go,
                                   base.h_out) if t <= base.h_out})
    cout_pg = base.cout_per_group
    c_ticks = sorted({t for t in (32, 64, 128, base.tile_cout, cout_pg)
                      if t <= cout_pg})
    plans = []
    for tg in go_ticks:
        for tc in c_ticks:
            try:
                plan = ConvPlan.build_weight_grad(
                    x_shape, w_shape, stride=stride, pad=pad,
                    groups=groups, dtype_bytes=dtype_bytes, tile_go=tg,
                    tile_cout=tc)
            except ValueError:
                continue
            if plan.vmem_resident_bytes <= vmem_bytes:
                plans.append(plan)
    return plans


def tune_weight_grad(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
                     groups: int = 1, dtype: str = "float32",
                     dtype_bytes: int | None = None,
                     backend: str | None = None,
                     write: bool = True, path: str | None = None) -> dict:
    """Tune the weight-gradient kernel for one forward problem and (by
    default) persist the winner under its ``conv2d_wgrad`` key.  Ranked
    by the plan's analytical roofline step time; fewer grid steps win
    ties (the accumulating output block serializes the sweep, so grid
    overhead is pure latency)."""
    plans = candidate_weight_grad_knobs(x_shape, w_shape, stride=stride,
                                        pad=pad, groups=groups,
                                        dtype_bytes=_resolve_bytes(
                                            dtype_bytes, dtype))
    if not plans:
        raise ValueError(f"no feasible wgrad candidates for "
                         f"{x_shape}/{w_shape}")
    def score(p):
        terms = conv_plan_roofline("tune", p)
        return (terms.step_time_s, p.hbm_bytes()["total"],
                p.go_tiles * p.co_tiles, p.tile_cout)
    best = min(plans, key=score)
    record = dict(tile_go=best.tile_go, tile_cout=best.tile_cout,
                  source="model",
                  model_step_time_s=conv_plan_roofline(
                      "tune", best).step_time_s, measured_us=None)
    if write:
        store(make_key(x_shape, w_shape, stride=stride, pad=pad,
                       groups=groups, dtype=dtype, backend=backend,
                       op="conv2d_wgrad"), record, path)
    return record


# ---------------------------------------------------------------------------
# Sharded shapes (DESIGN.md §6)
# ---------------------------------------------------------------------------

def sharded_key_op(batch_shards: int, spatial_shards: int) -> str:
    """The op namespace of a sharded conv record:
    ``conv2d_shard:<ndev>:b<bs>x<ss>`` — the device count AND the
    (batch, spatial) split are part of the namespace because both change
    the per-shard strip geometry: a knob tuned on one shard grid must
    never be served to another split of the same size, to a different
    mesh size, or to the single-device path."""
    ndev = int(batch_shards) * int(spatial_shards)
    return (f"conv2d_shard:{ndev}:"
            f"b{int(batch_shards)}x{int(spatial_shards)}")


def sharded_knobs_for(x_shape, w_shape, *, batch_shards: int = 1,
                      spatial_shards: int = 1, stride: int = 1,
                      pad: int = 0, groups: int = 1,
                      dtype: str = "float32", backend: str | None = None,
                      path: str | None = None) -> dict | None:
    """Cached (validated) knobs for one sharded conv problem, or None —
    the lookup the ``ops.conv2d(..., mesh=)`` path performs.  Keys are
    the *global* kernel-seen shape under the shard-grid namespace of
    :func:`sharded_key_op`.  Honors ``REPRO_CONV_AUTOTUNE=0``."""
    if os.environ.get(AUTOTUNE_ENV, "1") == "0":
        return None
    key = make_key(x_shape, w_shape, stride=stride, pad=pad,
                   groups=groups, dtype=dtype, backend=backend,
                   op=sharded_key_op(batch_shards, spatial_shards))
    rec = lookup(key, path)
    if rec is None:
        return None
    if not _valid_record(rec, stride):
        _reject(key, f"bad shape/type/knobs: {rec!r}", path)
        return None
    try:
        from repro.core.conv_shard import ShardedConvPlan
        plan = ShardedConvPlan.build(
            x_shape, w_shape, stride=stride, pad=pad, groups=groups,
            tile_h=rec["tile_h"], tile_cout=rec["tile_cout"],
            dataflow=rec["dataflow"], batch_shards=batch_shards,
            spatial_shards=spatial_shards)
        if plan.local_plan().vmem_resident_bytes > VMEM_BYTES:
            raise ValueError(
                "per-shard resident bytes exceed VMEM "
                "(the tuner only writes feasible plans)")
    except ValueError as e:
        _reject(key, f"knobs infeasible for current geometry: {e}", path)
        return None
    return rec


def tune_sharded(x_shape, w_shape, *, batch_shards: int = 1,
                 spatial_shards: int = 1, stride: int = 1, pad: int = 0,
                 groups: int = 1, dtype: str = "float32",
                 dtype_bytes: int | None = None,
                 backend: str | None = None,
                 write: bool = True, path: str | None = None) -> dict:
    """Tune one *sharded* conv problem and (by default) persist the
    winner under its ``conv2d_shard:<ndev>`` key.

    Candidates are the VMEM-feasible knobs of the *per-shard* problem
    (the assembled local window — device count changes the strip
    geometry, which is why sharded records are namespaced), scored by
    the sharded roofline: ``max(T_comp, T_mem, T_collective)`` with the
    cross-device halo bytes on the collective term.
    """
    from repro.core.conv_shard import ShardedConvPlan
    from repro.core.roofline import sharded_conv_roofline
    dtype_bytes = _resolve_bytes(dtype_bytes, dtype)
    base = ShardedConvPlan.build(x_shape, w_shape, stride=stride, pad=pad,
                                 groups=groups, dtype_bytes=dtype_bytes,
                                 batch_shards=batch_shards,
                                 spatial_shards=spatial_shards)
    local = candidate_knobs(base.local_x_shape, w_shape, stride=stride,
                            pad=0, groups=groups, dtype_bytes=dtype_bytes)
    if not local:
        raise ValueError(f"no feasible sharded candidates for "
                         f"{x_shape}/{w_shape}")
    plans = [ShardedConvPlan.build(
        x_shape, w_shape, stride=stride, pad=pad, groups=groups,
        dtype_bytes=dtype_bytes, tile_h=p.tile_h, tile_cout=p.tile_cout,
        dataflow=p.dataflow, batch_shards=batch_shards,
        spatial_shards=spatial_shards) for p in local]

    def score(p):
        terms = sharded_conv_roofline("tune", p)
        return (terms.step_time_s, p.sharded_traffic()["total"],
                0 if p.dataflow == "halo" else 1,
                p.local_plan().g_tiles, p.tile_cout)

    best = min(plans, key=score)
    record = dict(tile_h=best.tile_h, tile_cout=best.tile_cout,
                  dataflow=best.dataflow, source="model",
                  model_step_time_s=sharded_conv_roofline(
                      "tune", best).step_time_s, measured_us=None)
    if write:
        store(make_key(x_shape, w_shape, stride=stride, pad=pad,
                       groups=groups, dtype=dtype, backend=backend,
                       op=sharded_key_op(batch_shards, spatial_shards)),
              record, path)
    return record


# ---------------------------------------------------------------------------
# Whole-network sweep (DESIGN.md §7)
# ---------------------------------------------------------------------------

def tune_network(network="vgg16", *, n: int = 1, dtype: str = "float32",
                 dtype_bytes: int | None = None,
                 backend: str | None = None, op: str = "conv2d",
                 batch_shards: int = 1, spatial_shards: int = 1,
                 measure: bool = False, include_backward: bool = False,
                 write: bool = True, path: str | None = None) -> dict:
    """Tune every conv layer of a topology in one sweep.

    ``network`` is a name ("vgg16" | "alexnet" | "mobilenet") or an
    explicit ``list[ConvLayer]`` (e.g. a :func:`~repro.core.netplan.
    scale_layers` reduction).  Each layer is tuned over the *kernel-seen*
    shape (the 'same' pre-pad folded in, exactly the key ``ops.conv2d``
    looks up at call time), so after one sweep the whole forward pass of
    ``examples/cnn_inference.py --net ...`` runs on cached plans.  With
    a shard grid the records land under the ``conv2d_shard:`` namespace
    instead.  Layers sharing a shape (VGG-16's repeated blocks) are
    tuned once; layers with ``K > MAX_NATIVE_K`` (AlexNet's 11x11) run
    on the kernel-tiled path that never consults the cache and are
    recorded as skipped.  ``include_backward`` additionally seeds both
    cotangent records per layer (:func:`tune_backward`).  ``op`` selects
    the single-device key namespace (``"conv2d_q8"`` seeds the int8
    inference path; pair it with ``dtype="int8"``).

    Returns ``{layer_name: record}`` with ``record["key"]`` the cache
    key written (or ``{"skipped": reason}``).
    """
    from repro.core.netplan import layer_kernel_problem, network_layers
    from repro.kernels.ops import MAX_NATIVE_K
    sharded = batch_shards > 1 or spatial_shards > 1
    if measure and sharded:
        raise ValueError(
            "measure=True is not supported with a shard grid: "
            "tune_sharded ranks by the sharded roofline model only")
    results: dict[str, dict] = {}
    seen: dict[str, dict] = {}
    for layer in network_layers(network):
        if layer.name in results:
            # results are keyed by layer name; a silent overwrite would
            # make the returned dict undercount the topology
            raise ValueError(
                f"duplicate layer name {layer.name!r} in topology; "
                "give repeated blocks unique names")
        if layer.kernel > MAX_NATIVE_K:
            results[layer.name] = {
                "skipped": f"K={layer.kernel} > {MAX_NATIVE_K}: "
                           "kernel-tiled path (no cache)"}
            continue
        # the shared layer -> executed-problem mapping (raises on
        # padding the execution path cannot reproduce)
        x_shape, pad, w_shape, _ = layer_kernel_problem(layer, n=n)
        layer_op = op if not sharded \
            else sharded_key_op(batch_shards, spatial_shards)
        key = make_key(x_shape, w_shape, stride=layer.stride, pad=pad,
                       groups=layer.groups, dtype=dtype, backend=backend,
                       op=layer_op)
        if key in seen:
            results[layer.name] = seen[key]
            continue
        common = dict(stride=layer.stride, pad=pad, groups=layer.groups,
                      dtype=dtype, dtype_bytes=dtype_bytes,
                      backend=backend, write=write, path=path)
        if sharded:
            rec = tune_sharded(x_shape, w_shape,
                               batch_shards=batch_shards,
                               spatial_shards=spatial_shards, **common)
        else:
            rec = tune(x_shape, w_shape, measure=measure, op=layer_op,
                       **common)
        rec = dict(rec, key=key)
        if include_backward and not sharded:
            rec["backward"] = tune_backward(x_shape, w_shape, **common)
        seen[key] = rec
        results[layer.name] = rec
    return results


def tune_graph(graph, *, n: int = 1, dtype: str = "float32",
               dtype_bytes: int | None = None,
               backend: str | None = None, op: str = "conv2d",
               fused: bool = False, measure: bool = False,
               include_backward: bool = False, write: bool = True,
               path: str | None = None) -> dict:
    """Tune every conv node of a DAG topology in one sweep — the graph
    analogue of :func:`tune_network`.

    ``graph`` is anything ``core.netplan.graph_nodes`` resolves
    ("resnet18" | "unet" | ``list[GraphNode]`` | a linear topology).
    Conv nodes key the same ``conv2d:`` namespace over the same
    kernel-seen shapes (node names are unique by graph validation, and
    nodes sharing a problem — ResNet's repeated blocks — are tuned
    once), so ``cnn_apply_from_graph`` / ``cnn_pack_params_from_graph``
    run on cached plans afterwards.  Joins execute as jnp epilogues and
    have nothing to tune.  ``fused=True`` additionally sweeps each
    fusable linear segment (``core.fuse_plan.graph_segments``) through
    :func:`tune_fused_network`, seeding the ``conv2d_fused:`` records
    the segment megakernels consult.

    Returns ``{"layers": {node: record}[, "fused": {segment: record}]}``.
    """
    from repro.core.netplan import graph_nodes
    nodes = graph_nodes(graph)
    layers = [nd.layer for nd in nodes if nd.op == "conv"]
    out = {"layers": tune_network(
        layers, n=n, dtype=dtype, dtype_bytes=dtype_bytes,
        backend=backend, op=op, measure=measure,
        include_backward=include_backward, write=write, path=path)}
    if fused:
        from repro.core.fuse_plan import graph_segments
        fused_recs: dict[str, dict] = {}
        for names, seg_layers in graph_segments(nodes):
            if len(seg_layers) < 2:
                continue
            fused_recs.update(tune_fused_network(
                list(seg_layers), n=n, dtype=dtype,
                dtype_bytes=dtype_bytes, backend=backend, write=write,
                path=path))
        out["fused"] = fused_recs
    return out


def prewarm_buckets(network, buckets, *, dtype: str = "float32",
                    dtype_bytes: int | None = None,
                    backend: str | None = None, op: str = "conv2d",
                    batch_shards: int = 1, spatial_shards: int = 1,
                    fused: bool = False, include_backward: bool = False,
                    measure: bool = False, write: bool = True,
                    path: str | None = None) -> dict:
    """Warm the plan cache across a serving bucket grid (DESIGN.md §10).

    Runs :func:`tune_network` once per batch bucket — every conv layer
    of ``network`` tuned at every bucket's kernel-seen shape, so no
    serving request (whose batch is always rounded up to a bucket) ever
    hits a cold tune.  ``fused=True`` additionally sweeps
    :func:`tune_fused_network` per bucket, seeding the
    ``conv2d_fused:`` group records the megakernel path consults.
    Buckets are deduplicated and swept ascending, so concurrent
    prewarmers (multiple serving replicas starting at once) write the
    same records in the same order and merge cleanly through the
    flock+merge store.

    Returns ``{bucket: {"layers": tune_network results[, "fused":
    tune_fused_network results]}}``.
    """
    results: dict[int, dict] = {}
    for n in sorted({int(b) for b in buckets}):
        if n < 1:
            raise ValueError(f"batch bucket must be >= 1, got {n}")
        per = {"layers": tune_network(
            network, n=n, dtype=dtype, dtype_bytes=dtype_bytes,
            backend=backend, op=op, batch_shards=batch_shards,
            spatial_shards=spatial_shards, measure=measure,
            include_backward=include_backward, write=write, path=path)}
        if fused:
            per["fused"] = tune_fused_network(
                network, n=n, dtype=dtype, dtype_bytes=dtype_bytes,
                backend=backend, write=write, path=path)
        results[n] = per
    return results


def tune_backward(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
                  groups: int = 1, dtype: str = "float32",
                  dtype_bytes: int | None = None,
                  backend: str | None = None,
                  measure: bool = False, write: bool = True,
                  path: str | None = None) -> dict:
    """Tune both cotangents of one forward problem.

    The input-gradient conv IS a forward problem over its transformed
    (stride-dilated, edge-padded) shapes, so it reuses :func:`tune` —
    and its record lands under the plain ``conv2d`` key of that
    transformed problem, exactly where the backward pass looks it up.
    The weight-gradient kernel gets its own ``conv2d_wgrad`` record.
    Returns ``{"input_grad": rec, "weight_grad": rec}``.
    """
    geo = input_grad_geometry(x_shape, w_shape, stride=stride, pad=pad,
                              groups=groups)
    igrad = tune(geo["g_padded_shape"], geo["wt_shape"], stride=1, pad=0,
                 groups=groups, dtype=dtype, dtype_bytes=dtype_bytes,
                 backend=backend, measure=measure, write=write, path=path)
    wgrad = tune_weight_grad(x_shape, w_shape, stride=stride, pad=pad,
                             groups=groups, dtype=dtype,
                             dtype_bytes=dtype_bytes, backend=backend,
                             write=write, path=path)
    return {"input_grad": igrad, "weight_grad": wgrad}


# ---------------------------------------------------------------------------
# Fused residency groups (DESIGN.md §8)
# ---------------------------------------------------------------------------

def fused_key(signature: str, *, n: int = 1, dtype: str = "float32",
              backend: str | None = None) -> str:
    """Cache key for one fused residency group.

    ``signature`` is the group's per-stage signature chain
    (:attr:`~repro.core.fuse_plan.FusedGroup.signature` — per-stage
    problem geometry joined with ``-``), so the namespace is
    ``conv2d_fused:d<depth>:n<n>:<chain>:<dtype>:<backend>``.  The
    ``conv2d_fused`` prefix guarantees a fused record can never alias a
    per-layer ``conv2d:``, ``conv2d_wgrad:`` or ``conv2d_shard:`` key,
    and depth + chain make distinct groups distinct even when they share
    a leading stage.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    depth = signature.count("-") + 1 if signature else 0
    return f"conv2d_fused:d{depth}:n{n}:{signature}:{dtype}:{backend}"


def _valid_fused_record(rec) -> bool:
    return (isinstance(rec, dict)
            and isinstance(rec.get("strip_rows"), int)
            and rec["strip_rows"] >= 1)


def fused_knobs_for(signature: str, *, n: int = 1, dtype: str = "float32",
                    backend: str | None = None,
                    path: str | None = None) -> dict | None:
    """The cached (validated) group knob for a fused-group signature, or
    None — the lookup ``FusedGroupPlan.build(use_autotune_cache=True)``
    performs.  Honors ``REPRO_CONV_AUTOTUNE=0``."""
    if os.environ.get(AUTOTUNE_ENV, "1") == "0":
        return None
    key = fused_key(signature, n=n, dtype=dtype, backend=backend)
    rec = lookup(key, path)
    if rec is None:
        return None
    if not _valid_fused_record(rec):
        _reject(key, f"bad shape/type/knobs: {rec!r}", path)
        return None
    return rec


def tune_fused(layers, *, start: int = 0, pools=None, n: int = 1,
               dtype: str = "float32", dtype_bytes: int | None = None,
               backend: str | None = None, vmem_budget: int | None = None,
               write: bool = True, path: str | None = None) -> dict:
    """Tune the strip height of one fused group (a layer chain) and (by
    default) persist the winner under its ``conv2d_fused:`` key.

    Candidates are the VMEM-feasible power-of-two strip heights of the
    group; each is scored by the *grouped roofline* — the fused
    schedule's executed bytes (overlapping stage-0 windows + per-strip
    weight streams + pooled output) against the group's FLOPs — and the
    minimal modeled step time wins, with total bytes then fewer strips
    as tie-breakers.
    """
    from repro.core.fuse_plan import (FUSED_VMEM_BUDGET, build_group,
                                      _strip_candidates)
    from repro.core.roofline import conv_plan_roofline
    dtype_bytes = _resolve_bytes(dtype_bytes, dtype)
    if vmem_budget is None:
        vmem_budget = FUSED_VMEM_BUDGET
    probe = build_group(layers, start, n=n, strip_rows=1,
                        dtype_bytes=dtype_bytes, pools=pools)
    feasible = []
    for t in _strip_candidates(probe.last.h_pool):
        g = build_group(layers, start, n=n, strip_rows=t,
                        dtype_bytes=dtype_bytes, pools=pools)
        if g.vmem_resident_bytes <= vmem_budget:
            feasible.append(g)
    if not feasible:
        raise ValueError(
            f"no VMEM-feasible strip height for fused group "
            f"{probe.signature} (budget {vmem_budget})")

    def score(g):
        terms = conv_plan_roofline("tune", g)
        return (terms.step_time_s, g.hbm_bytes()["total"], g.n_strips)

    best = min(feasible, key=score)
    record = dict(strip_rows=best.strip_rows, depth=best.depth,
                  source="model",
                  model_step_time_s=conv_plan_roofline(
                      "tune", best).step_time_s,
                  hbm_total=best.hbm_bytes()["total"], measured_us=None)
    if write:
        store(fused_key(best.signature, n=n, dtype=dtype, backend=backend),
              record, path)
    return record


def tune_fused_network(network="vgg16", *, n: int = 1,
                       dtype: str = "float32",
                       dtype_bytes: int | None = None,
                       backend: str | None = None,
                       residency: str = "auto",
                       write: bool = True, path: str | None = None) -> dict:
    """Tune every fused residency group of a topology in one sweep.

    Partitions the network with :class:`~repro.core.fuse_plan.
    FusedGroupPlan` (model-driven, no cache) and writes one
    ``conv2d_fused:`` record per depth>=2 group, so a subsequent
    ``FusedGroupPlan.build(use_autotune_cache=True)`` — and therefore
    ``cnn_apply_from_layers(..., fused=True)`` — runs on cached group
    knobs.  Returns ``{"<first>..<last>": record}`` per fused group.
    """
    from repro.core.fuse_plan import FusedGroupPlan
    from repro.core.netplan import infer_pools, network_layers
    layers = list(network_layers(network))
    pools = list(infer_pools(layers))
    dtype_bytes = _resolve_bytes(dtype_bytes, dtype)
    plan = FusedGroupPlan.build(layers, n=n, dtype_bytes=dtype_bytes,
                                residency=residency)
    results: dict[str, dict] = {}
    for g in plan.groups:
        if not g.fused:
            continue
        sub = layers[g.start:g.start + g.depth]
        rec = tune_fused(sub, start=g.start,
                         pools=pools[g.start:g.start + g.depth], n=n,
                         dtype=dtype, dtype_bytes=dtype_bytes,
                         backend=backend, write=write, path=path)
        rec = dict(rec, key=fused_key(g.signature, n=n, dtype=dtype,
                                      backend=backend))
        results[f"{sub[0].name}..{sub[-1].name}"] = rec
    return results
