"""Three-term roofline analysis from compiled XLA artifacts.

The container is CPU-only; TPU v5e is the *target*.  We derive, per
(architecture x shape x mesh) dry-run cell:

    T_compute    = FLOPs_per_device      / PEAK_FLOPS        (197 TFLOP/s bf16)
    T_memory     = HBM_bytes_per_device  / HBM_BW            (819 GB/s)
    T_collective = wire_bytes_per_device / ICI_BW            (50 GB/s/link)

``compiled.cost_analysis()`` reports **per-device** flops / bytes on this
backend (verified against a hand-computed sharded einsum).  Collective wire
bytes are not in cost_analysis, so we parse the post-optimization HLO text
and apply ring-algorithm wire-cost formulas per collective kind:

    all-reduce       2 * S * (g-1)/g      (reduce-scatter + all-gather)
    all-gather       S_out * (g-1)/g
    reduce-scatter   S_in * (g-1)/g  ==  S_out * (g-1)
    all-to-all       S * (g-1)/g
    collective-permute  S                 (point-to-point)

where S is the per-device tensor size in the HLO and g the replica-group
size.  This counts each byte once per link traversal on a ring; a real
torus has multiple links per axis, so T_collective is an upper bound
(documented in EXPERIMENTS.md).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e-like hardware constants (per chip).
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# numpy/jax spellings of the HLO names above, so every subsystem (plans,
# autotune, energy, HLO parsing) prices widths from this one table.
_DTYPE_NAME_ALIASES = {
    "bool": "pred", "int4": "s4", "uint4": "u4", "int8": "s8",
    "uint8": "u8", "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
    "int16": "s16", "uint16": "u16", "bfloat16": "bf16", "float16": "f16",
    "int32": "s32", "uint32": "u32", "float32": "f32", "int64": "s64",
    "uint64": "u64", "float64": "f64", "complex64": "c64",
    "complex128": "c128",
}


def dtype_width(dtype) -> int:
    """Byte width of ``dtype`` — the single width table for every plan.

    Accepts HLO names (``"f32"``, ``"s8"``), numpy/jax names
    (``"float32"``, ``"int8"``, ``"bfloat16"``) and dtype objects
    (``jnp.bfloat16``, ``np.dtype("float32")``, an array's ``.dtype``).
    """
    if isinstance(dtype, str):
        name = dtype
    else:
        import numpy as np
        name = np.dtype(dtype).name
    name = _DTYPE_NAME_ALIASES.get(name, name)
    try:
        return _DTYPE_BYTES[name]
    except KeyError:
        raise ValueError(f"unknown dtype {dtype!r}") from None

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# op line: `%name = <result shapes> <op-kind>(`  or  `ROOT %name = ...`
_OP_LINE_RE = re.compile(
    r"=\s*(?P<result>\(?[\w\[\],{}\s/#*]*?\)?)\s*"
    r"(?P<kind>all-reduce-start|all-gather-start|reduce-scatter|"
    r"all-to-all|collective-permute-start|all-reduce|all-gather|"
    r"collective-permute)\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    """Total bytes of every dtype[shape] token in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return n_devices


@dataclass
class CollectiveStats:
    """Wire bytes per device, split by collective kind."""

    by_kind: dict = field(default_factory=dict)
    ops: list = field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return sum(self.by_kind.values())


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if m is None:
            continue
        kind = m.group("kind").replace("-start", "")
        size = _shape_bytes(m.group("result"))
        g = _group_size(line, n_devices)
        if g <= 1 or size == 0:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            wire = 2 * size * frac
        elif kind == "all-gather":
            wire = size * frac           # result is the gathered size
        elif kind == "reduce-scatter":
            wire = size * (g - 1)        # result is the scattered size
        elif kind == "all-to-all":
            wire = size * frac
        else:                            # collective-permute
            wire = size
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.ops.append(dict(kind=kind, bytes=size, group=g, wire=wire))
    return stats


@dataclass
class RooflineTerms:
    cell: str
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_by_kind: dict
    peak_memory_bytes: float = 0.0
    model_flops_per_dev: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step estimate: max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        if self.flops_per_dev == 0:
            return 0.0
        return self.model_flops_per_dev / self.flops_per_dev

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline actually achieved at the
        estimated step time (a.k.a. projected MFU on useful flops)."""
        if self.step_time_s == 0:
            return 0.0
        return self.model_flops_per_dev / (self.step_time_s * PEAK_FLOPS)

    def as_row(self) -> dict:
        return {
            "cell": self.cell,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "peak_memory_gib": self.peak_memory_bytes / 2**30,
        }


def analyze_compiled(cell: str, compiled, n_devices: int,
                     model_flops_total: float = 0.0) -> RooflineTerms:
    """Build roofline terms from a compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text(), n_devices)
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes", 0) if mem else 0
    # arguments (weights/opt state) resident in HBM count toward peak too;
    # CompiledMemoryStats.peak covers temp + args on this backend.
    args = getattr(mem, "argument_size_in_bytes", 0) if mem else 0
    out = getattr(mem, "output_size_in_bytes", 0) if mem else 0
    peak = max(peak, args + out)
    return RooflineTerms(
        cell=cell,
        flops_per_dev=flops,
        hbm_bytes_per_dev=hbm,
        coll_bytes_per_dev=colls.total_bytes,
        coll_by_kind=dict(colls.by_kind),
        peak_memory_bytes=float(peak),
        model_flops_per_dev=model_flops_total / max(n_devices, 1),
    )


def sum_terms(cell: str, terms: list) -> RooflineTerms:
    """Combine per-kernel roofline terms into one sequential-schedule
    estimate (flops/bytes add; peak memory is the max single kernel).

    Used for conv *training* steps: forward + input-grad + weight-grad
    are three kernels whose plans each produce their own terms
    (``conv_plan_roofline`` accepts ``WeightGradPlan`` too — the plans
    duck-type the traffic/flops interface)."""
    coll: dict = {}
    for t in terms:
        for k, v in t.coll_by_kind.items():
            coll[k] = coll.get(k, 0.0) + v
    return RooflineTerms(
        cell=cell,
        flops_per_dev=sum(t.flops_per_dev for t in terms),
        hbm_bytes_per_dev=sum(t.hbm_bytes_per_dev for t in terms),
        coll_bytes_per_dev=sum(t.coll_bytes_per_dev for t in terms),
        coll_by_kind=coll,
        peak_memory_bytes=max((t.peak_memory_bytes for t in terms),
                              default=0.0),
        model_flops_per_dev=sum(t.model_flops_per_dev for t in terms),
    )


def conv_plan_roofline(cell: str, plan, mode: str | None = None
                       ) -> RooflineTerms:
    """Roofline terms for one conv layer, read straight from its
    ``ConvPlan`` (or ``WeightGradPlan``) — the same object the Pallas
    kernel executes, so the hillclimb's T_mem uses exactly the kernel's
    strip/carry traffic.  ``mode=None`` accounts the plan's own
    ``dataflow``."""
    traffic = plan.hbm_bytes(mode)
    return RooflineTerms(
        cell=cell,
        flops_per_dev=float(plan.flops),
        hbm_bytes_per_dev=float(traffic["total"]),
        coll_bytes_per_dev=0.0,
        coll_by_kind={},
        peak_memory_bytes=float(plan.vmem_resident_bytes),
        model_flops_per_dev=float(plan.flops),
    )


def sharded_conv_roofline(cell: str, plan) -> RooflineTerms:
    """Roofline terms for one *sharded* conv layer, read straight from
    its ``ShardedConvPlan`` (DESIGN.md §6): per-device HBM traffic and
    FLOPs from the local per-shard plan, and the cross-device
    halo-exchange round trip (forward ``ppermute`` + vjp transpose
    shuffle) on the collective term (``ppermute`` wire cost = the bytes
    themselves).  At ``shards == 1`` this reduces to
    ``conv_plan_roofline`` of the equivalent single-device plan (zero
    collective bytes)."""
    local = plan.local_plan()
    traffic = local.hbm_bytes()
    halo = float(plan.halo_bytes_per_device)
    return RooflineTerms(
        cell=cell,
        flops_per_dev=float(plan.local_flops),
        hbm_bytes_per_dev=float(traffic["total"]),
        coll_bytes_per_dev=halo,
        coll_by_kind={"collective-permute": halo} if halo else {},
        peak_memory_bytes=float(local.vmem_resident_bytes),
        model_flops_per_dev=float(plan.flops) / plan.n_devices,
    )


def network_roofline(cell: str, netplan) -> RooflineTerms:
    """Roofline terms for a whole :class:`~repro.core.netplan.NetworkPlan`
    or :class:`~repro.core.netplan.NetworkGraph` — the sequential-
    schedule sum (:func:`sum_terms`) of every step's terms, with the
    network's residency decisions applied to the memory term (resident
    boundaries and edges move no HBM bytes) and sharded layers'
    halo-exchange bytes on the collective term.  Graph join steps carry
    no ConvPlan (``plan is None``): they contribute their activation
    traffic as pure memory-bound work with zero flops."""
    terms = []
    for s in netplan.steps:
        t = s.hbm_bytes()
        halo = float(t["halo"])
        plan = getattr(s, "plan", None)
        flops = float(plan.flops) if plan is not None else 0.0
        peak = float(plan.vmem_resident_bytes) if plan is not None else 0.0
        terms.append(RooflineTerms(
            cell=s.name,
            flops_per_dev=flops,
            hbm_bytes_per_dev=float(t["total"]),
            coll_bytes_per_dev=halo,
            coll_by_kind={"collective-permute": halo} if halo else {},
            peak_memory_bytes=peak,
            model_flops_per_dev=flops,
        ))
    return sum_terms(cell, terms)


def markdown_table(rows: list[RooflineTerms]) -> str:
    hdr = ("| cell | T_comp (ms) | T_mem (ms) | T_coll (ms) | dominant | "
           "useful/HLO | roofline frac | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r.cell} | {r.t_compute*1e3:.2f} | {r.t_memory*1e3:.2f} "
            f"| {r.t_collective*1e3:.2f} | {r.dominant} "
            f"| {r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} "
            f"| {r.peak_memory_bytes/2**30:.2f} |")
    return "\n".join(lines)
