"""Residency-group fusion planning (DESIGN.md §8).

``NetworkPlan`` (§7) *decides* which inter-layer boundaries keep their
pooled ofmap resident in VMEM, but the execution engine still ran every
layer as its own ``pallas_call`` — the ofmap round-tripped through HBM
and the measured trim-vs-3dtrim traffic ratio sat at ~1.0009x while the
model claimed ~3.3x.  This module turns those residency decisions into
an executable partition:

* :class:`FusedGroupPlan` — partitions a network topology into
  *residency groups*: conv→[pool]→conv chains whose every interior
  boundary the ``NetworkPlan`` marked resident AND that the fused
  megakernel (``kernels/trim_conv2d_fused.py``) can execute in one
  pipelined ``pallas_call``.  The partition is a shortest-path dynamic
  program over executed HBM bytes, so the chosen grouping is the
  cheapest legal one — and since the all-singletons partition is always
  a candidate, ``executed_hbm_bytes() <= never_hbm_bytes()`` holds
  structurally.  Groups of depth 1 fall back to the ordinary per-layer
  path, so ``max_depth=1`` reduces *exactly* to per-layer execution and
  its byte accounting.

* :class:`FusedStage` / :class:`FusedGroup` — the static per-stage
  strip geometry the kernel executes.  Stage *i+1*'s K-1 halo rows
  constrain how many rows stage *i* must produce ahead: the same
  carry/halo machinery :class:`~repro.core.conv_plan.ConvPlan` owns for
  one layer, chained backwards through the group.  For a strip of
  ``strip_rows`` pooled output rows of the *last* stage, each stage's
  input/conv/pool row ranges are affine in the strip index ``g``
  (``start + g*step``, ``rows`` wide), derived by the backward
  recursion in :func:`_strip_geometry`.

* Traffic pricing — a fused group moves only the stage-0 input windows
  (the halo overlap is billed), each stage's weights streamed tap-by-tap
  from HBM once per strip, and the final pooled output.  Every interior
  activation — including interior *pooling* — stays in VMEM and moves
  zero HBM bytes.  The per-layer baseline is billed as the per-layer
  engine actually executes: the conv writes its full ofmap, a separate
  pooling op re-reads it and writes the pooled result (``NetworkPlan``'s
  ``fold_pooling=True`` models the paper's ASIC, not this engine).

The megakernel keeps activations resident but *streams* weights: each
stage's weight tensor stays in HBM (``pltpu.ANY``) and one (Cin, Cout)
tap slice at a time is DMA'd into a VMEM scratch buffer — so a group's
VMEM working set is the stage-0 window + the per-stage fp32
accumulators + one tap slice per stage, never the full weight chain.
That is what makes 512-channel VGG-16 tails fusable at all, and it is
why the feasibility check below counts windows and accumulators but
only a single tap per stage.  The working set is compared against the
*full* VMEM (``FUSED_VMEM_BUDGET``), not the half-VMEM strip budget:
the fused kernel owns the whole core while it runs (the residency
*decision* still uses the half-VMEM ``RESIDENCY_BUDGET``).

The group-level tuning knob (fuse depth x strip height) lives in
``core/autotune.py`` under the ``conv2d_fused:`` key namespace; the
plan consults it via ``use_autotune_cache=True``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import roofline
from repro.core.conv_plan import STRIP_VMEM_BUDGET
from repro.core.netplan import (NetworkPlan, RESIDENCY_BUDGET, graph_nodes,
                                infer_pools, layer_kernel_problem,
                                network_layers, pool_between,
                                pooled_out_size)

# Fused stages run the taps as native MXU matmuls, same ceiling as the
# single-layer kernel (kernels/ops.MAX_NATIVE_K, re-stated here to keep
# core/ free of kernel imports).
MAX_FUSED_K = 8

# The megakernel's working set may use the whole ~16 MiB VMEM core (it
# is the only kernel running), unlike the per-layer strip budget which
# reserves half for weights/accumulators it doesn't count.
FUSED_VMEM_BUDGET = 2 * STRIP_VMEM_BUDGET


def _same_pads(size: int, k: int, s: int) -> tuple[int, int]:
    """TF-style asymmetric 'same' padding — must mirror
    ``kernels/ops._same_pads`` exactly (the fused kernel's in-kernel
    padding has to reproduce the per-layer pre-pad bit-for-bit)."""
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    return total // 2, total - total // 2


# ---------------------------------------------------------------------------
# Static per-stage description + strip geometry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FusedStage:
    """One conv[+pool] stage of a fused group, with its strip geometry.

    All row ranges are affine in the strip index ``g``: a strip covers
    rows ``[start + g*step, start + g*step + rows)`` in the *global*
    (unpadded) coordinates of that tensor.  ``in_*`` ranges address the
    stage's input (== the previous stage's pooled output), ``conv_*``
    the conv output, ``pool_*`` the pooled output.  Rows outside the
    valid extent (``h_in`` / ``h_conv`` / ``h_pool``) are zeros — the
    kernel's post-pool mask makes them so, and they double as the next
    stage's 'same' H-padding.
    """

    name: str
    # problem geometry (square spatial dims)
    h_in: int
    w_in: int
    cin: int
    cout: int
    kernel: int
    stride: int
    pad_lo: int          # 'same' H/W pad (asymmetric), 0 for 'valid'
    pad_hi: int
    h_conv: int          # valid conv output rows (== layer.out_size)
    w_conv: int
    pool_stride: int     # (1, 1) == no pooling
    pool_window: int
    h_pool: int
    w_pool: int
    # strip geometry (affine in the strip index g)
    in_start: int
    in_step: int
    in_rows: int
    conv_start: int
    conv_step: int
    conv_rows: int
    pool_start: int
    pool_step: int
    pool_rows: int

    @property
    def weight_shape(self) -> tuple[int, int, int, int]:
        return (self.kernel, self.kernel, self.cin, self.cout)

    def weight_bytes(self, dtype_bytes: int) -> int:
        k = self.kernel
        return k * k * self.cin * self.cout * dtype_bytes

    def tap_bytes(self, dtype_bytes: int) -> int:
        """One streamed (Cin, Cout) weight tap slice."""
        return self.cin * self.cout * dtype_bytes

    @property
    def pooled(self) -> bool:
        return self.pool_stride > 1 or self.pool_window > 1

    @property
    def signature(self) -> str:
        """Stage signature for the ``conv2d_fused:`` autotune key."""
        return (f"h{self.h_in}c{self.cin}f{self.cout}k{self.kernel}"
                f"s{self.stride}p{self.pad_lo}.{self.pad_hi}"
                f"q{self.pool_stride}x{self.pool_window}")


def _stage_problems(layers, pools):
    """Per-layer (layer, pad_lo, pad_hi, h_conv, ps, pw, h_pool) tuples,
    validating each layer is 'same'/'valid'-executable."""
    probs = []
    for layer, (ps, pw) in zip(layers, pools):
        layer_kernel_problem(layer)     # raises if not 'same'/'valid'
        lo, hi = (_same_pads(layer.ifmap, layer.kernel, layer.stride)
                  if layer.padding else (0, 0))
        h_conv = layer.out_size
        probs.append((layer, lo, hi, h_conv, ps, pw,
                      pooled_out_size(h_conv, ps, pw)))
    return probs


def _strip_geometry(probs, strip_rows):
    """Backward recursion: from ``strip_rows`` pooled rows of the last
    stage, derive every stage's affine (start, step, rows) ranges.

    A pooled range needs conv rows ``[a*ps, a*ps + (c-1)*ps + pw)``; a
    conv range needs padded-input rows ``[a*s, a*s + (c-1)*s + K)``;
    un-padding subtracts the top 'same' pad.  The resulting stage-0
    input range is what one grid step fetches from HBM.
    """
    stages = []
    a, b, c = 0, strip_rows, strip_rows          # last stage pooled range
    for layer, lo, hi, h_conv, ps, pw, h_pool in reversed(probs):
        pa, pb, pc = a, b, c                      # pooled-out range
        a, b, c = a * ps, b * ps, (c - 1) * ps + pw          # conv-out
        ca, cb, cc = a, b, c
        s, k = layer.stride, layer.kernel
        a, b, c = a * s - lo, b * s, (c - 1) * s + k         # input
        stages.append(FusedStage(
            name=layer.name, h_in=layer.ifmap, w_in=layer.ifmap,
            cin=layer.in_channels, cout=layer.out_channels,
            kernel=k, stride=s, pad_lo=lo, pad_hi=hi,
            h_conv=h_conv, w_conv=h_conv,
            pool_stride=ps, pool_window=pw,
            h_pool=h_pool, w_pool=h_pool,
            in_start=a, in_step=b, in_rows=c,
            conv_start=ca, conv_step=cb, conv_rows=cc,
            pool_start=pa, pool_step=pb, pool_rows=pc))
    stages.reverse()
    return tuple(stages)


# ---------------------------------------------------------------------------
# A fused residency group
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FusedGroup:
    """One residency group: ``depth`` consecutive layers executed as a
    single megakernel (depth >= 2) or via the per-layer path (depth 1,
    where the strip geometry is unused)."""

    start: int                          # index of the first layer
    stages: tuple[FusedStage, ...]
    n: int = 1
    strip_rows: int = 1                 # pooled rows of the LAST stage/strip
    dtype_bytes: int = 4

    @property
    def depth(self) -> int:
        return len(self.stages)

    @property
    def fused(self) -> bool:
        return self.depth >= 2

    @property
    def last(self) -> FusedStage:
        return self.stages[-1]

    @property
    def n_strips(self) -> int:
        return math.ceil(self.last.h_pool / self.strip_rows)

    # -- stage-0 HBM layout ------------------------------------------------

    @property
    def extra_top(self) -> int:
        """Zero rows prepended to the HBM input so strip 0's (negative-
        starting) window begins at element row 0."""
        return max(0, -self.stages[0].in_start)

    @property
    def pad_bottom(self) -> int:
        """Zero rows appended so the last strip's window is in bounds."""
        s0 = self.stages[0]
        need = s0.in_start + (self.n_strips - 1) * s0.in_step + s0.in_rows
        return max(0, need - s0.h_in)

    def in_row_offset(self, g: int) -> int:
        """Element row offset of strip ``g``'s window in the padded HBM
        input (non-negative by construction)."""
        return self.stages[0].in_start + self.extra_top \
            + g * self.stages[0].in_step

    @property
    def padded_input_shape(self) -> tuple[int, int, int, int]:
        s0 = self.stages[0]
        return (self.n, self.extra_top + s0.h_in + self.pad_bottom,
                s0.w_in, s0.cin)

    @property
    def padded_output_shape(self) -> tuple[int, int, int, int]:
        lt = self.last
        return (self.n, self.n_strips * self.strip_rows, lt.w_pool, lt.cout)

    @property
    def out_shape(self) -> tuple[int, int, int, int]:
        lt = self.last
        return (self.n, lt.h_pool, lt.w_pool, lt.cout)

    # -- arithmetic / working set / traffic --------------------------------

    @property
    def macs(self) -> int:
        return sum(self.n * st.h_conv * st.w_conv * st.cout
                   * st.kernel * st.kernel * st.cin for st in self.stages)

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def vmem_resident_bytes(self) -> int:
        """Resident set of one grid step: the stage-0 input window, each
        stage's fp32 conv accumulator (interior activations live inside
        this footprint), plus one streamed weight tap slice and the bias
        per stage.  Full weight tensors are NOT resident — the kernel
        DMAs them tap-by-tap from HBM."""
        db = self.dtype_bytes
        s0 = self.stages[0]
        window = s0.in_rows * s0.w_in * s0.cin * db
        taps = sum(st.tap_bytes(db) + st.cout * db for st in self.stages)
        accs = sum(st.conv_rows * st.w_conv * st.cout * 4
                   for st in self.stages)
        return window + taps + accs

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """Executed HBM bytes of the megakernel's schedule: overlapping
        stage-0 windows (the halo overlap is billed in full), weights
        streamed once per strip, one pooled output write.  Interior
        activations and pooling move zero bytes.  ``mode`` is accepted
        for interface parity with ``ConvPlan`` (the schedule is fixed)."""
        db = self.dtype_bytes
        s0, lt = self.stages[0], self.last
        in_bytes = self.n * self.n_strips * s0.in_rows * s0.w_in \
            * s0.cin * db
        w_bytes = sum(st.weight_bytes(db) for st in self.stages) \
            * self.n_strips
        out_bytes = self.n * lt.h_pool * lt.w_pool * lt.cout * db
        return dict(input=in_bytes, weights=w_bytes, output=out_bytes,
                    total=in_bytes + w_bytes + out_bytes)

    def arithmetic_intensity(self, mode: str | None = None) -> float:
        return self.flops / max(self.hbm_bytes(mode)["total"], 1)

    @property
    def signature(self) -> str:
        return "-".join(st.signature for st in self.stages)

    def as_dict(self) -> dict:
        return dict(start=self.start, depth=self.depth, fused=self.fused,
                    layers=[st.name for st in self.stages],
                    strip_rows=self.strip_rows, n_strips=self.n_strips,
                    vmem_resident_bytes=self.vmem_resident_bytes,
                    flops=self.flops,
                    hbm_total=self.hbm_bytes()["total"])


def build_group(layers, start, *, n=1, strip_rows=1, dtype_bytes=4,
                pools=None):
    """A :class:`FusedGroup` over ``layers`` — the constructor used by
    the plan and by tests that need a hand-rolled group.  ``pools``
    defaults to :func:`infer_pools` over ``layers`` *as given* (pass the
    whole-network pools to keep a trailing group's final pool)."""
    if pools is None:
        pools = infer_pools(list(layers))
    probs = _stage_problems(list(layers), list(pools))
    stages = _strip_geometry(probs, strip_rows)
    return FusedGroup(start=start, stages=stages, n=n,
                      strip_rows=strip_rows, dtype_bytes=dtype_bytes)


# ---------------------------------------------------------------------------
# Whole-network partition
# ---------------------------------------------------------------------------

def _layer_eligible(layer) -> bool:
    """Can this layer run *inside* a fused megakernel at all?"""
    if layer.groups != 1 or layer.kernel > MAX_FUSED_K:
        return False
    if layer.stride > 1 and layer.out_size == 1:
        # A strided stage collapsing to a single output row fuses as a
        # strided interior-row gather whose dot lowers with a different
        # reduction association than the per-layer kernel (observed
        # one-ULP drift), breaking the bitwise guarantee — and a
        # one-strip output gains nothing from strip fusion anyway.
        return False
    try:
        layer_kernel_problem(layer)
    except ValueError:
        return False
    return True


def _strip_candidates(h_pool_last: int):
    """Candidate strip heights: powers of two up to the full pooled
    height (the full-height strip is always included)."""
    t, cands = 1, []
    while t < h_pool_last:
        cands.append(t)
        t *= 2
    cands.append(h_pool_last)
    return cands


@dataclass(frozen=True)
class FusedGroupPlan:
    """Partition of a network into residency groups, with executed-byte
    accounting for the fused schedule vs the per-layer baseline."""

    groups: tuple[FusedGroup, ...]
    n: int
    dtype_bytes: int
    residency: str
    vmem_budget: int
    layer_exec_bytes: tuple   # per-layer executed byte dicts (see below)

    @classmethod
    def build(cls, network, *, n: int = 1, dtype_bytes: int | None = None,
              residency: str = "auto",
              residency_budget: int = RESIDENCY_BUDGET,
              vmem_budget: int = FUSED_VMEM_BUDGET,
              max_depth: int | None = None,
              strip_rows: int | None = None,
              use_autotune_cache: bool = False,
              dtype: str = "float32", backend: str | None = None,
              dataflow: str = "carry") -> "FusedGroupPlan":
        """Partition ``network`` (name or layer list) into residency
        groups.

        A range ``[i, j]`` may form one fused group iff every interior
        boundary's pooled ofmap is marked resident by the
        :class:`NetworkPlan` ``residency`` policy, every layer is
        kernel-eligible, and some strip height keeps the working set
        under ``vmem_budget``.  Among all legal partitions the build
        picks the one with minimal executed HBM bytes (shortest-path
        DP); ``max_depth`` caps group depth (``max_depth=1`` ==
        per-layer execution); ``strip_rows`` forces the strip height
        instead of tuning/modelling it.
        """
        if dtype_bytes is None:
            dtype_bytes = roofline.dtype_width(dtype)
        layers = list(network_layers(network))
        pools = list(infer_pools(layers))
        nplan = NetworkPlan.build(layers, n=n, dtype_bytes=dtype_bytes,
                                  dataflow=dataflow, residency=residency,
                                  residency_budget=residency_budget)
        exec_bytes = cls._per_layer_exec_bytes(
            layers, pools, n=n, dtype_bytes=dtype_bytes, dataflow=dataflow)

        cap = len(layers) if max_depth is None else max(1, max_depth)

        def group_cost(i, j):
            """Best fused group over layers[i..j] and its bytes, or
            (None, inf) when the range can't fuse."""
            if j > i:
                if not all(_layer_eligible(layers[k])
                           for k in range(i, j + 1)):
                    return None, math.inf
                if not all(nplan.steps[k].resident_out
                           for k in range(i, j)):
                    return None, math.inf
                g = cls._tune_group(
                    layers, pools, i, j - i + 1, n=n,
                    dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
                    strip_rows=strip_rows,
                    use_autotune_cache=use_autotune_cache,
                    dtype=dtype, backend=backend)
                if g is None:
                    return None, math.inf
                return g, g.hbm_bytes()["total"]
            g = build_group(layers[i:i + 1], i, n=n, strip_rows=1,
                            dtype_bytes=dtype_bytes, pools=pools[i:i + 1])
            return g, exec_bytes[i]["total"]

        # shortest path over layer boundaries: best[j] = cheapest bytes
        # for layers[0..j-1]; the all-singletons path is always legal,
        # so the optimum never exceeds the per-layer baseline.
        best = [0.0] + [math.inf] * len(layers)
        choice: list = [None] * (len(layers) + 1)
        for j in range(1, len(layers) + 1):
            for i in range(max(0, j - cap), j):
                g, cost = group_cost(i, j - 1)
                if g is not None and best[i] + cost < best[j]:
                    best[j] = best[i] + cost
                    choice[j] = g
        groups: list[FusedGroup] = []
        j = len(layers)
        while j > 0:
            g = choice[j]
            groups.append(g)
            j = g.start
        groups.reverse()
        return cls(groups=tuple(groups), n=n, dtype_bytes=dtype_bytes,
                   residency=residency, vmem_budget=vmem_budget,
                   layer_exec_bytes=exec_bytes)

    @staticmethod
    def _per_layer_exec_bytes(layers, pools, *, n, dtype_bytes, dataflow):
        """What the per-layer engine actually moves for each layer: the
        conv's ``residency="never"`` bytes with the FULL ofmap written
        (``fold_pooling=False``), plus the separate pooling op's
        read-back of that ofmap and write of the pooled result."""
        never = NetworkPlan.build(list(layers), n=n,
                                  dtype_bytes=dtype_bytes,
                                  dataflow=dataflow, residency="never",
                                  fold_pooling=False)
        out = []
        for st, (ps, pw) in zip(never.steps, pools):
            b = dict(st.hbm_bytes())
            if ps > 1 or pw > 1:
                layer = st.layer
                db = dtype_bytes
                full = n * layer.out_size ** 2 * layer.out_channels * db
                pooled = n * pooled_out_size(layer.out_size, ps, pw) ** 2 \
                    * layer.out_channels * db
                b["pool"] = full + pooled
                b["total"] += b["pool"]
            else:
                b["pool"] = 0
            out.append(b)
        return tuple(out)

    @classmethod
    def _tune_group(cls, layers, pools, start, depth, *, n, dtype_bytes,
                    vmem_budget, strip_rows, use_autotune_cache, dtype,
                    backend):
        """Best VMEM-feasible group over ``layers[start:start+depth]``,
        or ``None`` when no strip height fits the budget.  Consults the
        ``conv2d_fused:`` cache first, then the byte model."""
        sub = layers[start:start + depth]
        subpools = pools[start:start + depth]

        def make(t):
            return build_group(sub, start, n=n, strip_rows=t,
                               dtype_bytes=dtype_bytes, pools=subpools)

        if strip_rows is not None:
            g = make(strip_rows)
            return g if g.vmem_resident_bytes <= vmem_budget else None

        probe = make(1)
        if use_autotune_cache:
            from repro.core import autotune
            rec = autotune.fused_knobs_for(
                probe.signature, n=n, dtype=dtype, backend=backend)
            if rec is not None:
                g = make(rec["strip_rows"])
                if g.vmem_resident_bytes <= vmem_budget:
                    return g
        best = None
        for t in _strip_candidates(probe.last.h_pool):
            g = make(t)
            if g.vmem_resident_bytes > vmem_budget:
                continue
            if best is None or g.hbm_bytes()["total"] \
                    < best.hbm_bytes()["total"]:
                best = g
        return best

    # -- accounting --------------------------------------------------------

    @property
    def depth(self) -> int:
        return max(g.depth for g in self.groups)

    @property
    def flops(self) -> int:
        return sum(g.flops for g in self.groups)

    @property
    def macs(self) -> int:
        return sum(g.macs for g in self.groups)

    @property
    def vmem_resident_bytes(self) -> int:
        return max(g.vmem_resident_bytes for g in self.groups)

    def executed_hbm_bytes(self) -> dict:
        """HBM bytes the fused execution actually moves: megakernel
        accounting for fused groups, per-layer-engine accounting
        (separate pooling op included) for depth-1 groups."""
        tot = dict(input=0, weights=0, output=0, pool=0, total=0)
        for g in self.groups:
            b = g.hbm_bytes() if g.fused else self.layer_exec_bytes[g.start]
            for k in tot:
                tot[k] += b.get(k, 0)
        return tot

    def hbm_bytes(self, mode: str | None = None) -> dict:
        """Alias so the plan duck-types ``ConvPlan`` for the roofline."""
        return self.executed_hbm_bytes()

    def never_hbm_bytes(self) -> int:
        """The per-layer baseline: every boundary spills to HBM and
        every pool is a separate read-modify-write op."""
        return sum(b["total"] for b in self.layer_exec_bytes)

    def executed_ratio(self) -> float:
        """Per-layer executed bytes over fused executed bytes — the
        measured counterpart of the modeled trim-vs-3dtrim ratio."""
        return self.never_hbm_bytes() \
            / max(self.executed_hbm_bytes()["total"], 1)

    def arithmetic_intensity(self, mode: str | None = None) -> float:
        return self.flops / max(self.executed_hbm_bytes()["total"], 1)

    def as_rows(self) -> list[dict]:
        return [g.as_dict() for g in self.groups]

    def summary(self) -> dict:
        return dict(groups=len(self.groups), max_depth=self.depth,
                    fused_layers=sum(g.depth for g in self.groups
                                     if g.fused),
                    executed_bytes=self.executed_hbm_bytes()["total"],
                    per_layer_bytes=self.never_hbm_bytes(),
                    executed_ratio=self.executed_ratio())


# ---------------------------------------------------------------------------
# DAG segmentation: fusable linear runs between joins
# ---------------------------------------------------------------------------

def graph_segments(nodes) -> list[tuple[tuple[str, ...], tuple]]:
    """Maximal fusable linear runs of a DAG topology, as ``(names,
    layers)`` tuples: the covered node names (conv nodes plus absorbed
    single-consumer pool nodes, in topological order) and the run's
    ``ConvLayer`` chain.

    A run extends from conv to conv only while the intermediate tensor
    has exactly one consumer (joins, skip taps and network outputs end
    runs — their tensor must materialize) and the boundary's pooling is
    exactly re-inferable from the spatial dims by
    :func:`~repro.core.netplan.pool_between` — ``infer_pools``' chain
    convention, so each run IS one of today's linear chains and
    ``FusedGroupPlan`` / ``cnn_apply_from_layers`` apply unchanged.  A
    trailing conv-node epilogue pool is *not* part of the run (the graph
    executor applies it after the run)."""
    nodes = list(nodes)
    by = {nd.name: nd for nd in nodes}
    cons: dict[str, list[str]] = {nd.name: [] for nd in nodes}
    for nd in nodes:
        for s in nd.inputs:
            cons[s].append(nd.name)
    used: set[str] = set()
    segments: list[tuple[tuple[str, ...], tuple]] = []
    for nd in nodes:
        if nd.op != "conv" or nd.name in used:
            continue
        names, layers = [nd.name], [nd.layer]
        used.add(nd.name)
        cur = nd
        while True:
            nxts = cons[cur.name]
            if len(nxts) != 1:
                break
            nxt = by[nxts[0]]
            absorbed: list[str] = []
            if nxt.op == "pool":
                if cur.pool > 1 or cur.pool_window > 1:
                    break        # stacked pools: not dims-recoverable
                pc = cons[nxt.name]
                if len(pc) != 1:
                    break        # pooled tensor has other consumers
                cand = by[pc[0]]
                expected = (nxt.pool, nxt.pool_window)
                absorbed = [nxt.name]
            elif nxt.op == "conv":
                cand = nxt
                expected = (cur.pool, cur.pool_window)
            else:
                break            # add / concat / upsample end the run
            if cand.op != "conv":
                break
            try:
                if pool_between(cur.layer, cand.layer) != expected:
                    break        # dims would re-infer a different pool
            except ValueError:
                break
            names.extend(absorbed)
            names.append(cand.name)
            layers.append(cand.layer)
            used.update(absorbed)
            used.add(cand.name)
            cur = cand
        segments.append((tuple(names), tuple(layers)))
    return segments


@dataclass(frozen=True)
class GraphFusePlan:
    """Fusion partition of a DAG topology: each fusable linear segment
    between joins is planned as today's chain (its own
    :class:`FusedGroupPlan`); joins and skip taps stay un-fused — their
    tensors must materialize, so they bound the segments.

    ``executed_ratio()`` compares segment-sum executed bytes against the
    all-per-layer baseline over the same segments; join traffic is
    identical on both sides of that comparison and is accounted by
    :class:`~repro.core.netplan.NetworkGraph`, not here."""

    name: str
    segments: tuple              # (names, FusedGroupPlan) pairs
    n: int
    dtype_bytes: int
    residency: str

    @classmethod
    def build(cls, graph, *, n: int = 1, dtype_bytes: int | None = None,
              residency: str = "auto",
              residency_budget: int = RESIDENCY_BUDGET,
              vmem_budget: int = FUSED_VMEM_BUDGET,
              max_depth: int | None = None,
              strip_rows: int | None = None,
              use_autotune_cache: bool = False,
              dtype: str = "float32", backend: str | None = None,
              dataflow: str = "carry") -> "GraphFusePlan":
        if dtype_bytes is None:
            dtype_bytes = roofline.dtype_width(dtype)
        nodes = graph_nodes(graph)
        segs = []
        for names, layers in graph_segments(nodes):
            plan = FusedGroupPlan.build(
                list(layers), n=n, dtype_bytes=dtype_bytes,
                residency=residency, residency_budget=residency_budget,
                vmem_budget=vmem_budget, max_depth=max_depth,
                strip_rows=strip_rows,
                use_autotune_cache=use_autotune_cache, dtype=dtype,
                backend=backend, dataflow=dataflow)
            segs.append((names, plan))
        nm = graph if isinstance(graph, str) else "custom"
        return cls(name=nm, segments=tuple(segs), n=n,
                   dtype_bytes=dtype_bytes, residency=residency)

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def groups(self) -> tuple[FusedGroup, ...]:
        return tuple(g for _, p in self.segments for g in p.groups)

    @property
    def flops(self) -> int:
        return sum(p.flops for _, p in self.segments)

    @property
    def macs(self) -> int:
        return sum(p.macs for _, p in self.segments)

    @property
    def vmem_resident_bytes(self) -> int:
        return max(p.vmem_resident_bytes for _, p in self.segments)

    def executed_hbm_bytes(self) -> dict:
        tot = dict(input=0, weights=0, output=0, pool=0, total=0)
        for _, p in self.segments:
            b = p.executed_hbm_bytes()
            for k in tot:
                tot[k] += b.get(k, 0)
        return tot

    def hbm_bytes(self, mode: str | None = None) -> dict:
        return self.executed_hbm_bytes()

    def never_hbm_bytes(self) -> int:
        return sum(p.never_hbm_bytes() for _, p in self.segments)

    def executed_ratio(self) -> float:
        return self.never_hbm_bytes() \
            / max(self.executed_hbm_bytes()["total"], 1)

    def as_rows(self) -> list[dict]:
        rows = []
        for names, p in self.segments:
            for g in p.groups:
                d = g.as_dict()
                d["segment"] = list(names)
                rows.append(d)
        return rows

    def summary(self) -> dict:
        return dict(segments=self.n_segments,
                    groups=sum(len(p.groups) for _, p in self.segments),
                    max_depth=max(p.depth for _, p in self.segments),
                    fused_layers=sum(g.depth for g in self.groups
                                     if g.fused),
                    executed_bytes=self.executed_hbm_bytes()["total"],
                    per_layer_bytes=self.never_hbm_bytes(),
                    executed_ratio=self.executed_ratio())
