"""Guarded conv dispatch: tier fallback chains + demotion events (§9).

The execution stack has four conv tiers — fused residency-group
megakernels (§8), sharded shard_map execution (§6), the per-layer Pallas
carry/halo kernels (§2–§4), and the XLA ``ref`` oracle — and before this
module any lowering, compile, or runtime failure in a fast tier was a
hard crash.  The paper's silicon assumes fault-free fixed-function
datapaths; a production serving system cannot.  ``run_chain`` is the
defined failure model underneath the whole stack:

* **Demotion, not crash.**  A tier chain is a list of ``(tier, thunk)``
  attempts ordered fastest-first.  An exception raised by a non-final
  tier demotes the call to the next tier; the final tier runs unguarded
  (its errors propagate — a genuinely invalid problem still fails
  loudly, from the simplest engine that can diagnose it).

* **Structured events.**  Every demotion appends one event to a bounded
  ring buffer (:data:`RING_SIZE`); :func:`events` returns them for
  tests, benchmarks (the ``guard`` column of ``benchmarks/run.py
  --json``) and the examples' degraded-mode report.

* **Memoized demotions.**  A failed ``(problem key, tier)`` pair is
  remembered (:func:`demotions`) and skipped on subsequent calls, so a
  broken config is attempted — and reported — exactly once, not once
  per call.  ``reset()`` clears the memo (e.g. after upgrading a
  backend).

* **Opt-in numerics guard.**  With ``REPRO_CONV_GUARD=1`` the output of
  every non-final tier is finite-checked; NaN/Inf demotes with
  ``kind="numerics"`` and the producing layer named.  The check needs a
  concrete array, so it is active in eager execution and inert under a
  ``jax.jit`` trace (tracers cannot be inspected without a host
  callback) — run the chaos suite eager.

* **Strict mode.**  ``REPRO_CONV_GUARD_STRICT=1`` disables demotion
  entirely (first tier runs bare, errors propagate) — the debugging
  escape hatch when a silent fallback would mask the bug you are
  chasing.

Exceptions caught during a *trace* still demote: the thunk raises while
jax traces it, so a jitted ``cnn_apply_from_layers`` falls from fused to
per-layer within the same trace.  Only post-compile runtime faults of a
jitted computation are beyond the guard's reach.

This module imports nothing heavy at module level (no jax) so benchmark
entry points can import it before choosing an XLA device configuration.
"""

from __future__ import annotations

import collections
import itertools
import os
import threading

GUARD_ENV = "REPRO_CONV_GUARD"          # "1" -> NaN/Inf numerics guard on
STRICT_ENV = "REPRO_CONV_GUARD_STRICT"  # "1" -> re-raise, never demote
RING_SIZE = 256

#: canonical tier order, fastest first — chains are sub-sequences of
#: this (the ``q8`` int8 kernel tier only appears in the quantized
#: chain ``q8 -> pallas -> ref`` of ``ops._conv2d_q8``, DESIGN.md §11)
TIER_CHAIN = ("fused", "sharded", "q8", "pallas", "ref")

_LOCK = threading.Lock()
_EVENTS: collections.deque = collections.deque(maxlen=RING_SIZE)
_DEMOTED: dict[tuple[str, str], dict] = {}     # (key, tier) -> event
_SEQ = itertools.count()


def numerics_enabled() -> bool:
    """True when ``REPRO_CONV_GUARD=1`` turned the NaN/Inf guard on."""
    return os.environ.get(GUARD_ENV, "0") not in ("", "0")


def strict() -> bool:
    """True when ``REPRO_CONV_GUARD_STRICT=1`` disables demotion."""
    return os.environ.get(STRICT_ENV, "0") not in ("", "0")


def events() -> list[dict]:
    """Demotion events, oldest first (bounded by :data:`RING_SIZE`).

    Event schema (every value JSON-serializable)::

        {"seq": int,            # monotonic within the process
         "tier": str,           # the tier that failed
         "to": str,             # the tier the call demoted to
         "key": str,            # problem key (shape/stride/groups/dtype)
         "kind": "error" | "numerics",
         "error": str,          # exception repr, or the numerics finding
         "layer": str | None}   # producing layer, when the caller knows
    """
    with _LOCK:
        return [dict(e) for e in _EVENTS]


def demotions() -> dict[tuple[str, str], dict]:
    """The memo of broken ``(problem key, tier)`` pairs -> first event."""
    with _LOCK:
        return {k: dict(v) for k, v in _DEMOTED.items()}


def is_demoted(key: str, tier: str) -> bool:
    """Has ``tier`` already failed for this problem key?"""
    with _LOCK:
        return (key, tier) in _DEMOTED


def clear_events() -> None:
    """Drop the event ring (the demotion memo survives)."""
    with _LOCK:
        _EVENTS.clear()


def reset() -> None:
    """Forget everything: events AND memoized demotions (tests; or after
    an environment change that may have fixed a previously broken tier).
    """
    with _LOCK:
        _EVENTS.clear()
        _DEMOTED.clear()


def problem_key(op: str, x_shape, w_shape, *, stride: int = 1,
                padding: str = "same", groups: int = 1,
                dtype: str = "float32") -> str:
    """Cheap structural key for one conv problem — what demotions are
    memoized under.  Deliberately backend-free (unlike autotune keys):
    the guard must not trigger jax initialization, and a tier broken on
    this process's backend is broken for the life of the process."""
    xs = "x".join(str(int(d)) for d in x_shape)
    ws = "x".join(str(int(d)) for d in w_shape)
    return f"{op}:i{xs}:w{ws}:s{stride}:{padding}:g{groups}:{dtype}"


def _record(tier: str, to: str, key: str, kind: str, error: str,
            layer: str | None) -> None:
    event = {"seq": next(_SEQ), "tier": tier, "to": to, "key": key,
             "kind": kind, "error": error[:500], "layer": layer}
    with _LOCK:
        # first failure wins the memo; the ring keeps every distinct one
        if (key, tier) not in _DEMOTED:
            _DEMOTED[(key, tier)] = event
            _EVENTS.append(event)


def _finite(out) -> bool:
    """All inexact leaves of ``out`` finite?  Returns True (check
    skipped) for tracers — only concrete arrays can be inspected."""
    import jax
    for leaf in jax.tree_util.tree_leaves(out):
        if not hasattr(leaf, "dtype"):
            continue
        try:
            import jax.numpy as jnp
            if not jnp.issubdtype(leaf.dtype, jnp.inexact):
                continue
            if not bool(jnp.isfinite(leaf).all()):
                return False
        except Exception:       # tracer (jit trace): cannot concretize
            return True
    return True


def run_chain(key: str, attempts, *, layer: str | None = None):
    """Run the first healthy tier of ``attempts``; demote on failure.

    ``attempts`` is an ordered list of ``(tier_name, thunk)`` pairs,
    fastest tier first.  Semantics:

    * A tier already memoized as broken for ``key`` is skipped silently
      (no new event — demotions are reported exactly once per problem).
    * A non-final tier that raises records a ``kind="error"`` demotion
      event and falls through to the next tier.
    * With the numerics guard on (``REPRO_CONV_GUARD=1``), a non-final
      tier whose concrete output contains NaN/Inf records a
      ``kind="numerics"`` demotion and recomputes on the next tier.
    * The final tier runs unguarded: its exceptions propagate, and its
      output is returned as-is.
    * ``REPRO_CONV_GUARD_STRICT=1``: the first tier runs bare (crash
      semantics restored for debugging).

    ``layer`` names the producing layer in the event (the netplan
    execution path passes layer names through ``ops.conv2d``).
    """
    attempts = list(attempts)
    if not attempts:
        raise ValueError("run_chain needs at least one tier")
    if strict():
        return attempts[0][1]()
    last = len(attempts) - 1
    for i, (tier, thunk) in enumerate(attempts):
        final = i == last
        if not final and is_demoted(key, tier):
            continue
        if final:
            return thunk()
        to = attempts[i + 1][0]
        try:
            out = thunk()
        except Exception as e:  # lowering/compile/runtime fault -> demote
            _record(tier, to, key, "error",
                    f"{type(e).__name__}: {e}", layer)
            continue
        if numerics_enabled() and not _finite(out):
            _record(tier, to, key, "numerics",
                    "non-finite output (NaN/Inf)", layer)
            continue
        return out
    raise AssertionError("unreachable: final tier always returns/raises")
