"""Continuous-batching serving engine over the tuned conv stack
(DESIGN.md §10).

The "millions of users" leg of the roadmap: requests enter a bounded
FIFO queue and are served in *buckets* — a fixed grid of batch sizes,
one compiled program per bucket, so the JIT cache stays finite no matter
what batch sizes the traffic produces.  Each serving step drains up to
``max_bucket`` queued requests, rounds the count up to the smallest
bucket that fits, pads the short rows, executes on the next free
replica, and returns only the real rows — padding never leaks
(per-image independence of the conv stack makes every served row
bit-identical to the single-request forward; tested in
``tests/test_serving.py``).

Three design rules keep the engine testable and production-shaped:

* **Deterministic core, async shell.**  :class:`ServingEngine` is a
  synchronous state machine — ``submit(rid, x, now)`` and
  ``step(now=...)`` take explicit timestamps, so
  :func:`replay` can drive an arrival trace on a virtual clock
  (``repro.testing.load``) with *injected* service times and reproduce a
  timeline bit-for-bit.  The asyncio front end
  (``repro.launch.serve_conv``) wraps the same engine with
  ``time.monotonic`` and real futures.

* **No cold paths after prewarm.**  ``prewarm()`` sweeps
  ``autotune.prewarm_buckets`` over the bucket grid (every layer of the
  topology tuned at every bucket's batch shape, fused groups included
  when fused execution is on) and runs one throwaway forward per
  (bucket, replica) so every compiled program exists before the first
  request.  A bucket served without prewarm is a *cold tune* — counted
  in ``stats()`` and asserted zero by the benchmark.

* **Degradation is visible, not fatal.**  Every replica's forward runs
  the guarded tier chain of ``core.guard`` (fused -> sharded -> pallas
  -> ref); the engine snapshots new demotion events after each step and
  attributes them to the serving replica, so ``stats()`` names exactly
  which replicas are degraded and why while they keep serving.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.core import guard
from repro.testing.load import TraceRecorder

__all__ = ["QueueFull", "BucketGrid", "Replica", "ServingEngine",
           "replay", "pow2_buckets"]


class QueueFull(RuntimeError):
    """Raised by :meth:`ServingEngine.submit` when the bounded request
    queue is at capacity — the backpressure signal (shed or retry
    upstream; the engine never buffers unboundedly)."""


def pow2_buckets(max_batch: int) -> tuple[int, ...]:
    """The default bucket grid: powers of two up to (and including)
    ``max_batch`` — ``pow2_buckets(8) == (1, 2, 4, 8)``, and a non-power
    ``max_batch`` is appended as its own bucket (``(1, 2, 4, 6)`` for
    6) so the configured serving batch always has an exact program."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    buckets = []
    b = 1
    while b < max_batch:
        buckets.append(b)
        b *= 2
    buckets.append(max_batch)
    return tuple(buckets)


@dataclasses.dataclass(frozen=True)
class BucketGrid:
    """The fixed grid of serving batch sizes (ascending, unique).

    One compiled program exists per bucket; :meth:`bucket_for` is the
    entire batching policy — exact and deterministic: the smallest
    bucket that fits ``n`` requests (a request count above ``max_bucket``
    is the caller's split problem; the engine never takes more than
    ``max_bucket`` per step)."""

    buckets: tuple[int, ...]

    @classmethod
    def build(cls, buckets) -> "BucketGrid":
        bs = sorted({int(b) for b in buckets})
        if not bs:
            raise ValueError("bucket grid cannot be empty")
        if bs[0] < 1:
            raise ValueError(f"buckets must be >= 1, got {bs[0]}")
        return cls(buckets=tuple(bs))

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= ``n`` (raises for n < 1 or n > max)."""
        if n < 1:
            raise ValueError(f"need at least 1 request, got {n}")
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(
            f"{n} requests exceed the largest bucket {self.max_bucket}; "
            "the engine drains at most max_bucket per step")

    def pad_rows(self, n: int) -> int:
        """How many padding rows bucket selection adds for ``n`` real
        requests."""
        return self.bucket_for(n) - n


@dataclasses.dataclass(frozen=True)
class Replica:
    """One serving replica: a name (for stats/guard attribution) and a
    batch forward ``fn(batch) -> outputs`` (row i of the output serves
    request i).  Replicas are data-parallel copies — the engine
    dispatches whole buckets to whichever is free."""

    name: str
    fn: object     # Callable[[np.ndarray], array-like]


class ServingEngine:
    """Continuous batching over a bucket grid with bounded queueing,
    multi-replica dispatch and guard-aware degradation reporting.

    The engine is clock-agnostic: every mutating entry point takes
    ``now`` (seconds on the caller's clock).  Thread-safe for the
    asyncio front end (one lock guards the queue and bookkeeping; the
    forward itself runs outside the lock).
    """

    def __init__(self, replicas, buckets, *, max_queue: int = 1024,
                 pad_fill: float = 0.0, topo=None, fused: bool = False,
                 tune_kwargs: dict | None = None, input_shape=None,
                 recorder: TraceRecorder | None = None) -> None:
        self.replicas = list(replicas)
        if not self.replicas:
            raise ValueError("need at least one replica")
        self.grid = buckets if isinstance(buckets, BucketGrid) \
            else BucketGrid.build(buckets)
        if max_queue < self.grid.max_bucket:
            raise ValueError(
                f"max_queue {max_queue} < max bucket "
                f"{self.grid.max_bucket}: the queue could never fill a "
                "full batch")
        self.max_queue = int(max_queue)
        self.pad_fill = float(pad_fill)
        self.topo = topo
        self.fused = fused
        self.tune_kwargs = dict(tune_kwargs or {})
        self.input_shape = tuple(input_shape) if input_shape else None
        self.recorder = recorder or TraceRecorder()

        self._lock = threading.Lock()
        self._queue: deque = deque()      # (rid, x, t_enqueue)
        self._rr = 0                      # round-robin replica cursor
        self._warm: set[int] = set()
        self.cold_tunes = 0
        self.served = 0
        self._bucket_counts: dict[int, int] = {}
        self._replica_served = {r.name: 0 for r in self.replicas}
        self._replica_events: dict[str, list[dict]] = \
            {r.name: [] for r in self.replicas}
        self._guard_seq = max([e["seq"] for e in guard.events()],
                              default=-1)

    # -- construction -------------------------------------------------------

    @classmethod
    def for_topology(cls, topo, params, *, buckets, n_replicas: int = 1,
                     mesh=None, rules=None, fused: bool = False,
                     fuse_plan=None, jit: bool = True,
                     distribute: bool = False, **kw) -> "ServingEngine":
        """Build an engine serving a conv topology (``list[ConvLayer]``)
        through ``models.layers.cnn_apply_from_layers``.

        ``n_replicas`` data-parallel replicas share ``params`` (or, with
        ``distribute=True``, each holds a copy placed on its own local
        device — the PR 4 device-mesh leg).  ``mesh``/``rules`` route
        every conv through the sharded halo-exchange path *within* each
        replica (spatial parallelism inside a replica composes with
        data parallelism across replicas).  ``fused=True`` serves the
        residency-group megakernels (guarded: a failing group demotes
        to per-layer execution per DESIGN.md §9)."""
        import jax
        import jax.numpy as jnp
        from repro.models import layers as mlayers

        topo = list(topo)

        def fwd(p, x):
            return mlayers.cnn_apply_from_layers(
                p, topo, x, mesh=mesh, rules=rules, fused=fused,
                fuse_plan=fuse_plan)

        call = jax.jit(fwd) if jit else fwd
        devices = jax.devices() if distribute else []
        replicas = []
        for i in range(n_replicas):
            if devices:
                dev = devices[i % len(devices)]
                p_i = jax.device_put(params, dev)
            else:
                dev, p_i = None, params

            def fn(batch, p=p_i, dev=dev):
                xb = jnp.asarray(np.asarray(batch))
                if dev is not None:
                    xb = jax.device_put(xb, dev)
                return np.asarray(call(p, xb))

            replicas.append(Replica(name=f"replica{i}", fn=fn))
        first = topo[0]
        return cls(replicas, buckets, topo=topo, fused=fused,
                   input_shape=(first.ifmap, first.ifmap,
                                first.in_channels), **kw)

    # -- request intake -----------------------------------------------------

    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    def submit(self, rid: int, x, *, now: float) -> None:
        """Enqueue one request.  Raises :class:`QueueFull` at capacity
        (backpressure: the queue depth is bounded by ``max_queue``,
        always)."""
        with self._lock:
            if len(self._queue) >= self.max_queue:
                raise QueueFull(
                    f"queue at capacity ({self.max_queue}); retry or "
                    "shed upstream")
            self.recorder.enqueue(rid, now)
            self._queue.append((rid, np.asarray(x), now))
            self.recorder.note_queue_depth(len(self._queue))

    def head_enqueue_time(self) -> float | None:
        """Enqueue timestamp of the oldest queued request (None when
        idle) — the earliest instant a batch could form."""
        with self._lock:
            return self._queue[0][2] if self._queue else None

    # -- serving ------------------------------------------------------------

    def _pad_batch(self, xs: list[np.ndarray], bucket: int) -> np.ndarray:
        batch = np.stack(xs)
        if len(xs) < bucket:
            pad = np.full((bucket - len(xs),) + batch.shape[1:],
                          self.pad_fill, batch.dtype)
            batch = np.concatenate([batch, pad])
        return batch

    def _ensure_warm(self, bucket: int) -> None:
        """First service of a non-prewarmed bucket tunes it on the spot
        — a *cold tune*, counted so the benchmark can assert prewarm
        coverage was complete."""
        if bucket in self._warm:
            return
        self.cold_tunes += 1
        if self.topo is not None:
            from repro.core import autotune
            autotune.tune_network(self.topo, n=bucket,
                                  **self.tune_kwargs)
            if self.fused:
                autotune.tune_fused_network(self.topo, n=bucket,
                                            **self.tune_kwargs)
        self._warm.add(bucket)

    def _collect_guard(self, replica_name: str) -> None:
        new = [e for e in guard.events() if e["seq"] > self._guard_seq]
        if new:
            self._guard_seq = new[-1]["seq"]
            self._replica_events[replica_name].extend(new)

    def step(self, *, now: float, replica: int | None = None,
             service_model=None) -> tuple[list[tuple[int, np.ndarray]],
                                          float]:
        """Serve one batch from the queue head.

        Drains up to ``max_bucket`` requests FIFO, executes the padded
        bucket on ``replica`` (or the round-robin next), and returns
        ``([(rid, result_row), ...], service_time_s)``.  With
        ``service_model`` (a ``bucket -> seconds`` callable) the
        returned/recorded service time is injected — the deterministic
        virtual-clock mode; otherwise it is the measured wall time of
        the forward.  An empty queue returns ``([], 0.0)``."""
        with self._lock:
            if not self._queue:
                return [], 0.0
            take = min(len(self._queue), self.grid.max_bucket)
            reqs = [self._queue.popleft() for _ in range(take)]
            if replica is None:
                replica = self._rr % len(self.replicas)
            self._rr += 1
        bucket = self.grid.bucket_for(take)
        self._ensure_warm(bucket)
        rep = self.replicas[replica]
        for rid, _, _ in reqs:
            self.recorder.batch(rid, now, bucket=bucket, replica=rep.name,
                                batch_real=take)
            self.recorder.execute(rid, now)
        batch = self._pad_batch([x for _, x, _ in reqs], bucket)
        t0 = time.perf_counter()
        out = np.asarray(rep.fn(batch))
        measured = time.perf_counter() - t0
        self._collect_guard(rep.name)
        dt = float(service_model(bucket)) if service_model else measured
        done = now + dt
        results = []
        for i, (rid, _, _) in enumerate(reqs):
            self.recorder.complete(rid, done)
            results.append((rid, out[i]))
        with self._lock:
            self.served += take
            self._bucket_counts[bucket] = \
                self._bucket_counts.get(bucket, 0) + 1
            self._replica_served[rep.name] += take
        return results, dt

    def forward_one(self, x) -> np.ndarray:
        """The single-request tuned forward (bucket 1 on replica 0) —
        the differential oracle every served row must bit-match."""
        batch = self._pad_batch([np.asarray(x)], self.grid.bucket_for(1))
        return np.asarray(self.replicas[0].fn(batch))[0]

    # -- prewarm ------------------------------------------------------------

    def prewarm(self, *, tune: bool = True, compile: bool = True) -> dict:
        """Make every (bucket, replica) path hot before the first
        request: sweep the autotune cache over the bucket grid
        (:func:`repro.core.autotune.prewarm_buckets` — skipped for
        engines without a topology) and run one throwaway forward per
        bucket per replica to populate the JIT cache.  Returns the
        per-bucket tune records."""
        records: dict = {}
        if tune and self.topo is not None:
            from repro.core import autotune
            records = autotune.prewarm_buckets(
                self.topo, self.grid.buckets, fused=self.fused,
                **self.tune_kwargs)
        if compile and self.input_shape is not None:
            for b in self.grid.buckets:
                zeros = np.zeros((b,) + self.input_shape, np.float32)
                for rep in self.replicas:
                    rep.fn(zeros)
        self._warm.update(self.grid.buckets)
        return records

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        """Serving counters + per-replica degradation report.  A replica
        with guard events kept serving on a fallback tier — degraded,
        labeled, never silent (DESIGN.md §9/§10)."""
        with self._lock:
            per_replica = {
                name: {"served": self._replica_served[name],
                       "degraded": bool(self._replica_events[name]),
                       "guard_events": [dict(e) for e in
                                        self._replica_events[name]]}
                for name in self._replica_served}
            return {
                "served": self.served,
                "pending": len(self._queue),
                "cold_tunes": self.cold_tunes,
                "prewarmed_buckets": sorted(self._warm),
                "bucket_batches": dict(sorted(
                    self._bucket_counts.items())),
                "max_queue_depth": self.recorder.max_queue_depth,
                "rejected": len(self.recorder.rejected),
                "replicas": per_replica,
            }


# ---------------------------------------------------------------------------
# Deterministic open-loop replay
# ---------------------------------------------------------------------------

def replay(engine: ServingEngine, trace, *, service_model=None,
           start: float = 0.0):
    """Event-driven replay of an arrival trace against the engine.

    ``trace`` is an iterable of ``(t_arrival, rid, x)``; arrivals are
    open-loop (they ignore service progress, like real traffic).  The
    loop advances a virtual timeline: a batch starts at
    ``max(earliest free replica, head-of-queue arrival)``, and every
    request arriving at or before that instant joins the queue first —
    continuous batching, replicas kept busy whenever work is queued.
    Arrivals that hit a full queue are rejected (recorded, not raised:
    open-loop load sheds at the backpressure bound).

    With ``service_model`` (``bucket -> seconds``) the whole timeline is
    deterministic — same trace, same results, same timestamps; without
    it, service times are the measured wall time of each real forward
    (the benchmark mode: real kernels under a deterministic arrival
    pattern).

    Returns ``(results, rejected)``: ``{rid: output_row}`` for every
    served request and the rid list of shed ones.  Lifecycle timestamps
    land in ``engine.recorder``.
    """
    trace = sorted(trace, key=lambda e: e[0])
    free = [float(start)] * len(engine.replicas)
    results: dict[int, np.ndarray] = {}
    rejected: list[int] = []
    i, n = 0, len(trace)

    def admit(j: int) -> None:
        t, rid, x = trace[j]
        try:
            engine.submit(rid, x, now=t)
        except QueueFull:
            engine.recorder.reject(rid, t)
            rejected.append(rid)

    while i < n or engine.pending():
        if engine.pending() == 0:
            admit(i)
            i += 1
            continue
        r = int(np.argmin(free))
        t_start = max(free[r], engine.head_enqueue_time())
        # continuous batching: arrivals landing before this batch can
        # start join it (queue permitting)
        while i < n and trace[i][0] <= t_start:
            admit(i)
            i += 1
        out, dt = engine.step(now=t_start, replica=r,
                              service_model=service_model)
        free[r] = t_start + dt
        for rid, y in out:
            results[rid] = y
    return results, rejected
