"""Depthwise causal conv1d (Mamba / RG-LRU temporal conv) as a Pallas kernel.

The 1D image of the 3D-TrIM dataflow: the sequence is tiled into
non-overlapping chunks of ``TL`` steps; the ``K-1`` boundary timesteps are
carried across grid steps in a VMEM scratch (shadow registers) instead of
being re-fetched from HBM; the channel axis is tiled for the VPU lanes.

At decode time the same carry *is* the inference state — see
``ref.depthwise_conv1d_step``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, carry_ref, *, k: int, tl: int):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)  # causal left padding

    window = jnp.concatenate([carry_ref[...], x_ref[0]], axis=0)  # (TL+K-1, TD)
    acc = jnp.zeros((tl, o_ref.shape[-1]), jnp.float32)
    for i in range(k):
        acc += window[i:i + tl].astype(jnp.float32) * w_ref[i].astype(jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)
    carry_ref[...] = window[-(k - 1):]


@functools.partial(jax.jit, static_argnames=("tile_l", "tile_d", "interpret"))
def trim_conv1d(x: jax.Array, w: jax.Array, *, tile_l: int | None = None,
                tile_d: int | None = None, interpret: bool = True
                ) -> jax.Array:
    """Causal depthwise conv1d.  x: (B, L, D); w: (K, D) -> (B, L, D)."""
    b, length, d = x.shape
    k, _ = w.shape
    assert k >= 2
    if tile_l is None:
        tile_l = min(length, 512)
    if tile_d is None:
        tile_d = min(d, 1024 if d % 128 == 0 else d)
    g_tiles = math.ceil(length / tile_l)
    d_tiles = math.ceil(d / tile_d)
    lp = g_tiles * tile_l
    xp = jnp.pad(x, ((0, 0), (0, lp - length), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, k=k, tl=tile_l),
        # g innermost: the carry is valid within one (batch, channel) sweep
        grid=(b, d_tiles, g_tiles),
        in_specs=[
            pl.BlockSpec((1, tile_l, tile_d), lambda bi, di, g: (bi, g, di)),
            pl.BlockSpec((k, tile_d), lambda bi, di, g: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, tile_l, tile_d),
                               lambda bi, di, g: (bi, g, di)),
        out_shape=jax.ShapeDtypeStruct((b, lp, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((k - 1, tile_d), x.dtype)],
        interpret=interpret,
    )(xp, w)
    return out[:, :length]
