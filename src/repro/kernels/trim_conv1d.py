"""Depthwise causal conv1d (Mamba / RG-LRU temporal conv) as a Pallas kernel.

The 1D image of the 3D-TrIM dataflow: the sequence is tiled into
non-overlapping chunks of ``TL`` steps; the ``K-1`` boundary timesteps are
carried across grid steps in a VMEM scratch (shadow registers) instead of
being re-fetched from HBM; the channel axis is tiled for the VPU lanes.

Chunk geometry, grid and carry shapes come from
``core.conv_plan.Conv1dPlan`` — the same plan object that models the
kernel's HBM traffic.

At decode time the same carry *is* the inference state — see
``ref.depthwise_conv1d_step``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_plan import Conv1dPlan
from repro.kernels.runtime import resolve_interpret


def _kernel(x_ref, w_ref, o_ref, carry_ref, *, k: int, tl: int):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)  # causal left padding

    window = jnp.concatenate([carry_ref[...], x_ref[0]], axis=0)  # (TL+K-1, TD)
    acc = jnp.zeros((tl, o_ref.shape[-1]), jnp.float32)
    for i in range(k):
        acc += window[i:i + tl].astype(jnp.float32) * w_ref[i].astype(jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)
    carry_ref[...] = window[-(k - 1):]


@functools.partial(jax.jit, static_argnames=("tile_l", "tile_d", "interpret"))
def trim_conv1d(x: jax.Array, w: jax.Array, *, tile_l: int | None = None,
                tile_d: int | None = None, interpret: bool | None = None
                ) -> jax.Array:
    """Causal depthwise conv1d.  x: (B, L, D); w: (K, D) -> (B, L, D).
    ``interpret=None`` auto-detects the backend (native on TPU)."""
    assert w.shape[0] >= 2
    interpret = resolve_interpret(interpret)
    plan = Conv1dPlan.build(x.shape, w.shape, dtype_bytes=x.dtype,
                            tile_l=tile_l, tile_d=tile_d)
    xp = jnp.pad(x, ((0, 0), (0, plan.length_padded - plan.length), (0, 0)))
    assert xp.shape == plan.padded_input_shape

    out = pl.pallas_call(
        functools.partial(_kernel, k=plan.k, tl=plan.tile_l),
        # g innermost: the carry is valid within one (batch, channel) sweep
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec(plan.in_block, lambda bi, di, g: (bi, g, di)),
            pl.BlockSpec(plan.w_block, lambda bi, di, g: (0, di)),
        ],
        out_specs=pl.BlockSpec(plan.in_block,
                               lambda bi, di, g: (bi, g, di)),
        out_shape=jax.ShapeDtypeStruct(plan.padded_input_shape, x.dtype),
        scratch_shapes=[pltpu.VMEM(plan.carry_shape, x.dtype)],
        interpret=interpret,
    )(xp, w)
    return out[:, :plan.length]
