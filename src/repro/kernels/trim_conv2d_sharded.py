"""Sharded TrIM convolution execution under ``shard_map`` (DESIGN.md §6).

The multi-device image of the paper's shadow-register overlap: each
device owns an H-slab of the (pre-padded) ifmap and a strip of the
output rows; before the local Pallas kernel runs, the K-1 boundary rows
move between neighbors as an explicit ``ppermute`` halo exchange — the
on-chip carry traffic of ``ConvPlan`` made into real inter-chip bytes,
which :class:`~repro.core.conv_shard.ShardedConvPlan` bills as a
first-class roofline term.

Per-shard schedule (geometry owned by the plan):

1. **Slab split.**  The globally padded input is padded/cropped to
   exactly ``spatial_shards * slab_rows`` rows plus a K-1 row tail; the
   slabs shard over ``spatial_axis``, the tail stays with the batch.
2. **Halo exchange.**  Shard ``d`` receives the first K-1 slab rows of
   shard ``d+1`` (*down*; the last shard's down-halo is the local
   tail).  Slabs are stride-aligned by construction, so this single
   direction assembles every owned output row's full receptive field —
   nothing is recomputed.
3. **Local kernel.**  The assembled ``local_in_rows`` window runs
   through the ordinary carry/halo Pallas kernel (``local_conv``; the
   differentiable custom_vjp core when called via ``ops.conv2d``) as a
   valid stride-``s`` conv, emitting exactly the owned ``h_out_local``
   rows per shard.

Because the whole function is ordinary traced jax, the backward pass
falls out of transposition: the input-grad halo exchange is the
transpose of the forward ``ppermute`` shuffle (boundary cotangent rows
flow back to the neighbor that owns them), and the weight/bias
cotangents of the replicated operands finish with a ``psum`` over the
mesh.  The per-shard cotangent kernels are the custom_vjp backward
kernels of the local conv — the single-device machinery, per shard.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.conv_shard import ShardedConvPlan


def _shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions (experimental home on 0.4.x)."""
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:                      # pragma: no cover - newer jax
        from jax import shard_map
    try:
        return shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=False)
    except TypeError:                        # pragma: no cover - newer jax
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)


def make_sharded_plan(x_shape, w_shape, mesh, *, rules: dict | None = None,
                      **kw) -> ShardedConvPlan:
    """The exact plan :func:`sharded_conv2d` executes for these
    arguments on this mesh (shard grid resolved from the conv rules)."""
    return ShardedConvPlan.from_mesh(x_shape, w_shape, mesh, rules=rules,
                                     **kw)


def sharded_conv2d(x: jax.Array, w: jax.Array,
                   bias: jax.Array | None = None, *,
                   plan: ShardedConvPlan, mesh,
                   local_conv=None,
                   interpret: bool | None = None) -> jax.Array:
    """Run one sharded conv according to ``plan`` under ``shard_map``.

    x: (N, H, W, Cin) **already pre-padded** (``plan.pad == 0`` — the
    caller folds 'same' padding globally, exactly like the single-device
    path); w: (K, K, Cin/groups, Cout) logical weights (replicated);
    bias: (Cout,) or None (replicated).

    ``local_conv(window, w, bias)`` executes one shard's valid
    stride-``plan.stride`` convolution; it defaults to the raw
    ``trim_conv2d`` kernel with the plan's knobs — ``ops.conv2d`` passes
    its differentiable custom_vjp core instead so gradients run on the
    Pallas backward kernels per shard.
    Returns the global (N, H_out, W_out, Cout).
    """
    if plan.pad != 0:
        raise ValueError("sharded_conv2d expects pre-padded input "
                         f"(plan.pad == 0), got pad={plan.pad}")
    assert x.shape == (plan.n, plan.h, plan.w, plan.cin), \
        (x.shape, plan)
    s, kh, ss = plan.stride, plan.kh, plan.spatial_shards
    slab = plan.slab_rows
    total, tail = ss * slab, kh - 1
    ba, sa = plan.batch_axis, plan.spatial_axis

    if local_conv is None:
        from repro.kernels.trim_conv2d import trim_conv2d
        local_conv = functools.partial(
            trim_conv2d, stride=s, pad=0, tile_h=plan.tile_h,
            tile_cout=plan.tile_cout, groups=plan.groups,
            dataflow=plan.dataflow, interpret=interpret)

    # slab split: exactly ss * slab_rows rows shard over the spatial
    # axis; the K-1 tail (real rows beyond the slabs, or zero padding)
    # rides replicated along it so the last shard's down-halo is local
    grow = total + tail - x.shape[1]
    xr = jnp.pad(x, ((0, 0), (0, max(grow, 0)), (0, 0), (0, 0)))
    xr = xr[:, :total + tail]
    x_main, x_tail = xr[:, :total], xr[:, total:]

    hops = -(-tail // slab) if tail else 0   # neighbor hops per exchange

    def _down_halo(xm, xt):
        """The K-1 rows below the slab: global rows [(d+1)*slab,
        (d+1)*slab + K-1).  Usually one ppermute from the next shard;
        when slabs are shorter than K-1 (over-sharded tail shards) the
        window spans several neighbors — hop ``j`` fetches shard
        ``d+j``'s slab prefix, and sources past the last slab read the
        replicated global tail instead."""
        if ss == 1:
            return xt
        idx = jax.lax.axis_index(sa)
        xtp = jnp.pad(xt, ((0, 0), (0, hops * slab - tail), (0, 0),
                           (0, 0)))
        parts, got = [], 0
        for j in range(1, hops + 1):
            take = min(slab, tail - got)
            src = xm[:, :take]
            perm = [(i + j, i) for i in range(ss - j)]
            hop = jax.lax.ppermute(src, sa, perm) if perm \
                else jnp.zeros_like(src)
            from_tail = jax.lax.dynamic_slice_in_dim(
                xtp, jnp.clip(idx + j - ss, 0, j - 1) * slab, take,
                axis=1)
            parts.append(jnp.where(idx + j >= ss, from_tail, hop))
            got += take
        return parts[0] if hops == 1 else jnp.concatenate(parts, axis=1)

    def _local(xm, xt, wl, bl):
        window = xm if not tail \
            else jnp.concatenate([xm, _down_halo(xm, xt)], axis=1)
        return local_conv(window, wl, bl)

    in_specs = [P(ba, sa, None, None), P(ba, None, None, None), P()]
    args = [x_main, x_tail, w]
    if bias is None:
        fn = lambda xm, xt, wl: _local(xm, xt, wl, None)  # noqa: E731
    else:
        fn = _local
        in_specs.append(P())
        args.append(bias)

    out = _shard_map(fn, mesh, tuple(in_specs),
                     P(ba, sa, None, None))(*args)
    assert out.shape[1] == ss * plan.h_out_local, (out.shape, plan)
    return out[:, :plan.h_out]
