"""Backend-dependent kernel runtime switches.

The Pallas kernels take ``interpret=None`` by default and resolve it here:
interpret mode everywhere *except* a real TPU backend, where the same call
site lowers natively.  Tests can still force ``interpret=True/False``.
"""

from __future__ import annotations

import functools

import jax


@functools.cache
def on_tpu() -> bool:
    """True when the default JAX backend is a TPU (fixed per process)."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` auto-detects: native lowering on TPU, interpreter off-TPU."""
    return (not on_tpu()) if interpret is None else interpret
