"""Pallas TPU kernels (validated in interpret mode) + jnp oracles.

Each kernel module provides a ``pl.pallas_call`` with explicit BlockSpec
VMEM tiling; ``ops.py`` is the jit'd public API; ``ref.py`` the oracle.
"""

from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.trim_conv1d import trim_conv1d  # noqa: F401
from repro.kernels.trim_conv2d import trim_conv2d  # noqa: F401
