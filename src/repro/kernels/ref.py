"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the ground truth for the allclose sweeps in tests/ and the
fallback implementation on platforms without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def epilogue(y: jax.Array, bias: jax.Array | None = None,
             activation: str | None = None) -> jax.Array:
    """Bias + activation epilogue oracle (fused into trim_conv2d)."""
    if bias is not None:
        y = y + bias
    if activation is None:
        return y
    fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
          "silu": jax.nn.silu}[activation]
    return fn(y)


def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
           padding: str = "same", feature_group_count: int = 1,
           bias: jax.Array | None = None,
           activation: str | None = None) -> jax.Array:
    """2D (grouped) convolution oracle.

    x: (N, H, W, Cin); w: (K, K, Cin/groups, Cout); bias: (Cout,) or None.
    """
    pad = padding.upper()
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count)
    return epilogue(y, bias, activation)


def conv2d_grads(x: jax.Array, w: jax.Array, gy: jax.Array, *,
                 stride: int = 1, padding: str = "same",
                 feature_group_count: int = 1) -> tuple:
    """Canonical (dx, dw) oracle: ``jax.vjp`` on the XLA convolution.

    Every kernel gradient test compares against this single source —
    the same ``lax.conv_general_dilated`` the forward oracle wraps.
    """
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding=padding.upper(),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=feature_group_count)
    _, vjp = jax.vjp(f, x, w)
    return vjp(gy)


def conv2d_input_grad(x: jax.Array, w: jax.Array, gy: jax.Array, *,
                      stride: int = 1, padding: str = "same",
                      feature_group_count: int = 1) -> jax.Array:
    """Input cotangent of the conv2d oracle."""
    return conv2d_grads(x, w, gy, stride=stride, padding=padding,
                        feature_group_count=feature_group_count)[0]


def conv2d_weight_grad(x: jax.Array, w: jax.Array, gy: jax.Array, *,
                       stride: int = 1, padding: str = "same",
                       feature_group_count: int = 1) -> jax.Array:
    """Weight cotangent of the conv2d oracle."""
    return conv2d_grads(x, w, gy, stride=stride, padding=padding,
                        feature_group_count=feature_group_count)[1]


def depthwise_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Causal depthwise conv1d oracle (Mamba / RG-LRU temporal conv).

    x: (B, L, D); w: (K, D).  y[b, t, d] = sum_k x[b, t-K+1+k, d] * w[k, d].
    """
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))


def depthwise_conv1d_step(state: jax.Array, x_t: jax.Array, w: jax.Array):
    """Single decode step.  state: (B, K-1, D) trailing inputs; x_t: (B, D).

    Returns (new_state, y_t).  The state is the decode-time image of the
    shadow registers: the K-1 values carried across step boundaries.
    """
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, D)
    y_t = jnp.einsum("bkd,kd->bd", window, w)
    return window[:, 1:, :], y_t


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, logits_soft_cap: float | None = None,
              window: int | None = None) -> jax.Array:
    """Dense attention oracle with GQA.

    q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D); Hq % Hkv == 0.
    ``window``: optional local-attention span (RecurrentGemma).
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, lq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / jnp.sqrt(d).astype(q.dtype)
    if logits_soft_cap is not None:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)
    q_pos = jnp.arange(lq)[:, None] + (lk - lq)
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, lq, hq, d)


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)
