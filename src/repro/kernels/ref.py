"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the ground truth for the allclose sweeps in tests/ and the
fallback implementation on platforms without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def epilogue(y: jax.Array, bias: jax.Array | None = None,
             activation: str | None = None) -> jax.Array:
    """Bias + activation epilogue oracle (fused into trim_conv2d)."""
    if bias is not None:
        y = y + bias
    if activation is None:
        return y
    fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
          "silu": jax.nn.silu}[activation]
    return fn(y)


def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
           padding: str = "same", feature_group_count: int = 1,
           bias: jax.Array | None = None,
           activation: str | None = None) -> jax.Array:
    """2D (grouped) convolution oracle.

    x: (N, H, W, Cin); w: (K, K, Cin/groups, Cout); bias: (Cout,) or None.
    """
    pad = padding.upper()
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count)
    return epilogue(y, bias, activation)


def _same_pads(size: int, k: int, s: int) -> tuple[int, int]:
    """XLA 'SAME' padding: out = ceil(size/s), possibly asymmetric."""
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    return total // 2, total - total // 2


def quantize_int8(x: jax.Array, scale, zero_point=0) -> jax.Array:
    """Affine int8 quantization ``q = clip(round(x/scale) + zp, -128, 127)``.

    ``scale``/``zero_point`` may be scalars (per-tensor activations) or
    broadcastable arrays (per-channel weights with ``zero_point=0``).
    """
    q = jnp.round(x / jnp.asarray(scale, jnp.float32))
    q = q + jnp.asarray(zero_point, jnp.float32)
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def weight_scales_int8(w: jax.Array) -> jax.Array:
    """Per-out-channel symmetric weight scales: ``max|w| / 127``.

    w: (K, K, Cin/g, Cout) -> (Cout,) f32.  Symmetric (zero_point = 0),
    so the int8 matmul needs no weight zero-point correction.
    """
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=(0, 1, 2))
    return jnp.maximum(amax, 1e-12) / 127.0


def dequant_params(w_q: jax.Array, w_scale: jax.Array, x_scale,
                   x_zero_point, bias: jax.Array | None = None) -> tuple:
    """The epilogue ``y = (acc_i32 + bias_q) * scale`` of an int8 conv.

    ``scale = x_scale * w_scale`` per out channel, and ``bias_q`` is the
    *requantized int32 bias*:

        bias_q = -z_x * colsum(w_q) + round(bias / scale)

    Because the ifmap is padded with the activation *zero point* (not
    zero), every output position sees a full kernel window of quantized
    values, so the zero-point correction ``-z_x * colsum`` is
    position-independent — and exactly integer.  The real bias is
    rounded onto the ``scale`` grid (the standard fixed-point bias
    treatment), which keeps the whole epilogue an exact int32 add
    followed by ONE correctly-rounded f32 multiply: no mul+add pair
    exists for a backend to contract into an FMA, so the kernel and the
    oracle agree bit-for-bit on every backend.

    Works on logical ``(K, K, Cin/g, Cout)`` weights with ``(Cout,)``
    scales and on the kernel's padded layout with ``(1, G*CoutP)`` rows
    (pad ``w_scale`` with ones so the bias requantization never divides
    by zero) — the kernel and the oracle MUST both price their epilogue
    through this one helper for the bit-exactness contract of
    ``tests/test_quant.py`` to hold.
    """
    colsum = w_q.astype(jnp.int32).sum(axis=(0, 1, 2))
    scale = jnp.asarray(x_scale, jnp.float32) * w_scale.astype(jnp.float32)
    bias_q = -jnp.asarray(x_zero_point, jnp.int32) * colsum
    if bias is not None:
        bias_q = bias_q + jnp.round(
            bias.astype(jnp.float32) / scale).astype(jnp.int32)
    return scale, bias_q


def conv2d_quantized(x_q: jax.Array, w_q: jax.Array, *, x_scale,
                     x_zero_point, w_scale: jax.Array,
                     bias: jax.Array | None = None, stride: int = 1,
                     padding: str = "same", feature_group_count: int = 1,
                     activation: str | None = None) -> jax.Array:
    """Int8 quantized conv oracle: int32 accumulation, f32 dequant epilogue.

    x_q: int8 (N, H, W, Cin); w_q: int8 (K, K, Cin/g, Cout); w_scale:
    (Cout,) per-out-channel symmetric scales; ``x_scale``/``x_zero_point``
    the per-tensor affine activation quantization.  'same' padding pads
    with the activation zero point (the quantized image of 0.0), so the
    result dequantizes to the f32 'same' conv.  Returns f32.
    """
    if padding == "same":
        kh, kw = w_q.shape[0], w_q.shape[1]
        ph = _same_pads(x_q.shape[1], kh, stride)
        pw = _same_pads(x_q.shape[2], kw, stride)
        zp = jnp.asarray(x_zero_point, x_q.dtype)
        x_q = jax.lax.pad(x_q, zp, ((0, 0, 0), (*ph, 0), (*pw, 0),
                                    (0, 0, 0)))
    elif padding != "valid":
        raise ValueError(f"padding={padding!r} must be 'same' or 'valid'")
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count)
    scale, bias_q = dequant_params(w_q, w_scale, x_scale, x_zero_point,
                                   bias)
    y = (acc + bias_q).astype(jnp.float32) * scale
    return epilogue(y, None, activation)


def conv2d_grads(x: jax.Array, w: jax.Array, gy: jax.Array, *,
                 stride: int = 1, padding: str = "same",
                 feature_group_count: int = 1) -> tuple:
    """Canonical (dx, dw) oracle: ``jax.vjp`` on the XLA convolution.

    Every kernel gradient test compares against this single source —
    the same ``lax.conv_general_dilated`` the forward oracle wraps.
    """
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding=padding.upper(),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=feature_group_count)
    _, vjp = jax.vjp(f, x, w)
    return vjp(gy)


def conv2d_input_grad(x: jax.Array, w: jax.Array, gy: jax.Array, *,
                      stride: int = 1, padding: str = "same",
                      feature_group_count: int = 1) -> jax.Array:
    """Input cotangent of the conv2d oracle."""
    return conv2d_grads(x, w, gy, stride=stride, padding=padding,
                        feature_group_count=feature_group_count)[0]


def conv2d_weight_grad(x: jax.Array, w: jax.Array, gy: jax.Array, *,
                       stride: int = 1, padding: str = "same",
                       feature_group_count: int = 1) -> jax.Array:
    """Weight cotangent of the conv2d oracle."""
    return conv2d_grads(x, w, gy, stride=stride, padding=padding,
                        feature_group_count=feature_group_count)[1]


def depthwise_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Causal depthwise conv1d oracle (Mamba / RG-LRU temporal conv).

    x: (B, L, D); w: (K, D).  y[b, t, d] = sum_k x[b, t-K+1+k, d] * w[k, d].
    """
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))


def depthwise_conv1d_step(state: jax.Array, x_t: jax.Array, w: jax.Array):
    """Single decode step.  state: (B, K-1, D) trailing inputs; x_t: (B, D).

    Returns (new_state, y_t).  The state is the decode-time image of the
    shadow registers: the K-1 values carried across step boundaries.
    """
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, D)
    y_t = jnp.einsum("bkd,kd->bd", window, w)
    return window[:, 1:, :], y_t


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, logits_soft_cap: float | None = None,
              window: int | None = None) -> jax.Array:
    """Dense attention oracle with GQA.

    q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D); Hq % Hkv == 0.
    ``window``: optional local-attention span (RecurrentGemma).
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, lq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / jnp.sqrt(d).astype(q.dtype)
    if logits_soft_cap is not None:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)
    q_pos = jnp.arange(lq)[:, None] + (lk - lq)
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, lq, hq, d)


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)
