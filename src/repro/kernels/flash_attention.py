"""Tiled online-softmax attention (FlashAttention) as a Pallas kernel.

This is the transformer hot spot of the assigned LM architectures.  The
schedule follows the same fetch-once contract as the conv kernel: the Q
block is the stationary operand resident in VMEM; K/V tiles stream through
VMEM exactly once per Q block; the softmax normalizer (m, l) and output
accumulator live in VMEM scratch across the KV grid steps.

GQA is handled in the index maps: the K/V BlockSpec maps a query head to
its KV group head, so KV tiles are never replicated in HBM.

Supports causal masking, local windows (RecurrentGemma) and logit soft
caps.  Validated against ``ref.attention`` in interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, soft_cap: float | None,
            window: int | None, block_q: int, block_k: int,
            lq: int, lk: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)

    # absolute positions (queries are right-aligned for decode: off = lk-lq)
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q) + (lk - lq)
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = (k_pos < lk)[None, :] & (q_pos < lk)[:, None]
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "soft_cap", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, soft_cap: float | None = None,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D) -> (B, Lq, Hq, D).
    ``interpret=None`` auto-detects the backend (native on TPU)."""
    interpret = resolve_interpret(interpret)
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    sm_scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, max(lq, 16))
    block_k = min(block_k, max(lk, 16))
    nq = math.ceil(lq / block_q)
    nk = math.ceil(lk / block_k)

    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, lq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, lk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, lk, d)
    qf = jnp.pad(qf, ((0, 0), (0, nq * block_q - lq), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, nk * block_k - lk), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, nk * block_k - lk), (0, 0)))

    def kv_head(bh):
        return (bh // hq) * hkv + (bh % hq) // group

    out = pl.pallas_call(
        functools.partial(
            _kernel, sm_scale=sm_scale, causal=causal, soft_cap=soft_cap,
            window=window, block_q=block_q, block_k=block_k,
            lq=lq, lk=lk, n_kv=nk),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik: (kv_head(bh), ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik: (kv_head(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # denominator l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :lq].reshape(b, hq, lq, d).transpose(0, 2, 1, 3)
