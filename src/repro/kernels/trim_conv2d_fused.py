"""Fused residency-group megakernel: conv→[pool]→conv chains in one
``pallas_call`` (DESIGN.md §8).

One grid step computes one *strip* of the group's final pooled output
and the whole stage chain feeding it, with every interior activation
resident in VMEM — the paper's shadow-register reuse lifted from
within-layer to between-layer.  The geometry comes from
:class:`~repro.core.fuse_plan.FusedGroup`: stage *i*'s input rows are an
affine window (``in_start + g*in_step``, ``in_rows`` wide) of stage
*i-1*'s pooled output, chained back to an overlapping element-offset
window of the HBM input (the only activation fetch the group pays).

Three design points keep this exactly equal to the per-layer path:

* **Identical tap math** — each stage runs the same ``(ki, kj)``-ordered
  tap loop as ``trim_conv2d._tap_matmuls``: fp32 accumulator, one MXU
  matmul per tap, bias added on the fp32 accumulator, activation, cast.
  A column split of the weight (per-layer ``tile_cout``) or a row split
  of the strip never changes an output element's reduction order, so
  the fused forward bit-matches the per-layer forward.

* **Masked rows ARE the next stage's padding** — rows of a strip buffer
  outside a stage's valid extent are forced to zero after pooling
  (a ``broadcasted_iota`` over global row indices), which makes them
  *exactly* the 'same'-padding zeros the next conv expects.  Valid
  pooled rows provably never read garbage conv rows: a valid pooled row
  ``r`` reads conv rows ``[r*ps, r*ps+pw) ⊆ [0, H_conv)``, and a valid
  conv row's window stays inside the 'same'-padded input.  W padding is
  applied in-kernel with ``jnp.pad`` (exact zeros).

* **Streamed weights** — weight tensors stay in HBM (``pltpu.ANY``) and
  one ``(Cin, Cout)`` tap slice at a time is DMA'd into a VMEM scratch
  buffer, so the VMEM working set is windows + accumulators + one tap
  per stage.  That is what makes 512-channel groups feasible at all.

Gradients: the fused op is a ``jax.custom_vjp`` whose backward pass
*recomputes* through the equivalent per-layer chain (``ops.conv2d`` +
max-pool) with ``jax.vjp`` — so cotangents run on the existing TrIM
backward kernels and training sees fused-forward speed at unchanged
gradient math (standard rematerialization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret
from repro.kernels.trim_conv2d import ACTIVATIONS


def _maxpool(x, stride, window):
    """VALID max-pool on NHWC, identical to ``models/layers._maxpool``."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------

def _stage_conv(buf, tap_load, b_ref, st, *, activation, dtype):
    """One conv stage on a resident row buffer: 'same' W-pad, the
    ``(ki, kj)``-ordered tap matmuls of ``trim_conv2d._tap_matmuls``
    (weights arriving via ``tap_load``), then the exact per-layer
    epilogue (fp32 bias add, activation, cast)."""
    k, s = st.kernel, st.stride
    xp = jnp.pad(buf, ((0, 0), (st.pad_lo, st.pad_hi), (0, 0)))
    acc = jnp.zeros((st.conv_rows * st.w_conv, st.cout), jnp.float32)
    for ki in range(k):
        for kj in range(k):
            tap = tap_load(ki, kj)                      # (Cin, Cout)
            rows = xp[ki: ki + (st.conv_rows - 1) * s + 1: s,
                      kj: kj + (st.w_conv - 1) * s + 1: s, :]
            acc += jnp.dot(rows.reshape(st.conv_rows * st.w_conv, st.cin),
                           tap, preferred_element_type=jnp.float32)
    acc += b_ref[0].astype(jnp.float32)
    acc = ACTIVATIONS[activation](acc)
    return acc.reshape(st.conv_rows, st.w_conv, st.cout).astype(dtype)


def _stage_pool(y, st):
    """VALID max-pool of one stage's conv strip — a static max tree over
    the (pw x pw) shifted strided views, exactly ``reduce_window`` max."""
    if not st.pooled:
        return y
    ps, pw = st.pool_stride, st.pool_window
    out = None
    for wi in range(pw):
        for wj in range(pw):
            v = y[wi: wi + (st.pool_rows - 1) * ps + 1: ps,
                  wj: wj + (st.w_pool - 1) * ps + 1: ps, :]
            out = v if out is None else jnp.maximum(out, v)
    return out


def _fused_kernel(group, activation, dtype, *refs):
    """refs = x_ref, (w_ref, b_ref) per stage, o_ref, tap scratch per
    stage, DMA semaphore."""
    depth = group.depth
    x_ref = refs[0]
    wb = refs[1:1 + 2 * depth]
    o_ref = refs[1 + 2 * depth]
    taps = refs[2 + 2 * depth: 2 + 3 * depth]
    sem = refs[2 + 3 * depth]
    g = pl.program_id(1)

    buf = x_ref[0]                                 # (in_rows0, W0, Cin0)
    for i, st in enumerate(group.stages):
        w_ref, b_ref, tap_ref = wb[2 * i], wb[2 * i + 1], taps[i]

        def tap_load(ki, kj, w_ref=w_ref, tap_ref=tap_ref):
            cp = pltpu.make_async_copy(w_ref.at[ki, kj], tap_ref, sem)
            cp.start()
            cp.wait()
            return tap_ref[...]

        y = _stage_conv(buf, tap_load, b_ref, st,
                        activation=activation, dtype=dtype)
        y = _stage_pool(y, st)
        # zero every row outside the stage's valid pooled extent: those
        # rows are garbage (bias-activated padding) and, once zeroed,
        # they are exactly the next stage's 'same' H-padding.
        start = st.pool_start + g * st.pool_step
        idx = jax.lax.broadcasted_iota(
            jnp.int32, (st.pool_rows, 1, 1), 0) + start
        buf = jnp.where((idx >= 0) & (idx < st.h_pool), y,
                        jnp.zeros_like(y))
    o_ref[0] = buf


# ---------------------------------------------------------------------------
# pallas_call wrapper
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("group", "activation", "interpret"))
def _fused_forward(x, weights, biases, *, group, activation, interpret):
    interpret = resolve_interpret(interpret)
    s0, lt = group.stages[0], group.last
    dtype = x.dtype
    xp = jnp.pad(x, ((0, 0), (group.extra_top, group.pad_bottom),
                     (0, 0), (0, 0)))

    in_specs = [pl.BlockSpec(
        (1, s0.in_rows, s0.w_in, s0.cin),
        lambda n, g: (n, group.in_row_offset(g), 0, 0),
        indexing_mode=pl.unblocked)]
    for st in group.stages:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        in_specs.append(pl.BlockSpec((1, st.cout), lambda n, g: (0, 0)))
    scratch = [pltpu.VMEM((st.cin, st.cout), dtype) for st in group.stages]
    scratch.append(pltpu.SemaphoreType.DMA)

    compiler_params = None
    if not interpret:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel"))

    operands = [xp]
    for w, b in zip(weights, biases):
        operands.append(w)
        operands.append(b.reshape(1, -1).astype(dtype))

    out = pl.pallas_call(
        functools.partial(_fused_kernel, group, activation, dtype),
        grid=(group.n, group.n_strips),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, group.strip_rows, lt.w_pool, lt.cout),
            lambda n, g: (n, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(group.padded_output_shape, dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params,
        interpret=interpret,
    )(*operands)
    return out[:, :lt.h_pool]


def reference_chain(x, weights, biases, *, group, activation="relu",
                    impl="pallas", use_autotune_cache=False):
    """The per-layer execution of the same group: ``ops.conv2d`` (with
    its 'same' pre-pad and TrIM kernels) + a separate max-pool per
    stage.  This is both the differential-test oracle for the megakernel
    and the recompute path of its backward pass."""
    from repro.kernels import ops
    for st, w, b in zip(group.stages, weights, biases):
        padding = "same" if (st.pad_lo or st.pad_hi) else "valid"
        x = ops.conv2d(x, w, stride=st.stride, padding=padding,
                       impl=impl, bias=b, activation=activation,
                       use_autotune_cache=use_autotune_cache)
        if st.pooled:
            x = _maxpool(x, st.pool_stride, st.pool_window)
    return x


# ---------------------------------------------------------------------------
# custom_vjp: fused forward, per-layer recompute backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _fused_vjp(group, activation, interpret, x, weights, biases):
    return _fused_forward(x, weights, biases, group=group,
                          activation=activation, interpret=interpret)


def _fused_vjp_fwd(group, activation, interpret, x, weights, biases):
    out = _fused_forward(x, weights, biases, group=group,
                         activation=activation, interpret=interpret)
    return out, (x, weights, biases)


def _fused_vjp_bwd(group, activation, interpret, res, gy):
    x, weights, biases = res

    def chain(x_, ws_, bs_):
        return reference_chain(x_, ws_, bs_, group=group,
                               activation=activation)

    _, vjp = jax.vjp(chain, x, weights, biases)
    return vjp(gy)


_fused_vjp.defvjp(_fused_vjp_fwd, _fused_vjp_bwd)


def fused_group_apply(x, weights, biases, *, group, activation="relu",
                      interpret=None):
    """Run one fused residency group: ``x (N, H, W, Cin)`` through the
    group's conv→[pool] stage chain in a single megakernel.

    ``weights``/``biases`` are per-stage lists (``(K, K, Cin, Cout)``
    and ``(Cout,)``; pass ``None`` biases for zero).  Forward executes
    the fused Pallas kernel; gradients recompute through the per-layer
    chain so the backward kernels are the ordinary TrIM cotangent convs.
    """
    if len(weights) != group.depth or len(biases) != group.depth:
        raise ValueError(
            f"group depth {group.depth} needs {group.depth} weights/"
            f"biases, got {len(weights)}/{len(biases)}")
    s0 = group.stages[0]
    if x.shape != (group.n, s0.h_in, s0.w_in, s0.cin):
        raise ValueError(
            f"input {x.shape} does not match the group's stage-0 "
            f"problem {(group.n, s0.h_in, s0.w_in, s0.cin)}")
    for st, w in zip(group.stages, weights):
        if tuple(w.shape) != st.weight_shape:
            raise ValueError(
                f"stage {st.name}: weight {tuple(w.shape)} != planned "
                f"{st.weight_shape}")
    biases = tuple(
        jnp.zeros((st.cout,), x.dtype) if b is None else b
        for st, b in zip(group.stages, biases))
    return _fused_vjp(group, activation, interpret, x, tuple(weights),
                      biases)
