"""Public jit'd operator API over the Pallas kernels and their oracles.

Every op takes ``impl``:

  * ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU).
  * ``"ref"``     — the pure-jnp oracle (kernels/ref.py).
  * ``"chunked"`` — (attention only) FlashAttention algorithm expressed in
    pure jnp with a ``lax.scan`` over KV chunks: identical O(L) memory
    behaviour to the kernel, XLA-fusable, dry-run friendly.
  * ``"chunked_unroll"`` — same, with a Python loop instead of the scan.
    Used by the dry-run Δ-cost compiles, because XLA's HloCostAnalysis
    counts while-loop bodies once (verified on this backend) and would
    undercount scanned flops.

``conv2d`` applies the paper's §III kernel tiling for K > MAX_NATIVE_K:
the kernel is decomposed into 3x3-ish sub-kernels whose partial outputs
are accumulated — the adder-tree path.

``conv2d`` consults the autotune cache (``core/autotune.py``) by default:
any ``tile_h`` / ``tile_cout`` / ``dataflow`` knob the caller leaves unset
is filled from the persisted per-(shape, dtype, backend) record when one
exists.  ``pack_conv2d_weights`` performs the kernel's weight pad/reshape
once at load time; passing the resulting :class:`PackedConv2dWeights` as
``w`` skips the per-call packing in the hot path entirely.

``conv2d`` / ``depthwise_conv2d`` are fully differentiable
(DESIGN.md §5): a ``jax.custom_vjp`` runs both cotangents as TrIM
convolutions (``trim_conv2d_input_grad`` / ``trim_conv2d_weight_grad``),
consulting the autotune cache under the backward problems' own keys.
Packed weights receive packed-layout cotangents; the K > MAX_NATIVE_K
adder-tree path differentiates through each sub-kernel.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

import typing

from repro.core import autotune, guard
from repro.core.conv_plan import ConvPlan, input_grad_geometry
from repro.core.conv_shard import ShardedConvPlan, resolve_conv_mesh
from repro.core.tiling import subkernel_decomposition
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.trim_conv1d import trim_conv1d
from repro.kernels.trim_conv2d import (ACTIVATIONS, trim_conv2d,
                                       trim_conv2d_input_grad,
                                       trim_conv2d_weight_grad)
from repro.kernels.trim_conv2d_sharded import sharded_conv2d

MAX_NATIVE_K = 8


# ---------------------------------------------------------------------------
# Convolutions
# ---------------------------------------------------------------------------

def _same_pads(size: int, k: int, s: int) -> tuple[int, int]:
    """XLA 'SAME' padding: out = ceil(size/s), possibly asymmetric."""
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    return total // 2, total - total // 2


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedConv2dWeights:
    """Conv weights pre-packed into the kernel's padded HBM layout.

    ``w`` is ``ConvPlan.padded_weight_shape`` for the frozen
    ``(groups, tile_cout)``; ``bias`` (optional) is the padded
    ``(1, groups * cout_padded_per_group)`` row the kernel streams
    per C_out tile.  ``tile_h`` / ``dataflow`` are optional tuned hints
    (e.g. from the autotune cache at pack time) applied when the call
    site doesn't override them.  Registered as a pytree (arrays are
    leaves, knobs are static) so packed params live in checkpointed /
    jitted parameter trees like any other weight.

    The int8 route (DESIGN.md §11) adds three quantization leaves,
    produced by :func:`quantize_conv2d_weights` /
    ``models.layers.calibrate_conv2d``: ``scale`` is the per-out-channel
    symmetric *weight* scale in the same padded ``(1, G * CoutP)`` row
    layout as ``bias`` (padded lanes hold 1.0 so the bias
    requantization of ``ref.dequant_params`` never divides by zero);
    ``zero_point`` / ``input_scale`` are the scalar per-tensor affine
    activation calibration.  A non-None ``scale`` is what routes
    ``ops.conv2d`` onto the quantized tier chain; ``w`` is then int8
    and ``bias`` stays the original f32 row (the effective int32 bias
    is derived per call).
    """

    w: jax.Array
    bias: jax.Array | None
    cout: int
    groups: int
    tile_cout: int
    tile_h: int | None = None
    dataflow: str | None = None
    scale: jax.Array | None = None
    zero_point: jax.Array | None = None
    input_scale: jax.Array | None = None

    def tree_flatten(self):
        return ((self.w, self.bias, self.scale, self.zero_point,
                 self.input_scale),
                (self.cout, self.groups, self.tile_cout, self.tile_h,
                 self.dataflow))

    @classmethod
    def tree_unflatten(cls, aux, children):
        w, bias, scale, zero_point, input_scale = children
        cout, groups, tile_cout, tile_h, dataflow = aux
        return cls(w=w, bias=bias, cout=cout, groups=groups,
                   tile_cout=tile_cout, tile_h=tile_h, dataflow=dataflow,
                   scale=scale, zero_point=zero_point,
                   input_scale=input_scale)


def pack_conv2d_weights(w: jax.Array, bias: jax.Array | None = None, *,
                        groups: int = 1, tile_cout: int | None = None,
                        tile_h: int | None = None,
                        dataflow: str | None = None,
                        x_shape=None, stride: int = 1,
                        padding: str = "same",
                        dtype: str | None = None,
                        op: str = "conv2d") -> PackedConv2dWeights:
    """Pad/reshape conv weights to the kernel layout once, at load time.

    w: (K, K, Cin/groups, Cout); bias: (Cout,) or None.  The packed
    layout is fixed by ``(groups, tile_cout)``; ``tile_cout`` defaults to
    the plan's MXU-friendly choice.  When ``x_shape`` is given and knobs
    are unset, the autotune cache is consulted (same key ``conv2d`` would
    use for that input) so the packed layout matches the tuned plan.
    ``dtype`` defaults to the *weight* dtype (the activations of a
    homogeneous network match it); pass it explicitly for mixed-dtype
    call sites so the cache consult keys on the activation dtype the
    conv will actually run with.  ``op`` picks the cache namespace
    (``"conv2d_q8"`` for the int8 route).
    """
    kh, kw, cin_pg, cout = w.shape
    if kh > MAX_NATIVE_K:
        raise ValueError(
            f"K={kh} > {MAX_NATIVE_K}: the kernel-tiled path re-slices "
            "weights per sub-kernel and cannot consume packed weights")
    if cout % groups:
        raise ValueError(f"groups={groups} must divide cout={cout}")
    if dtype is None:
        dtype = str(w.dtype)
    if x_shape is not None and (tile_cout is None or tile_h is None
                                or dataflow is None):
        xs, pad = kernel_input_shape(x_shape, kh, stride, padding)
        rec = autotune.knobs_for(xs, w.shape, stride=stride, pad=pad,
                                 groups=groups, dtype=dtype, op=op)
        if rec is not None:
            tile_cout = tile_cout if tile_cout is not None \
                else rec["tile_cout"]
            tile_h = tile_h if tile_h is not None else rec["tile_h"]
            dataflow = dataflow if dataflow is not None else rec["dataflow"]
    # the padded layout is the plan's, not a re-derivation (the spatial
    # dims are irrelevant to the weight layout — any kernel-sized input
    # yields the same padded_weight_shape)
    plan = ConvPlan.build((1, kh, kw, cin_pg * groups), w.shape,
                          groups=groups, tile_cout=tile_cout)
    tile_cout, cpp = plan.tile_cout, plan.cout_padded_per_group
    cout_pg = plan.cout_per_group
    wk = w.reshape(kh, kw, cin_pg, groups, cout_pg)
    wk = jnp.pad(wk, ((0, 0),) * 4 + ((0, cpp - cout_pg),))
    wk = wk.reshape(plan.padded_weight_shape)
    bp = None
    if bias is not None:
        bp = jnp.pad(bias.reshape(groups, cout_pg),
                     ((0, 0), (0, cpp - cout_pg))).reshape(1, groups * cpp)
    return PackedConv2dWeights(w=wk, bias=bp, cout=cout, groups=groups,
                               tile_cout=tile_cout, tile_h=tile_h,
                               dataflow=dataflow)


def quantize_conv2d_weights(w: jax.Array, bias: jax.Array | None = None, *,
                            x_scale, x_zero_point=0, groups: int = 1,
                            tile_cout: int | None = None,
                            tile_h: int | None = None,
                            dataflow: str | None = None,
                            x_shape=None, stride: int = 1,
                            padding: str = "same") -> PackedConv2dWeights:
    """Quantize + pack conv weights for the int8 route (DESIGN.md §11).

    w: f32 (K, K, Cin/groups, Cout); bias: (Cout,) or None.
    Per-out-channel symmetric weight scales (``ref.weight_scales_int8``),
    per-tensor affine activation calibration ``(x_scale, x_zero_point)``
    — typically from ``models.layers.calibrate_conv2d`` over a sample
    batch.  Returns a :class:`PackedConv2dWeights` whose non-None
    ``scale`` routes ``ops.conv2d`` onto the quantized tier chain.
    """
    w_scale = ref.weight_scales_int8(w)
    w_q = ref.quantize_int8(w, w_scale[None, None, None, :])
    pk = pack_conv2d_weights(w_q, None, groups=groups, tile_cout=tile_cout,
                             tile_h=tile_h, dataflow=dataflow,
                             x_shape=x_shape, stride=stride,
                             padding=padding, dtype="int8", op="conv2d_q8")
    cpp = pk.w.shape[3] // groups
    cout_pg = pk.cout // groups
    # padded lanes hold scale 1.0 (not 0): ref.dequant_params divides the
    # real bias by the scale, and 0-scale lanes would round NaN to int32
    sp = jnp.pad(w_scale.reshape(groups, cout_pg),
                 ((0, 0), (0, cpp - cout_pg)),
                 constant_values=1.0).reshape(1, groups * cpp)
    bp = None
    if bias is not None:
        bp = jnp.pad(bias.astype(jnp.float32).reshape(groups, cout_pg),
                     ((0, 0), (0, cpp - cout_pg))).reshape(1, groups * cpp)
    return dataclasses.replace(
        pk, bias=bp, scale=sp,
        zero_point=jnp.asarray(x_zero_point, jnp.int32),
        input_scale=jnp.asarray(x_scale, jnp.float32))


def kernel_input_shape(x_shape, k: int, stride: int, padding: str):
    """(shape, residual_pad) the Pallas kernel actually sees: 'same'
    pre-pads in HBM (possibly asymmetric for stride > 1) and calls the
    kernel with pad=0.  This is the shape autotune cache keys are built
    over (used by ``benchmarks/hillclimb.py --write-cache``)."""
    n, h, w, cin = x_shape
    if padding == "same":
        ph, pw = _same_pads(h, k, stride), _same_pads(w, k, stride)
        return (n, h + sum(ph), w + sum(pw), cin), 0
    return (n, h, w, cin), 0


# ---------------------------------------------------------------------------
# Differentiable conv core (custom_vjp) — DESIGN.md §5
#
# Both cotangents are TrIM convolutions: the input gradient is a stride-1
# conv of the dilated/edge-padded cotangent with flipped/transposed
# weights (the forward kernel, dataflow axis and all), the weight
# gradient a dedicated spatially-contracting strip kernel.  The primal
# path (no differentiation) still runs the fully fused kernel; under
# jax.grad the fwd rule runs the epilogue unfused so the pre-activation
# is available as a residual.
# ---------------------------------------------------------------------------

class _ConvVjpConfig(typing.NamedTuple):
    """Static knobs of one differentiable conv call (hashable; a
    nondiff argument of the custom_vjp cores)."""

    stride: int
    pad: int
    groups: int
    activation: str | None
    tile_h: int | None
    tile_cout: int | None
    dataflow: str
    use_autotune_cache: bool
    packed_cout: int | None = None


def _activation_bwd(activation: str | None, z: jax.Array | None,
                    gy: jax.Array) -> jax.Array:
    """Cotangent through the (jnp-level) epilogue activation."""
    if activation is None:
        return gy
    return jax.vjp(ACTIVATIONS[activation], z)[1](gy)[0]


def _backward_knobs(cfg: _ConvVjpConfig, x_shape, w_shape, dtype: str):
    """Tile/dataflow knobs for the two cotangent kernels: the autotune
    cache consulted under the backward problems' own keys (the
    input-grad conv under the plain ``conv2d`` key of its transformed
    shapes, the weight grad under ``conv2d_wgrad``), else defaults."""
    ig = dict(tile_h=None, tile_cout=None, dataflow="carry")
    wg = dict(tile_go=None, tile_cout=None)
    if cfg.use_autotune_cache:
        geo = input_grad_geometry(x_shape, w_shape, stride=cfg.stride,
                                  pad=cfg.pad, groups=cfg.groups)
        rec = autotune.knobs_for(geo["g_padded_shape"], geo["wt_shape"],
                                 stride=1, pad=0, groups=cfg.groups,
                                 dtype=dtype)
        if rec is not None:
            ig = dict(tile_h=rec["tile_h"], tile_cout=rec["tile_cout"],
                      dataflow=rec["dataflow"])
        wrec = autotune.weight_grad_knobs_for(
            x_shape, w_shape, stride=cfg.stride, pad=cfg.pad,
            groups=cfg.groups, dtype=dtype)
        if wrec is not None:
            wg = dict(tile_go=wrec["tile_go"],
                      tile_cout=wrec["tile_cout"])
    return ig, wg


def _conv_grads(cfg: _ConvVjpConfig, x, w, bias, z, gy):
    """Shared backward math: (dx, dw_logical, db_or_None, dz)."""
    dz = _activation_bwd(cfg.activation, z, gy)
    ig, wg = _backward_knobs(cfg, x.shape, w.shape, str(x.dtype))
    dx = trim_conv2d_input_grad(dz, w, x_shape=x.shape, stride=cfg.stride,
                                pad=cfg.pad, groups=cfg.groups, **ig)
    dw = trim_conv2d_weight_grad(x, dz, kernel_size=w.shape[:2],
                                 stride=cfg.stride, pad=cfg.pad,
                                 groups=cfg.groups, **wg)
    db = None if bias is None \
        else dz.sum((0, 1, 2)).astype(bias.dtype)
    return dx.astype(x.dtype), dw.astype(w.dtype), db, dz


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv2d_vjp_core(cfg: _ConvVjpConfig, x, w, bias):
    """Primal: the fully fused kernel (bias + activation in-epilogue)."""
    return trim_conv2d(x, w, bias, stride=cfg.stride, pad=cfg.pad,
                       tile_h=cfg.tile_h, tile_cout=cfg.tile_cout,
                       groups=cfg.groups, activation=cfg.activation,
                       dataflow=cfg.dataflow)


def _conv2d_vjp_fwd(cfg: _ConvVjpConfig, x, w, bias):
    z = trim_conv2d(x, w, bias, stride=cfg.stride, pad=cfg.pad,
                    tile_h=cfg.tile_h, tile_cout=cfg.tile_cout,
                    groups=cfg.groups, activation=None,
                    dataflow=cfg.dataflow)
    y = z if cfg.activation is None else ACTIVATIONS[cfg.activation](z)
    # z is only a residual when the activation needs it in the backward
    return y, (x, w, bias, z if cfg.activation is not None else None)


def _conv2d_vjp_bwd(cfg: _ConvVjpConfig, res, gy):
    x, w, bias, z = res
    dx, dw, db, _ = _conv_grads(cfg, x, w, bias, z, gy)
    return dx, dw, db


_conv2d_vjp_core.defvjp(_conv2d_vjp_fwd, _conv2d_vjp_bwd)


def _unpack_weights(wp: jax.Array, groups: int, cout: int) -> jax.Array:
    """Packed padded layout -> logical (K, K, Cin/g, Cout)."""
    kh, kw, cin_pg, gcpp = wp.shape
    cpp, cout_pg = gcpp // groups, cout // groups
    w = wp.reshape(kh, kw, cin_pg, groups, cpp)[..., :cout_pg]
    return w.reshape(kh, kw, cin_pg, cout)


def _pack_weight_grad(dw: jax.Array, groups: int, cpp: int) -> jax.Array:
    """Logical weight cotangent -> the packed padded layout (the
    cotangent of a PackedConv2dWeights.w leaf must match its shape)."""
    kh, kw, cin_pg, cout = dw.shape
    cout_pg = cout // groups
    dwp = dw.reshape(kh, kw, cin_pg, groups, cout_pg)
    dwp = jnp.pad(dwp, ((0, 0),) * 4 + ((0, cpp - cout_pg),))
    return dwp.reshape(kh, kw, cin_pg, groups * cpp)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv2d_packed_vjp_core(cfg: _ConvVjpConfig, x, wp, bp):
    """Primal: the fused packed-weights kernel path."""
    return trim_conv2d(x, wp, bp, stride=cfg.stride, pad=cfg.pad,
                       tile_h=cfg.tile_h, tile_cout=cfg.tile_cout,
                       groups=cfg.groups, activation=cfg.activation,
                       dataflow=cfg.dataflow, packed_cout=cfg.packed_cout)


def _conv2d_packed_vjp_fwd(cfg: _ConvVjpConfig, x, wp, bp):
    z = trim_conv2d(x, wp, bp, stride=cfg.stride, pad=cfg.pad,
                    tile_h=cfg.tile_h, tile_cout=cfg.tile_cout,
                    groups=cfg.groups, activation=None,
                    dataflow=cfg.dataflow, packed_cout=cfg.packed_cout)
    y = z if cfg.activation is None else ACTIVATIONS[cfg.activation](z)
    return y, (x, wp, bp, z if cfg.activation is not None else None)


def _conv2d_packed_vjp_bwd(cfg: _ConvVjpConfig, res, gy):
    x, wp, bp, z = res
    w = _unpack_weights(wp, cfg.groups, cfg.packed_cout)
    dx, dw, _, dz = _conv_grads(cfg, x, w, None, z, gy)
    cpp = wp.shape[3] // cfg.groups
    dwp = _pack_weight_grad(dw, cfg.groups, cpp)
    dbp = None
    if bp is not None:
        db = dz.sum((0, 1, 2))                     # logical (Cout,)
        cout_pg = cfg.packed_cout // cfg.groups
        dbp = jnp.pad(db.reshape(cfg.groups, cout_pg),
                      ((0, 0), (0, cpp - cout_pg)))
        dbp = dbp.reshape(1, cfg.groups * cpp).astype(bp.dtype)
    return dx, dwp.astype(wp.dtype), dbp


_conv2d_packed_vjp_core.defvjp(_conv2d_packed_vjp_fwd,
                               _conv2d_packed_vjp_bwd)


def conv2d(x: jax.Array, w, *, stride: int = 1,
           padding: str = "same", impl: str = "pallas",
           feature_group_count: int = 1, bias: jax.Array | None = None,
           activation: str | None = None,
           tile_h: int | None = None, tile_cout: int | None = None,
           dataflow: str | None = None,
           use_autotune_cache: bool = True,
           mesh=None, rules: dict | None = None,
           layer: str | None = None) -> jax.Array:
    """(Grouped) 2D convolution with optional fused bias + activation.

    x: (N, H, W, Cin); w: (K, K, Cin/groups, Cout) or a
    :class:`PackedConv2dWeights`; bias: (Cout,) or None;
    ``feature_group_count=Cin`` gives depthwise convolution.  The Pallas
    path fuses the epilogue into the kernel's accumulator store.

    Tile/dataflow knobs left as ``None`` are filled from the autotune
    cache (``core/autotune.py``) when a record exists for this problem
    (disable with ``use_autotune_cache=False`` or
    ``REPRO_CONV_AUTOTUNE=0``), falling back to the plan defaults.  The
    K > MAX_NATIVE_K kernel-tiled path honors explicit knobs on every
    sub-kernel but never consults the cache (records describe the full-K
    problem, not the sub-kernel geometry).

    ``mesh`` (with optional conv ``rules``, default
    ``distributed.sharding.CONV_RULES``) selects the sharded execution
    path (DESIGN.md §6): batch shards over the rules' ``"batch"`` axis,
    output H-strips over ``"strips"``, with a ``ppermute`` neighbor halo
    exchange of the K-1 boundary rows before the per-shard kernel.  The
    sharded path consults the autotune cache under device-count
    namespaced keys (``conv2d_shard:<ndev>:``) so single- and
    multi-device tunings never alias.

    Execution is *guarded* (DESIGN.md §9): the tier chain
    ``sharded -> pallas -> ref`` fails soft — a lowering/compile/runtime
    error in a fast tier demotes the call to the next tier, records a
    structured event (``core.guard.events()``), and memoizes the broken
    ``(problem, tier)`` pair so it is never re-attempted.  The final
    ``ref`` tier runs unguarded, so a genuinely invalid problem still
    raises.  ``REPRO_CONV_GUARD=1`` additionally finite-checks tier
    outputs (eager only) and demotes on NaN/Inf; ``layer`` names the
    producing layer in those events.

    Runnable quickstart snippets for every path (dataflows, packing,
    autotune, ``mesh=``, guard) live in README.md and are executed by CI
    (``tools/doclint.py``); whole-topology execution is
    ``models/layers.py cnn_apply_from_layers`` (DESIGN.md §7).
    """
    # invalid *arguments* are rejected here, before the guarded chain:
    # they are caller errors, not tier faults, and must raise the same
    # actionable ValueError from every tier (the ref oracle would
    # otherwise surface them as KeyErrors after a pointless demotion)
    if activation is not None and activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"choose from {sorted(ACTIVATIONS, key=str)}")
    if dataflow is not None and dataflow not in autotune.DATAFLOWS:
        raise ValueError(f"unknown dataflow {dataflow!r}; "
                         f"choose from {autotune.DATAFLOWS}")
    if isinstance(w, PackedConv2dWeights):
        if mesh is not None:
            raise ValueError(
                "sharded conv2d takes raw (K, K, Cin/g, Cout) weights; "
                "packed weights freeze a single-device layout")
        return _conv2d_packed(x, w, stride=stride, padding=padding,
                              impl=impl, bias=bias, activation=activation,
                              tile_h=tile_h, dataflow=dataflow,
                              use_autotune_cache=use_autotune_cache,
                              layer=layer)
    cin, (cin_pg, cout) = x.shape[3], w.shape[2:]
    if cin_pg * feature_group_count != cin:
        raise ValueError(
            f"weights expect cin/groups={cin_pg} with "
            f"groups={feature_group_count}, input has cin={cin}")
    if cout % feature_group_count:
        raise ValueError(f"groups={feature_group_count} must divide "
                         f"cout={cout}")
    if impl == "ref":
        # the oracle computes the same global math regardless of mesh
        return ref.conv2d(x, w, stride=stride, padding=padding,
                          feature_group_count=feature_group_count,
                          bias=bias, activation=activation)

    def _pallas_tier():
        return _conv2d_pallas(x, w, stride=stride, padding=padding,
                              feature_group_count=feature_group_count,
                              bias=bias, activation=activation,
                              tile_h=tile_h, tile_cout=tile_cout,
                              dataflow=dataflow,
                              use_autotune_cache=use_autotune_cache)

    def _ref_tier():
        return ref.conv2d(x, w, stride=stride, padding=padding,
                          feature_group_count=feature_group_count,
                          bias=bias, activation=activation)

    tiers = [("pallas", _pallas_tier), ("ref", _ref_tier)]
    if mesh is not None:
        def _sharded_tier():
            return _conv2d_sharded(x, w, stride=stride, padding=padding,
                                   feature_group_count=feature_group_count,
                                   bias=bias, activation=activation,
                                   tile_h=tile_h, tile_cout=tile_cout,
                                   dataflow=dataflow,
                                   use_autotune_cache=use_autotune_cache,
                                   mesh=mesh, rules=rules)
        tiers.insert(0, ("sharded", _sharded_tier))
    key = guard.problem_key("conv2d", x.shape, w.shape, stride=stride,
                            padding=padding, groups=feature_group_count,
                            dtype=str(x.dtype))
    return guard.run_chain(key, tiers, layer=layer)


def _conv2d_pallas(x: jax.Array, w: jax.Array, *, stride: int,
                   padding: str, feature_group_count: int,
                   bias: jax.Array | None, activation: str | None,
                   tile_h: int | None, tile_cout: int | None,
                   dataflow: str | None,
                   use_autotune_cache: bool) -> jax.Array:
    """The single-device Pallas tier: 'same' pre-pad, autotune-cache
    knob fill, differentiable kernel core — or the K > MAX_NATIVE_K
    adder-tree decomposition."""
    k = w.shape[0]
    if padding == "same":
        ph, pw = _same_pads(x.shape[1], k, stride), \
            _same_pads(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    if k <= MAX_NATIVE_K:
        if use_autotune_cache and (tile_h is None or tile_cout is None
                                   or dataflow is None):
            rec = autotune.knobs_for(x.shape, w.shape, stride=stride,
                                     pad=0, groups=feature_group_count,
                                     dtype=str(x.dtype))
            if rec is not None:
                tile_h = tile_h if tile_h is not None else rec["tile_h"]
                tile_cout = tile_cout if tile_cout is not None \
                    else rec["tile_cout"]
                dataflow = dataflow if dataflow is not None \
                    else rec["dataflow"]
        cfg = _ConvVjpConfig(stride=stride, pad=0,
                             groups=feature_group_count,
                             activation=activation, tile_h=tile_h,
                             tile_cout=tile_cout,
                             dataflow=dataflow or "carry",
                             use_autotune_cache=use_autotune_cache)
        return _conv2d_vjp_core(cfg, x, w, bias)
    # Kernel tiling (paper §III): split K x K into sub-kernels, accumulate.
    # The epilogue is applied once, after the adder tree.  Explicit tile
    # knobs apply to every sub-kernel; the autotune cache is NOT consulted
    # here (its records describe the full-K problem, not the sub-kernel
    # geometry).
    h_out = (x.shape[1] - k) // stride + 1
    w_out = (x.shape[2] - k) // stride + 1
    out = None
    cfg = _ConvVjpConfig(stride=stride, pad=0,
                         groups=feature_group_count, activation=None,
                         tile_h=tile_h, tile_cout=tile_cout,
                         dataflow=dataflow or "carry",
                         use_autotune_cache=use_autotune_cache)
    for r0, c0, kh, kw in subkernel_decomposition(k, native_k=3):
        zs = x[:, r0:r0 + (h_out - 1) * stride + kh,
               c0:c0 + (w_out - 1) * stride + kw, :]
        # each sub-kernel is a differentiable core call, so the whole
        # adder-tree path (slices + sum) autodiffs through the same
        # backward kernels
        part = _conv2d_vjp_core(cfg, zs, w[r0:r0 + kh, c0:c0 + kw], None)
        out = part if out is None else out + part   # adder tree
    return ref.epilogue(out, bias, activation)


def _conv2d_sharded(x: jax.Array, w: jax.Array, *, stride: int,
                    padding: str, feature_group_count: int,
                    bias: jax.Array | None, activation: str | None,
                    tile_h: int | None, tile_cout: int | None,
                    dataflow: str | None, use_autotune_cache: bool,
                    mesh, rules: dict | None) -> jax.Array:
    """The shard_map path (DESIGN.md §6): resolve the shard grid from
    the mesh + conv rules, plan with :class:`ShardedConvPlan`, and run
    the halo-exchange schedule with the *differentiable* conv core as
    the per-shard kernel — gradients transpose the halo shuffle and
    psum the replicated weight/bias cotangents automatically."""
    k = w.shape[0]
    if k > MAX_NATIVE_K:
        raise ValueError(
            f"sharded conv2d supports K <= {MAX_NATIVE_K}; decompose "
            "large kernels before sharding (ops.conv2d adder-tree path)")
    if padding == "same":
        ph, pw = _same_pads(x.shape[1], k, stride), \
            _same_pads(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    ba, bs, sa, ss = resolve_conv_mesh(mesh, rules)
    if use_autotune_cache and (tile_h is None or tile_cout is None
                               or dataflow is None):
        rec = autotune.sharded_knobs_for(
            x.shape, w.shape, batch_shards=bs, spatial_shards=ss,
            stride=stride, pad=0, groups=feature_group_count,
            dtype=str(x.dtype))
        if rec is not None:
            tile_h = tile_h if tile_h is not None else rec["tile_h"]
            tile_cout = tile_cout if tile_cout is not None \
                else rec["tile_cout"]
            dataflow = dataflow if dataflow is not None \
                else rec["dataflow"]
    plan = ShardedConvPlan.build(
        x.shape, w.shape, stride=stride, pad=0,
        groups=feature_group_count, dtype_bytes=x.dtype.itemsize,
        tile_h=tile_h, tile_cout=tile_cout, dataflow=dataflow or "carry",
        batch_shards=bs, spatial_shards=ss, batch_axis=ba,
        spatial_axis=sa)
    cfg = _ConvVjpConfig(stride=stride, pad=0,
                         groups=feature_group_count,
                         activation=activation, tile_h=tile_h,
                         tile_cout=tile_cout,
                         dataflow=dataflow or "carry",
                         use_autotune_cache=use_autotune_cache)
    return sharded_conv2d(x, w, bias, plan=plan, mesh=mesh,
                          local_conv=functools.partial(_conv2d_vjp_core,
                                                       cfg))


def _conv2d_packed(x: jax.Array, pk: PackedConv2dWeights, *,
                   stride: int, padding: str, impl: str,
                   bias: jax.Array | None, activation: str | None,
                   tile_h: int | None, dataflow: str | None,
                   use_autotune_cache: bool,
                   layer: str | None = None) -> jax.Array:
    """The pre-packed fast path: no per-call weight pad/reshape.

    Guarded like :func:`conv2d`: the ``ref`` fallback unpacks the padded
    layout back to logical ``(K, K, Cin/g, Cout)`` weights + ``(Cout,)``
    bias, so demotion preserves the packed API."""
    if bias is not None:
        raise ValueError("bias is packed inside PackedConv2dWeights; "
                         "pass it to pack_conv2d_weights instead")
    if impl != "pallas":
        raise ValueError(f"packed weights require impl='pallas', "
                         f"got {impl!r}")
    if pk.scale is not None:
        # quantized packed weights (quantize_conv2d_weights /
        # calibrate_conv2d): the int8 tier chain
        return _conv2d_q8(x, pk, stride=stride, padding=padding,
                          activation=activation, tile_h=tile_h,
                          dataflow=dataflow,
                          use_autotune_cache=use_autotune_cache,
                          layer=layer)
    k = pk.w.shape[0]

    def _pallas_tier():
        return _conv2d_packed_pallas(
            x, pk, stride=stride, padding=padding, activation=activation,
            tile_h=tile_h, dataflow=dataflow,
            use_autotune_cache=use_autotune_cache)

    def _ref_tier():
        w_logical = _unpack_weights(pk.w, pk.groups, pk.cout)
        b_logical = None
        if pk.bias is not None:
            cpp = pk.w.shape[3] // pk.groups
            cout_pg = pk.cout // pk.groups
            b_logical = pk.bias.reshape(pk.groups, cpp)[:, :cout_pg] \
                .reshape(pk.cout)
        return ref.conv2d(x, w_logical, stride=stride, padding=padding,
                          feature_group_count=pk.groups, bias=b_logical,
                          activation=activation)

    key = guard.problem_key("conv2d_packed", x.shape,
                            (k, pk.w.shape[1], pk.w.shape[2], pk.cout),
                            stride=stride, padding=padding,
                            groups=pk.groups, dtype=str(x.dtype))
    return guard.run_chain(key, [("pallas", _pallas_tier),
                                 ("ref", _ref_tier)], layer=layer)


def _conv2d_packed_pallas(x: jax.Array, pk: PackedConv2dWeights, *,
                          stride: int, padding: str,
                          activation: str | None, tile_h: int | None,
                          dataflow: str | None,
                          use_autotune_cache: bool) -> jax.Array:
    k = pk.w.shape[0]
    if padding == "same":
        ph, pw = _same_pads(x.shape[1], k, stride), \
            _same_pads(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    tile_h = tile_h if tile_h is not None else pk.tile_h
    dataflow = dataflow if dataflow is not None else pk.dataflow
    if use_autotune_cache and (tile_h is None or dataflow is None):
        # the packed layout freezes tile_cout; tile_h/dataflow may still
        # come from the cache (logical weight shape keys the record)
        w_shape = (k, pk.w.shape[1], pk.w.shape[2], pk.cout)
        rec = autotune.knobs_for(x.shape, w_shape, stride=stride, pad=0,
                                 groups=pk.groups, dtype=str(x.dtype))
        if rec is not None and rec["tile_cout"] == pk.tile_cout:
            tile_h = tile_h if tile_h is not None else rec["tile_h"]
            dataflow = dataflow if dataflow is not None \
                else rec["dataflow"]
    cfg = _ConvVjpConfig(stride=stride, pad=0, groups=pk.groups,
                         activation=activation, tile_h=tile_h,
                         tile_cout=pk.tile_cout,
                         dataflow=dataflow or "carry",
                         use_autotune_cache=use_autotune_cache,
                         packed_cout=pk.cout)
    return _conv2d_packed_vjp_core(cfg, x, pk.w, pk.bias)


def _unpack_cout_row(row: jax.Array, groups: int, cout: int) -> jax.Array:
    """Packed padded ``(1, G*CoutP)`` row -> logical ``(Cout,)``."""
    cpp, cout_pg = row.shape[1] // groups, cout // groups
    return row.reshape(groups, cpp)[:, :cout_pg].reshape(cout)


def _q8_forward(x_q: jax.Array, pk: PackedConv2dWeights, *, stride: int,
                activation: str | None, tile_h: int | None,
                dataflow: str | None,
                use_autotune_cache: bool) -> jax.Array:
    """The int8 Pallas tier: exact int32 MXU accumulation with the fused
    dequant epilogue.  ``x_q`` is already quantized and 'same'-pre-padded
    with the activation zero point.  Module-level so the fault harness
    (``testing/faults.py``) can patch it as the ``"q8"`` tier target.
    """
    s_row, b_q = ref.dequant_params(pk.w, pk.scale, pk.input_scale,
                                    pk.zero_point, pk.bias)
    tile_h = tile_h if tile_h is not None else pk.tile_h
    dataflow = dataflow if dataflow is not None else pk.dataflow
    if use_autotune_cache and (tile_h is None or dataflow is None):
        # int8 tunings live in their own conv2d_q8: namespace — an f32
        # record for the same geometry must never leak knobs in here
        w_shape = (pk.w.shape[0], pk.w.shape[1], pk.w.shape[2], pk.cout)
        rec = autotune.knobs_for(x_q.shape, w_shape, stride=stride, pad=0,
                                 groups=pk.groups, dtype="int8",
                                 op="conv2d_q8")
        if rec is not None and rec["tile_cout"] == pk.tile_cout:
            tile_h = tile_h if tile_h is not None else rec["tile_h"]
            dataflow = dataflow if dataflow is not None \
                else rec["dataflow"]
    return trim_conv2d(x_q, pk.w, b_q.reshape(1, -1),
                       s_row.reshape(1, -1), stride=stride, pad=0,
                       tile_h=tile_h, tile_cout=pk.tile_cout,
                       groups=pk.groups, activation=activation,
                       dataflow=dataflow or "carry", packed_cout=pk.cout)


def _conv2d_q8(x: jax.Array, pk: PackedConv2dWeights, *, stride: int,
               padding: str, activation: str | None, tile_h: int | None,
               dataflow: str | None, use_autotune_cache: bool,
               layer: str | None = None) -> jax.Array:
    """The quantized tier chain (DESIGN.md §11): ``q8 -> pallas -> ref``.

    ``q8`` runs the int8 kernel; a fault demotes to ``pallas``, the f32
    kernel over the *dequantized* weights (same quantization error, fast
    path); ``ref`` is the ``conv2d_quantized`` oracle.  x may be f32
    (quantized here against the packed calibration) or already int8.
    """
    k = pk.w.shape[0]
    if jnp.issubdtype(x.dtype, jnp.integer):
        x_q = x
    else:
        x_q = ref.quantize_int8(x, pk.input_scale, pk.zero_point)
    if padding == "same":
        ph, pw = _same_pads(x.shape[1], k, stride), \
            _same_pads(x.shape[2], k, stride)
        zp = pk.zero_point.astype(x_q.dtype)
        x_pad = jax.lax.pad(x_q, zp, ((0, 0, 0), (*ph, 0), (*pw, 0),
                                      (0, 0, 0)))
    elif padding == "valid":
        x_pad = x_q
    else:
        raise ValueError(f"padding={padding!r} must be 'same' or 'valid'")

    def _q8_tier():
        return _q8_forward(x_pad, pk, stride=stride, activation=activation,
                           tile_h=tile_h, dataflow=dataflow,
                           use_autotune_cache=use_autotune_cache)

    w_q = _unpack_weights(pk.w, pk.groups, pk.cout)
    w_scale = _unpack_cout_row(pk.scale, pk.groups, pk.cout)
    b_logical = None if pk.bias is None \
        else _unpack_cout_row(pk.bias, pk.groups, pk.cout)

    def _pallas_tier():
        # f32 kernel over the dequantized weights and quantized-dequantized
        # input: same quantization error as the int8 tier, fast fallback
        x_dq = (x_q.astype(jnp.float32)
                - pk.zero_point.astype(jnp.float32)) * pk.input_scale
        w_dq = w_q.astype(jnp.float32) * w_scale
        return _conv2d_pallas(x_dq, w_dq, stride=stride, padding=padding,
                              feature_group_count=pk.groups,
                              bias=b_logical, activation=activation,
                              tile_h=tile_h, tile_cout=pk.tile_cout,
                              dataflow=dataflow,
                              use_autotune_cache=use_autotune_cache)

    def _ref_tier():
        return ref.conv2d_quantized(
            x_q, w_q, x_scale=pk.input_scale, x_zero_point=pk.zero_point,
            w_scale=w_scale, bias=b_logical, stride=stride,
            padding=padding, feature_group_count=pk.groups,
            activation=activation)

    key = guard.problem_key("conv2d_q8", x.shape,
                            (k, pk.w.shape[1], pk.w.shape[2], pk.cout),
                            stride=stride, padding=padding,
                            groups=pk.groups, dtype=str(x.dtype))
    return guard.run_chain(key, [("q8", _q8_tier),
                                 ("pallas", _pallas_tier),
                                 ("ref", _ref_tier)], layer=layer)


def depthwise_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                     padding: str = "same", impl: str = "pallas",
                     bias: jax.Array | None = None,
                     activation: str | None = None,
                     mesh=None, rules: dict | None = None,
                     layer: str | None = None) -> jax.Array:
    """Depthwise 2D convolution (the MobileNet scenario of the paper's
    OPs/Access comparison).

    Sugar for :func:`conv2d` with ``feature_group_count == Cin``: each
    input channel is convolved with its own ``(K, K)`` filter(s).
    x: (N, H, W, Cin); w: (K, K, 1, Cin * multiplier).  Everything else
    — fused bias/activation epilogue, autotune-cache consultation, the
    ``custom_vjp`` backward kernels, the ``mesh=`` sharded path — is
    inherited from :func:`conv2d`; the group axis rides the kernel grid
    so a depthwise conv is still a single ``pallas_call``.
    """
    return conv2d(x, w, stride=stride, padding=padding, impl=impl,
                  feature_group_count=x.shape[-1], bias=bias,
                  activation=activation, mesh=mesh, rules=rules,
                  layer=layer)


def depthwise_conv1d(x: jax.Array, w: jax.Array, *,
                     impl: str = "pallas") -> jax.Array:
    """Causal depthwise conv1d.  x: (B, L, D); w: (K, D)."""
    if impl == "ref" or w.shape[0] < 2:
        return ref.depthwise_conv1d(x, w)
    return trim_conv1d(x, w)


depthwise_conv1d_step = ref.depthwise_conv1d_step


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _chunk_update(q, kc, vc, carry, *, k_start, lk, sm_scale, causal,
                  soft_cap, window, lq_off):
    """Online-softmax update for one KV chunk.

    q: (B, Hkv, G, Lq, D); kc/vc: (B, C, Hkv, D);
    carry = (m, l, acc) with m/l: (B, Hkv, G, Lq, 1), acc like q.
    """
    m_prev, l_prev, acc = carry
    s = jnp.einsum("bhgqd,bchd->bhgqc", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) * sm_scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    lq, c = q.shape[3], kc.shape[1]
    q_pos = jnp.arange(lq) + lq_off
    k_pos = jnp.arange(c) + k_start
    mask = jnp.broadcast_to((k_pos < lk)[None, :], (lq, c))
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask, s, -1e30)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_new = acc * alpha + jnp.einsum("bhgqc,bchd->bhgqd", p,
                                       vc.astype(jnp.float32))
    return m_new, l_new, acc_new


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, soft_cap: float | None = None,
                      window: int | None = None, chunk: int = 1024,
                      unroll: bool = False) -> jax.Array:
    """FlashAttention schedule in pure jnp (KV streamed chunk by chunk).

    q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D).
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    chunk = min(chunk, lk)
    nk = math.ceil(lk / chunk)
    lkp = nk * chunk
    sm_scale = 1.0 / math.sqrt(d)
    lq_off = lk - lq   # queries right-aligned (decode/prefill continuation)

    qg = q.transpose(0, 2, 1, 3).reshape(b, hkv, group, lq, d)
    kp = jnp.pad(k, ((0, 0), (0, lkp - lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, lkp - lk), (0, 0), (0, 0)))

    m0 = jnp.full((b, hkv, group, lq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, lq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, lq, d), jnp.float32)

    # One chunk is checkpointed: the backward recomputes that chunk's
    # logits instead of saving them — the FlashAttention-bwd structure.
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def _update(carry, kc, vc, ic):
        return _chunk_update(qg, kc, vc, carry, k_start=ic * chunk, lk=lk,
                             sm_scale=sm_scale, causal=causal,
                             soft_cap=soft_cap, window=window,
                             lq_off=lq_off)

    def step(carry, ic):
        kc = jax.lax.dynamic_slice_in_dim(kp, ic * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, ic * chunk, chunk, axis=1)
        return _update(carry, kc, vc, ic), None

    if unroll:
        carry = (m0, l0, a0)
        for ic in range(nk):
            # skip chunks that are fully masked (causal / local window)
            if causal and ic * chunk > lq_off + lq - 1:
                continue
            if window is not None and (ic + 1) * chunk - 1 < lq_off - window + 1:
                continue
            carry, _ = step(carry, ic)
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nk))

    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.reshape(b, hq, lq, d).transpose(0, 2, 1, 3)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, soft_cap: float | None = None,
              window: int | None = None, impl: str = "chunked",
              chunk: int = 1024) -> jax.Array:
    """Multi-head GQA attention.  q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D)."""
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal,
                             logits_soft_cap=soft_cap, window=window)
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, soft_cap=soft_cap,
                               window=window)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, soft_cap=soft_cap,
                                 window=window, chunk=chunk)
    if impl == "chunked_unroll":
        return chunked_attention(q, k, v, causal=causal, soft_cap=soft_cap,
                                 window=window, chunk=chunk, unroll=True)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, soft_cap: float | None = None,
                     window: int | None = None) -> jax.Array:
    """One-token attention over a KV cache.

    q: (B, 1, Hq, D); caches: (B, Lmax, Hkv, D); cache_len: () or (B,) —
    number of valid cache entries (including the current token).
    """
    b, _, hq, d = q.shape
    _, lmax, hkv, _ = k_cache.shape
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(d)
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    k_pos = jnp.arange(lmax)
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    if window is not None:
        valid &= k_pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)
