"""Public jit'd operator API over the Pallas kernels and their oracles.

Every op takes ``impl``:

  * ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU).
  * ``"ref"``     — the pure-jnp oracle (kernels/ref.py).
  * ``"chunked"`` — (attention only) FlashAttention algorithm expressed in
    pure jnp with a ``lax.scan`` over KV chunks: identical O(L) memory
    behaviour to the kernel, XLA-fusable, dry-run friendly.
  * ``"chunked_unroll"`` — same, with a Python loop instead of the scan.
    Used by the dry-run Δ-cost compiles, because XLA's HloCostAnalysis
    counts while-loop bodies once (verified on this backend) and would
    undercount scanned flops.

``conv2d`` applies the paper's §III kernel tiling for K > MAX_NATIVE_K:
the kernel is decomposed into 3x3-ish sub-kernels whose partial outputs
are accumulated — the adder-tree path.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.tiling import subkernel_decomposition
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.trim_conv1d import trim_conv1d
from repro.kernels.trim_conv2d import trim_conv2d

MAX_NATIVE_K = 8


# ---------------------------------------------------------------------------
# Convolutions
# ---------------------------------------------------------------------------

def _same_pads(size: int, k: int, s: int) -> tuple[int, int]:
    """XLA 'SAME' padding: out = ceil(size/s), possibly asymmetric."""
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    return total // 2, total - total // 2


def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
           padding: str = "same", impl: str = "pallas",
           feature_group_count: int = 1, bias: jax.Array | None = None,
           activation: str | None = None) -> jax.Array:
    """(Grouped) 2D convolution with optional fused bias + activation.

    x: (N, H, W, Cin); w: (K, K, Cin/groups, Cout); bias: (Cout,) or None;
    ``feature_group_count=Cin`` gives depthwise convolution.  The Pallas
    path fuses the epilogue into the kernel's accumulator store.
    """
    if impl == "ref":
        return ref.conv2d(x, w, stride=stride, padding=padding,
                          feature_group_count=feature_group_count,
                          bias=bias, activation=activation)
    k = w.shape[0]
    if padding == "same":
        ph, pw = _same_pads(x.shape[1], k, stride), \
            _same_pads(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    if k <= MAX_NATIVE_K:
        return trim_conv2d(x, w, bias, stride=stride, pad=0,
                           groups=feature_group_count,
                           activation=activation)
    # Kernel tiling (paper §III): split K x K into sub-kernels, accumulate.
    # The epilogue is applied once, after the adder tree.
    h_out = (x.shape[1] - k) // stride + 1
    w_out = (x.shape[2] - k) // stride + 1
    out = None
    for r0, c0, kh, kw in subkernel_decomposition(k, native_k=3):
        zs = x[:, r0:r0 + (h_out - 1) * stride + kh,
               c0:c0 + (w_out - 1) * stride + kw, :]
        part = trim_conv2d(zs, w[r0:r0 + kh, c0:c0 + kw], stride=stride,
                           pad=0, groups=feature_group_count)
        out = part if out is None else out + part   # adder tree
    return ref.epilogue(out, bias, activation)


def depthwise_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                     padding: str = "same", impl: str = "pallas",
                     bias: jax.Array | None = None,
                     activation: str | None = None) -> jax.Array:
    """Depthwise 2D conv (MobileNet-style).  w: (K, K, 1, Cin * mult)."""
    return conv2d(x, w, stride=stride, padding=padding, impl=impl,
                  feature_group_count=x.shape[-1], bias=bias,
                  activation=activation)


def depthwise_conv1d(x: jax.Array, w: jax.Array, *,
                     impl: str = "pallas") -> jax.Array:
    """Causal depthwise conv1d.  x: (B, L, D); w: (K, D)."""
    if impl == "ref" or w.shape[0] < 2:
        return ref.depthwise_conv1d(x, w)
    return trim_conv1d(x, w)


depthwise_conv1d_step = ref.depthwise_conv1d_step


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _chunk_update(q, kc, vc, carry, *, k_start, lk, sm_scale, causal,
                  soft_cap, window, lq_off):
    """Online-softmax update for one KV chunk.

    q: (B, Hkv, G, Lq, D); kc/vc: (B, C, Hkv, D);
    carry = (m, l, acc) with m/l: (B, Hkv, G, Lq, 1), acc like q.
    """
    m_prev, l_prev, acc = carry
    s = jnp.einsum("bhgqd,bchd->bhgqc", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) * sm_scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    lq, c = q.shape[3], kc.shape[1]
    q_pos = jnp.arange(lq) + lq_off
    k_pos = jnp.arange(c) + k_start
    mask = jnp.broadcast_to((k_pos < lk)[None, :], (lq, c))
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask, s, -1e30)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_new = acc * alpha + jnp.einsum("bhgqc,bchd->bhgqd", p,
                                       vc.astype(jnp.float32))
    return m_new, l_new, acc_new


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, soft_cap: float | None = None,
                      window: int | None = None, chunk: int = 1024,
                      unroll: bool = False) -> jax.Array:
    """FlashAttention schedule in pure jnp (KV streamed chunk by chunk).

    q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D).
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    chunk = min(chunk, lk)
    nk = math.ceil(lk / chunk)
    lkp = nk * chunk
    sm_scale = 1.0 / math.sqrt(d)
    lq_off = lk - lq   # queries right-aligned (decode/prefill continuation)

    qg = q.transpose(0, 2, 1, 3).reshape(b, hkv, group, lq, d)
    kp = jnp.pad(k, ((0, 0), (0, lkp - lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, lkp - lk), (0, 0), (0, 0)))

    m0 = jnp.full((b, hkv, group, lq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, lq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, lq, d), jnp.float32)

    # One chunk is checkpointed: the backward recomputes that chunk's
    # logits instead of saving them — the FlashAttention-bwd structure.
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def _update(carry, kc, vc, ic):
        return _chunk_update(qg, kc, vc, carry, k_start=ic * chunk, lk=lk,
                             sm_scale=sm_scale, causal=causal,
                             soft_cap=soft_cap, window=window,
                             lq_off=lq_off)

    def step(carry, ic):
        kc = jax.lax.dynamic_slice_in_dim(kp, ic * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, ic * chunk, chunk, axis=1)
        return _update(carry, kc, vc, ic), None

    if unroll:
        carry = (m0, l0, a0)
        for ic in range(nk):
            # skip chunks that are fully masked (causal / local window)
            if causal and ic * chunk > lq_off + lq - 1:
                continue
            if window is not None and (ic + 1) * chunk - 1 < lq_off - window + 1:
                continue
            carry, _ = step(carry, ic)
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nk))

    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.reshape(b, hq, lq, d).transpose(0, 2, 1, 3)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, soft_cap: float | None = None,
              window: int | None = None, impl: str = "chunked",
              chunk: int = 1024) -> jax.Array:
    """Multi-head GQA attention.  q: (B, Lq, Hq, D); k/v: (B, Lk, Hkv, D)."""
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal,
                             logits_soft_cap=soft_cap, window=window)
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, soft_cap=soft_cap,
                               window=window)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, soft_cap=soft_cap,
                                 window=window, chunk=chunk)
    if impl == "chunked_unroll":
        return chunked_attention(q, k, v, causal=causal, soft_cap=soft_cap,
                                 window=window, chunk=chunk, unroll=True)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, soft_cap: float | None = None,
                     window: int | None = None) -> jax.Array:
    """One-token attention over a KV cache.

    q: (B, 1, Hq, D); caches: (B, Lmax, Hkv, D); cache_len: () or (B,) —
    number of valid cache entries (including the current token).
    """
    b, _, hq, d = q.shape
    _, lmax, hkv, _ = k_cache.shape
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(d)
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    k_pos = jnp.arange(lmax)
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    if window is not None:
        valid &= k_pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)
