"""3D-TrIM convolution as a TPU Pallas kernel.

TPU-native re-expression of the paper's dataflow (DESIGN.md §2):

* **Input-stationary strips.**  The padded ifmap is tiled into
  non-overlapping strips of ``TH`` rows.  A strip is fetched from HBM
  exactly once and stays resident in VMEM while every C_out tile consumes
  it — the grid order is ``(N, group, strip, cout)`` with the input
  BlockSpec index map *ignoring the cout axis*, which is the BlockSpec
  image of the paper's P_O slices sharing one Input Recycling Buffer.

* **Shadow-register carry.**  The ``K-1`` boundary rows a strip needs from
  its predecessor are *not* re-fetched from HBM (that would be TrIM's
  end-of-row overhead).  They are carried across sequential grid steps in
  a VMEM scratch buffer (``carry_ref``) — the exact role the paper's
  shadow registers play at the register level.

* **Weight-stationary MXU taps.**  The K x K spatial taps are unrolled into
  K^2 dense matmuls ``(TH_out * W_out, Cin) x (Cin, TCout)`` against the
  stationary weight tile — the triangular PE movement re-shaped for a
  128 x 128 systolic MXU instead of a 3 x 3 scalar PE slice.

* **Adder tree + fused epilogue.**  Tap/channel partial sums accumulate in
  an fp32 register accumulator (the in-kernel analogue of the P_O adder
  trees); an optional bias + activation epilogue is applied to the
  accumulator before the single store to HBM, so inference layers pay no
  extra output round-trip.

* **Grouped / depthwise.**  ``groups > 1`` adds a group axis to the grid;
  each group sweeps its own channel slice with its own carry, covering the
  MobileNet-style depthwise workloads of the paper's OPs/Access study.

All geometry (strips, carry, grid, padded layouts) comes from
``core.conv_plan.ConvPlan`` — the same object that produces the analytical
HBM traffic numbers, so the kernel and the model cannot disagree.
Supports arbitrary K and stride (kernel tiling for huge K is provided by
``ops.conv2d``); validated in interpret mode against ``ref.conv2d``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_plan import ConvPlan

ACTIVATIONS = {
    None: lambda a: a,
    "relu": lambda a: jnp.maximum(a, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int, th_out: int,
            w_out: int, n_cout_tiles: int, activation: str | None,
            has_bias: bool):
    """One grid step: strip ``g`` of (image ``n``, group) x cout tile."""
    if has_bias:
        b_ref, o_ref, carry_ref = rest
    else:
        b_ref, (o_ref, carry_ref) = None, rest
    g = pl.program_id(2)
    co = pl.program_id(3)
    s = stride
    r = (kh - 1) % s  # static in-window row offset (ConvPlan.row_offset)

    if kh > 1:
        @pl.when(jnp.logical_and(g == 0, co == 0))
        def _reset_carry():
            # First strip of a (batch, group) sweep: no predecessor, the
            # carry region is zero padding.
            carry_ref[...] = jnp.zeros_like(carry_ref)

        window = jnp.concatenate([carry_ref[...], x_ref[0]], axis=0)
    else:
        window = x_ref[0]

    cin = window.shape[-1]
    acc = jnp.zeros((th_out * w_out, o_ref.shape[-1]), jnp.float32)
    for ki in range(kh):       # the K x K taps: triangular movement as
        for kj in range(kw):   # K^2 shifted views of the resident window
            rows = window[ki + r: ki + r + (th_out - 1) * s + 1: s,
                          kj: kj + (w_out - 1) * s + 1: s, :]
            acc += jnp.dot(rows.reshape(th_out * w_out, cin),
                           w_ref[ki, kj],
                           preferred_element_type=jnp.float32)
    # fused epilogue: bias + activation on the fp32 accumulator
    if has_bias:
        acc = acc + b_ref[0].astype(jnp.float32)
    acc = ACTIVATIONS[activation](acc)
    o_ref[0] = acc.reshape(th_out, w_out, -1).astype(o_ref.dtype)

    if kh > 1:
        @pl.when(co == n_cout_tiles - 1)
        def _update_carry():
            # Shadow registers: keep the last K-1 rows for the next strip.
            carry_ref[...] = window[-(kh - 1):]


def make_plan(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
              groups: int = 1, dtype_bytes: int = 4,
              tile_h: int | None = None,
              tile_cout: int | None = None) -> ConvPlan:
    """The exact plan :func:`trim_conv2d` executes for these arguments."""
    return ConvPlan.build(x_shape, w_shape, stride=stride, pad=pad,
                          groups=groups, dtype_bytes=dtype_bytes,
                          tile_h=tile_h, tile_cout=tile_cout)


@functools.partial(jax.jit, static_argnames=(
    "stride", "pad", "tile_h", "tile_cout", "groups", "activation",
    "interpret"))
def trim_conv2d(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                *, stride: int = 1, pad: int = 0, tile_h: int | None = None,
                tile_cout: int | None = None, groups: int = 1,
                activation: str | None = None,
                interpret: bool = True) -> jax.Array:
    """Strided (grouped) 2D convolution with fused bias + activation.

    x: (N, H, W, Cin); w: (K, K, Cin/groups, Cout); bias: (Cout,) or None.
    ``pad`` is symmetric zero padding (use ``(K-1)//2`` for 'same');
    ``activation`` is one of ``None | "relu" | "gelu" | "silu"``.
    Returns (N, H_out, W_out, Cout).
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"choose from {sorted(ACTIVATIONS, key=str)}")
    plan = make_plan(x.shape, w.shape, stride=stride, pad=pad, groups=groups,
                     dtype_bytes=x.dtype.itemsize, tile_h=tile_h,
                     tile_cout=tile_cout)

    # --- layout: pad once in HBM, tile into non-overlapping strips ---------
    z = jnp.pad(x, ((0, 0), (pad, max(plan.pad_bottom, 0)), (pad, pad),
                    (0, 0)))
    if plan.pad_bottom < 0:
        z = z[:, :plan.rows_padded]
    assert z.shape == plan.padded_input_shape, (z.shape, plan)
    assert plan.wp >= (plan.w_out - 1) * plan.stride + plan.kw

    cpp, cout_pg = plan.cout_padded_per_group, plan.cout_per_group
    wk = w.reshape(plan.kh, plan.kw, plan.cin_per_group, groups, cout_pg)
    wk = jnp.pad(wk, ((0, 0),) * 4 + ((0, cpp - cout_pg),))
    wk = wk.reshape(plan.padded_weight_shape)

    co_tiles = plan.co_tiles
    in_specs = [
        # fresh strip: index map ignores `co` -> fetched once per strip,
        # shared by every cout tile (IRB sharing); one channel slice per
        # group
        pl.BlockSpec(plan.in_block, lambda ni, gr, g, co: (ni, g, 0, gr)),
        # stationary weight tile of this group's cout block
        pl.BlockSpec(plan.w_block,
                     lambda ni, gr, g, co: (0, 0, 0, gr * co_tiles + co)),
    ]
    inputs = [z, wk]
    if bias is not None:
        bp = jnp.pad(bias.reshape(groups, cout_pg),
                     ((0, 0), (0, cpp - cout_pg)))
        inputs.append(bp.reshape(1, groups * cpp))
        in_specs.append(pl.BlockSpec(
            (1, plan.tile_cout),
            lambda ni, gr, g, co: (0, gr * co_tiles + co)))

    out_padded = pl.pallas_call(
        functools.partial(_kernel, kh=plan.kh, kw=plan.kw,
                          stride=plan.stride, th_out=plan.th_out,
                          w_out=plan.w_out, n_cout_tiles=co_tiles,
                          activation=activation, has_bias=bias is not None),
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            plan.out_block,
            lambda ni, gr, g, co: (ni, g, 0, gr * co_tiles + co)),
        out_shape=jax.ShapeDtypeStruct(plan.padded_output_shape, x.dtype),
        scratch_shapes=[pltpu.VMEM(plan.carry_shape, x.dtype)],
        interpret=interpret,
    )(*inputs)

    out = out_padded[:, plan.delta:plan.delta + plan.h_out]
    if cpp != cout_pg:
        out = out.reshape(plan.n, plan.h_out, plan.w_out, groups, cpp)
        out = out[..., :cout_pg].reshape(plan.n, plan.h_out, plan.w_out,
                                         plan.cout)
    return out


def hbm_traffic_model(n, h, width, cin, cout, k, stride=1, pad=0,
                      tile_h=8, tile_cout=128, dtype_bytes=4,
                      mode: str = "3dtrim") -> dict:
    """Analytical HBM bytes for the kernel — thin wrapper over
    ``ConvPlan.hbm_bytes`` kept for API compatibility.

    ``mode='trim'`` models strips that re-fetch their K-1 halo rows from
    HBM (no carry scratch) — the overhead the shadow registers eliminate.
    """
    plan = ConvPlan(n=n, h=h, w=width, cin=cin, cout=cout, kh=k, kw=k,
                    stride=stride, pad=pad, dtype_bytes=dtype_bytes,
                    tile_h=tile_h, tile_cout=tile_cout)
    return plan.hbm_bytes(mode)
