"""3D-TrIM convolution as a TPU Pallas kernel.

TPU-native re-expression of the paper's dataflow (DESIGN.md §2):

* **Input-stationary strips.**  The padded ifmap is tiled into
  non-overlapping strips of ``TH`` rows.  A strip is fetched from HBM
  exactly once and stays resident in VMEM while every C_out tile consumes
  it — the grid order is ``(N, strip, cout)`` with the input BlockSpec
  index map *ignoring the cout axis*, which is the BlockSpec image of the
  paper's P_O slices sharing one Input Recycling Buffer.

* **Shadow-register carry.**  The ``K-1`` boundary rows a strip needs from
  its predecessor are *not* re-fetched from HBM (that would be TrIM's
  end-of-row overhead).  They are carried across sequential grid steps in
  a VMEM scratch buffer (``carry_ref``) — the exact role the paper's
  shadow registers play at the register level.

* **Weight-stationary MXU taps.**  The K x K spatial taps are unrolled into
  K^2 dense matmuls ``(TH_out * W_out, Cin) x (Cin, TCout)`` against the
  stationary weight tile — the triangular PE movement re-shaped for a
  128 x 128 systolic MXU instead of a 3 x 3 scalar PE slice.

* **Adder tree.**  Tap/channel partial sums accumulate in an fp32 register
  accumulator, the in-kernel analogue of the P_O adder trees.

Supports arbitrary K and stride (kernel tiling for huge K is provided by
``ops.conv2d``); validated in interpret mode against ``ref.conv2d``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, carry_ref, *, kh: int, kw: int,
            stride: int, th_out: int, w_out: int, n_cout_tiles: int):
    """One grid step: strip ``g`` of image ``n`` against cout tile ``co``."""
    g = pl.program_id(1)
    co = pl.program_id(2)
    s = stride
    r = (kh - 1) % s  # static in-window row offset (see ops.conv2d)

    if kh > 1:
        @pl.when(jnp.logical_and(g == 0, co == 0))
        def _reset_carry():
            # Strip 0 has no predecessor: the carry region is zero padding.
            carry_ref[...] = jnp.zeros_like(carry_ref)

        window = jnp.concatenate([carry_ref[...], x_ref[0]], axis=0)
    else:
        window = x_ref[0]

    cin = window.shape[-1]
    acc = jnp.zeros((th_out * w_out, o_ref.shape[-1]), jnp.float32)
    for ki in range(kh):       # the K x K taps: triangular movement as
        for kj in range(kw):   # K^2 shifted views of the resident window
            rows = window[ki + r: ki + r + (th_out - 1) * s + 1: s,
                          kj: kj + (w_out - 1) * s + 1: s, :]
            acc += jnp.dot(rows.reshape(th_out * w_out, cin),
                           w_ref[ki, kj],
                           preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(th_out, w_out, -1).astype(o_ref.dtype)

    if kh > 1:
        @pl.when(co == n_cout_tiles - 1)
        def _update_carry():
            # Shadow registers: keep the last K-1 rows for the next strip.
            carry_ref[...] = window[-(kh - 1):]


@functools.partial(jax.jit, static_argnames=(
    "stride", "pad", "tile_h", "tile_cout", "interpret"))
def trim_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                pad: int = 0, tile_h: int | None = None,
                tile_cout: int | None = None,
                interpret: bool = True) -> jax.Array:
    """Strided 2D convolution.  x: (N, H, W, Cin); w: (K, K, Cin, Cout).

    ``pad`` is symmetric zero padding (use ``(K-1)//2`` for 'same').
    Returns (N, H_out, W_out, Cout).
    """
    n, h, width, cin = x.shape
    kh, kw_dim, _, cout = w.shape
    s = stride
    h_out = (h + 2 * pad - kh) // s + 1
    w_out = (width + 2 * pad - kw_dim) // s + 1

    # --- tile planning -----------------------------------------------------
    if tile_cout is None:
        tile_cout = min(cout, 128 if cout % 128 == 0 else cout)
    if tile_h is None:
        # strip height: multiple of stride, resident set within ~8 MiB
        wp_bytes = (width + 2 * pad + kh) * cin * x.dtype.itemsize
        tile_h = max(s, min(h_out * s, (8 << 20) // max(wp_bytes, 1)))
        tile_h -= tile_h % s
        tile_h = max(tile_h, s)
    assert tile_h % s == 0, "tile_h must be a multiple of the stride"
    th_out = tile_h // s

    # --- layout: pad once in HBM, tile into non-overlapping strips ---------
    delta = (kh - 1) // s                      # top rows of the padded output
    g_tiles = math.ceil((h_out + delta) / th_out)
    rows_needed = g_tiles * tile_h
    pad_bottom = rows_needed - h - pad
    z = jnp.pad(x, ((0, 0), (pad, max(pad_bottom, 0)), (pad, pad), (0, 0)))
    if pad_bottom < 0:
        z = z[:, :rows_needed]
    wp = z.shape[2]
    assert wp >= (w_out - 1) * s + kw_dim

    co_tiles = math.ceil(cout / tile_cout)
    if cout % tile_cout:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0),
                        (0, co_tiles * tile_cout - cout)))

    out_padded = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw_dim, stride=s, th_out=th_out,
                          w_out=w_out, n_cout_tiles=co_tiles),
        grid=(n, g_tiles, co_tiles),
        in_specs=[
            # fresh strip: index map ignores `co` -> fetched once per strip,
            # shared by every cout tile (IRB sharing)
            pl.BlockSpec((1, tile_h, wp, cin), lambda ni, g, co: (ni, g, 0, 0)),
            # stationary weight tile
            pl.BlockSpec((kh, kw_dim, cin, tile_cout),
                         lambda ni, g, co: (0, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((1, th_out, w_out, tile_cout),
                               lambda ni, g, co: (ni, g, 0, co)),
        out_shape=jax.ShapeDtypeStruct(
            (n, g_tiles * th_out, w_out, co_tiles * tile_cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((max(kh - 1, 1), wp, cin), x.dtype)],
        interpret=interpret,
    )(z, w)
    return out_padded[:, delta:delta + h_out, :, :cout]


def hbm_traffic_model(n, h, width, cin, cout, k, stride=1, pad=0,
                      tile_h=8, tile_cout=128, dtype_bytes=4,
                      mode: str = "3dtrim") -> dict:
    """Analytical HBM bytes for the kernel — TPU image of the paper's model.

    ``mode='trim'`` models strips that re-fetch their K-1 halo rows from
    HBM (no carry scratch) — the overhead the shadow registers eliminate.
    """
    s = stride
    h_out = (h + 2 * pad - k) // s + 1
    w_out = (width + 2 * pad - k) // s + 1
    th_out = tile_h // s
    g_tiles = math.ceil((h_out + (k - 1) // s) / th_out)
    wp = width + 2 * pad
    halo_rows = 0 if mode == "3dtrim" else (g_tiles - 1) * (k - 1)
    in_bytes = n * (g_tiles * tile_h + halo_rows) * wp * cin * dtype_bytes
    w_bytes = k * k * cin * cout * dtype_bytes * g_tiles  # refetch per strip
    out_bytes = n * h_out * w_out * cout * dtype_bytes
    return dict(input=in_bytes, weights=w_bytes, output=out_bytes,
                total=in_bytes + w_bytes + out_bytes,
                overhead_pct=100.0 * halo_rows / max(g_tiles * tile_h, 1))
