"""3D-TrIM convolution as a TPU Pallas kernel.

TPU-native re-expression of the paper's dataflow (DESIGN.md §2, §4):

* **Input-stationary strips.**  The padded ifmap is tiled into
  non-overlapping strips of ``TH`` rows.  A strip is fetched from HBM
  exactly once and stays resident in VMEM while every C_out tile consumes
  it — the grid order is ``(N, group, strip, cout)`` with the input
  BlockSpec index map *ignoring the cout axis*, which is the BlockSpec
  image of the paper's P_O slices sharing one Input Recycling Buffer.

* **Two dataflows for the strip boundary** (``dataflow=`` knob, DESIGN.md
  §4).  ``"carry"`` is the paper's shadow registers: the ``K-1`` boundary
  rows a strip needs from its predecessor ride across *sequential* grid
  steps in a VMEM scratch (``carry_ref``) — zero halo traffic, serialized
  strips.  ``"halo"`` is the TrIM baseline re-expressed at strip level:
  every strip over-fetches its ``K-1`` predecessor rows through an
  overlapping (unblocked) BlockSpec — it pays the halo bytes the shadow
  registers eliminate, but has no cross-step state, so batch / group /
  strip / cout grid axes can execute in any order (parallelizable).  The
  autotuner (``core/autotune.py``) picks per layer.

* **Weight-stationary MXU taps.**  The K x K spatial taps are unrolled into
  K^2 dense matmuls ``(TH_out * W_out, Cin) x (Cin, TCout)`` against the
  stationary weight tile — the triangular PE movement re-shaped for a
  128 x 128 systolic MXU instead of a 3 x 3 scalar PE slice.

* **Adder tree + fused epilogue.**  Tap/channel partial sums accumulate in
  an fp32 register accumulator (the in-kernel analogue of the P_O adder
  trees); an optional bias + activation epilogue is applied to the
  accumulator before the single store to HBM, so inference layers pay no
  extra output round-trip.

* **Grouped / depthwise.**  ``groups > 1`` adds a group axis to the grid;
  each group sweeps its own channel slice with its own carry, covering the
  MobileNet-style depthwise workloads of the paper's OPs/Access study.

* **Pre-packed weights.**  ``packed_cout`` signals that ``w`` (and
  ``bias``) already sit in the plan's padded layouts
  (``ops.pack_conv2d_weights``), so the per-call pad/reshape in the hot
  path is skipped — the load-time packing of ``models/layers.py``.

* **Backward kernels** (DESIGN.md §5).  Both conv cotangents are TrIM
  convolutions: ``trim_conv2d_input_grad`` re-expresses dx as a
  stride-1 forward problem (dilated/edge-padded cotangent x
  flipped/transposed weights) through this very kernel — dataflow axis
  included — and ``trim_conv2d_weight_grad`` is a dedicated kernel that
  contracts the spatial axes: cotangent strips stay resident with their
  overlapping ifmap window while the K x K taps accumulate into a
  weight-shaped fp32 output block revisited across the (batch, strip)
  sweep.  ``ops.conv2d`` wires them into a ``jax.custom_vjp``.

All geometry (strips, carry, halo windows, grid, padded layouts) comes
from ``core.conv_plan.ConvPlan`` — the same object that produces the
analytical HBM traffic numbers, so the kernel and the model cannot
disagree.  Supports arbitrary K and stride (kernel tiling for huge K is
provided by ``ops.conv2d``); validated in interpret mode against
``ref.conv2d``.  ``interpret=None`` auto-detects the backend: the same
call site lowers natively on a real TPU and interprets elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_plan import ConvPlan, input_grad_geometry
from repro.kernels.runtime import resolve_interpret

ACTIVATIONS = {
    None: lambda a: a,
    "relu": lambda a: jnp.maximum(a, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _tap_matmuls(window, w_ref, *, kh: int, kw: int, stride: int,
                 th_out: int, w_out: int, n_out: int):
    """The K x K taps: triangular movement as K^2 shifted views of the
    resident window, each a dense MXU matmul.  ``window`` holds the strip
    plus its K-1 predecessor rows (from the carry scratch or the halo
    over-fetch — identical contents either way)."""
    s = stride
    r = (kh - 1) % s  # static in-window row offset (ConvPlan.row_offset)
    cin = window.shape[-1]
    # int8 inputs accumulate exactly in int32 on the MXU; floats in fp32
    acc_dtype = (jnp.int32 if jnp.issubdtype(window.dtype, jnp.integer)
                 else jnp.float32)
    acc = jnp.zeros((th_out * w_out, n_out), acc_dtype)
    for ki in range(kh):
        for kj in range(kw):
            rows = window[ki + r: ki + r + (th_out - 1) * s + 1: s,
                          kj: kj + (w_out - 1) * s + 1: s, :]
            acc += jnp.dot(rows.reshape(th_out * w_out, cin),
                           w_ref[ki, kj],
                           preferred_element_type=acc_dtype)
    return acc


def _epilogue_store(acc, s_ref, b_ref, o_ref, *, th_out: int, w_out: int,
                    activation: str | None):
    """Fused epilogue: (dequant) + bias + activation on the accumulator,
    then the single store to the output block.

    ``s_ref`` (int8 route) holds the per-out-channel dequant scale row
    and ``b_ref`` the *requantized int32 bias* — the int32 accumulator
    becomes f32 via exactly ``(acc + bias_q) * scale``: an exact integer
    add followed by one correctly-rounded multiply, the same operations
    as ``ref.dequant_params`` / ``ref.conv2d_quantized`` with no mul+add
    pair a backend could contract into an FMA, which is what makes the
    quantized kernel bit-exact against the oracle."""
    if s_ref is not None:
        if b_ref is not None:
            acc = acc + b_ref[0]       # int32 + int32: exact
        acc = acc.astype(jnp.float32) * s_ref[0].astype(jnp.float32)
    elif b_ref is not None:
        acc = acc + b_ref[0].astype(jnp.float32)
    acc = ACTIVATIONS[activation](acc)
    o_ref[0] = acc.reshape(th_out, w_out, -1).astype(o_ref.dtype)


def _carry_kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int,
                  th_out: int, w_out: int, n_cout_tiles: int,
                  activation: str | None, has_bias: bool,
                  has_scale: bool = False):
    """One grid step: strip ``g`` of (image ``n``, group) x cout tile,
    with the K-1 boundary rows carried across sequential strips."""
    s_ref = rest[0] if has_scale else None
    b_ref = rest[has_scale] if has_bias else None
    o_ref, carry_ref = rest[has_scale + has_bias:]
    g = pl.program_id(2)
    co = pl.program_id(3)

    if kh > 1:
        @pl.when(jnp.logical_and(g == 0, co == 0))
        def _reset_carry():
            # First strip of a (batch, group) sweep: no predecessor, the
            # carry region is zero padding.
            carry_ref[...] = jnp.zeros_like(carry_ref)

        window = jnp.concatenate([carry_ref[...], x_ref[0]], axis=0)
    else:
        window = x_ref[0]

    acc = _tap_matmuls(window, w_ref, kh=kh, kw=kw, stride=stride,
                       th_out=th_out, w_out=w_out, n_out=o_ref.shape[-1])
    _epilogue_store(acc, s_ref, b_ref, o_ref, th_out=th_out, w_out=w_out,
                    activation=activation)

    if kh > 1:
        @pl.when(co == n_cout_tiles - 1)
        def _update_carry():
            # Shadow registers: keep the last K-1 rows for the next strip.
            carry_ref[...] = window[-(kh - 1):]


def _halo_kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int,
                 th_out: int, w_out: int, activation: str | None,
                 has_bias: bool, has_scale: bool = False):
    """One grid step of the halo dataflow: the overlapping input window
    already contains the K-1 predecessor rows — no scratch, no cross-step
    dependency, any grid order."""
    s_ref = rest[0] if has_scale else None
    b_ref = rest[has_scale] if has_bias else None
    (o_ref,) = rest[has_scale + has_bias:]
    acc = _tap_matmuls(x_ref[0], w_ref, kh=kh, kw=kw, stride=stride,
                       th_out=th_out, w_out=w_out, n_out=o_ref.shape[-1])
    _epilogue_store(acc, s_ref, b_ref, o_ref, th_out=th_out, w_out=w_out,
                    activation=activation)


def make_plan(x_shape, w_shape, *, stride: int = 1, pad: int = 0,
              groups: int = 1, dtype_bytes: int = 4,
              tile_h: int | None = None,
              tile_cout: int | None = None,
              dataflow: str = "carry") -> ConvPlan:
    """The exact plan :func:`trim_conv2d` executes for these arguments."""
    return ConvPlan.build(x_shape, w_shape, stride=stride, pad=pad,
                          groups=groups, dtype_bytes=dtype_bytes,
                          tile_h=tile_h, tile_cout=tile_cout,
                          dataflow=dataflow)


@functools.partial(jax.jit, static_argnames=(
    "stride", "pad", "tile_h", "tile_cout", "groups", "activation",
    "dataflow", "packed_cout", "interpret"))
def trim_conv2d(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                scale: jax.Array | None = None,
                *, stride: int = 1, pad: int = 0, tile_h: int | None = None,
                tile_cout: int | None = None, groups: int = 1,
                activation: str | None = None,
                dataflow: str = "carry",
                packed_cout: int | None = None,
                interpret: bool | None = None) -> jax.Array:
    """Strided (grouped) 2D convolution with fused bias + activation.

    x: (N, H, W, Cin); w: (K, K, Cin/groups, Cout); bias: (Cout,) or None.
    ``pad`` is symmetric zero padding (use ``(K-1)//2`` for 'same');
    ``activation`` is one of ``None | "relu" | "gelu" | "silu"``;
    ``dataflow`` selects the strip-boundary schedule (DESIGN.md §4):
    ``"carry"`` (shadow-register scratch, serialized strips, zero halo) or
    ``"halo"`` (overlapping strip fetch, order-independent grid).

    ``scale`` enables the int8 route (DESIGN.md §11): x and w are int8,
    the K x K taps run as int8 MXU matmuls with exact int32 accumulation,
    and the fused epilogue dequantizes ``(acc + bias) * scale`` in f32 —
    ``scale`` is the per-out-channel ``x_scale * w_scale`` row of
    ``ref.dequant_params`` (shape ``(Cout,)``; the packed layout when
    ``packed_cout``), ``bias`` the *requantized int32 bias* from the same
    helper (zero-point correction plus the real bias on the scale grid),
    and the caller pre-pads 'same' inputs with the activation zero point
    (``pad=0`` here).  The output is f32.

    ``packed_cout``: when not None, ``w`` is already in the plan's
    ``padded_weight_shape`` (and ``bias``/``scale``, if given, in the
    padded ``(1, groups * cout_padded)`` layout) as produced by
    ``ops.pack_conv2d_weights`` with the same ``tile_cout``;
    ``packed_cout`` is the *logical* C_out the caller gets back.

    ``interpret=None`` auto-detects the backend (native on TPU).
    Returns (N, H_out, W_out, Cout).
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"choose from {sorted(ACTIVATIONS, key=str)}")
    quantized = scale is not None
    if jnp.issubdtype(x.dtype, jnp.integer) != quantized:
        raise ValueError(
            "the int8 route requires BOTH integer inputs and a dequant "
            f"scale: got x.dtype={x.dtype}, scale "
            f"{'given' if quantized else 'missing'}")
    if quantized and not jnp.issubdtype(w.dtype, jnp.integer):
        raise ValueError(f"quantized conv needs integer weights, "
                         f"got {w.dtype}")
    if quantized and bias is not None \
            and not jnp.issubdtype(bias.dtype, jnp.integer):
        raise ValueError(
            "quantized conv takes the requantized int32 bias of "
            f"ref.dequant_params, got {bias.dtype}")
    interpret = resolve_interpret(interpret)
    if packed_cout is None:
        w_shape = w.shape
    else:
        if tile_cout is None:
            raise ValueError("packed weights require the tile_cout they "
                             "were packed for")
        w_shape = (w.shape[0], w.shape[1], w.shape[2], packed_cout)
    plan = make_plan(x.shape, w_shape, stride=stride, pad=pad,
                     groups=groups, dtype_bytes=x.dtype,
                     tile_h=tile_h, tile_cout=tile_cout, dataflow=dataflow)

    # --- layout: pad once in HBM, tile into non-overlapping strips ---------
    z = jnp.pad(x, ((0, 0), (pad, max(plan.pad_bottom, 0)), (pad, pad),
                    (0, 0)))
    if plan.pad_bottom < 0:
        z = z[:, :plan.rows_padded]
    assert z.shape == plan.padded_input_shape, (z.shape, plan)
    assert plan.wp >= (plan.w_out - 1) * plan.stride + plan.kw

    cpp, cout_pg = plan.cout_padded_per_group, plan.cout_per_group
    if packed_cout is None:
        wk = w.reshape(plan.kh, plan.kw, plan.cin_per_group, groups,
                       cout_pg)
        wk = jnp.pad(wk, ((0, 0),) * 4 + ((0, cpp - cout_pg),))
        wk = wk.reshape(plan.padded_weight_shape)
    else:
        assert w.shape == plan.padded_weight_shape, \
            (w.shape, plan.padded_weight_shape)
        wk = w

    co_tiles = plan.co_tiles
    if plan.dataflow == "halo":
        # Overlapping strip windows (unblocked indexing, element offsets):
        # strip g reads rows [g*TH, g*TH + TH + K-1) of the halo-padded
        # input, whose K-1 extra top zero rows are this strip-level image
        # of TrIM's re-fetched boundary — the halo bytes ConvPlan bills as
        # mode="trim".
        z = jnp.pad(z, ((0, 0), (plan.kh - 1, 0), (0, 0), (0, 0)))
        assert z.shape == plan.halo_padded_input_shape
        th, cin_pg = plan.tile_h, plan.cin_per_group
        in_specs = [
            pl.BlockSpec(plan.halo_in_block,
                         lambda ni, gr, g, co: (ni, g * th, 0, gr * cin_pg),
                         indexing_mode=pl.unblocked),
        ]
        kernel = functools.partial(
            _halo_kernel, kh=plan.kh, kw=plan.kw, stride=plan.stride,
            th_out=plan.th_out, w_out=plan.w_out, activation=activation,
            has_bias=bias is not None, has_scale=quantized)
        scratch_shapes = []
    else:
        in_specs = [
            # fresh strip: index map ignores `co` -> fetched once per
            # strip, shared by every cout tile (IRB sharing); one channel
            # slice per group
            pl.BlockSpec(plan.in_block,
                         lambda ni, gr, g, co: (ni, g, 0, gr)),
        ]
        kernel = functools.partial(
            _carry_kernel, kh=plan.kh, kw=plan.kw, stride=plan.stride,
            th_out=plan.th_out, w_out=plan.w_out, n_cout_tiles=co_tiles,
            activation=activation, has_bias=bias is not None,
            has_scale=quantized)
        scratch_shapes = [pltpu.VMEM(plan.carry_shape, x.dtype)]

    # stationary weight tile of this group's cout block
    in_specs.append(pl.BlockSpec(
        plan.w_block, lambda ni, gr, g, co: (0, 0, 0, gr * co_tiles + co)))
    inputs = [z, wk]

    def _cout_row(v):
        """Pad a per-out-channel row (bias / dequant scale) to the plan's
        ``(1, groups * cout_padded)`` layout and give it the cout-tile
        BlockSpec."""
        if packed_cout is None:
            vp = jnp.pad(v.reshape(groups, cout_pg),
                         ((0, 0), (0, cpp - cout_pg)))
            vp = vp.reshape(1, groups * cpp)
        else:
            assert v.shape == (1, groups * cpp), v.shape
            vp = v
        inputs.append(vp)
        in_specs.append(pl.BlockSpec(
            (1, plan.tile_cout),
            lambda ni, gr, g, co: (0, gr * co_tiles + co)))

    if quantized:
        _cout_row(scale.astype(jnp.float32))
    if bias is not None:
        _cout_row(bias)

    compiler_params = None
    if not interpret:
        # carry: every axis is "arbitrary" (the scratch serializes the
        # sweep); halo: no cross-step state, all axes parallelizable.
        semantics = ("parallel",) * 4 if plan.dataflow == "halo" \
            else ("arbitrary",) * 4
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=semantics)

    out_padded = pl.pallas_call(
        kernel,
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            plan.out_block,
            lambda ni, gr, g, co: (ni, g, 0, gr * co_tiles + co)),
        out_shape=jax.ShapeDtypeStruct(
            plan.padded_output_shape,
            jnp.float32 if quantized else x.dtype),
        scratch_shapes=scratch_shapes,
        compiler_params=compiler_params,
        interpret=interpret,
    )(*inputs)

    out = out_padded[:, plan.delta:plan.delta + plan.h_out]
    if cpp != cout_pg:
        out = out.reshape(plan.n, plan.h_out, plan.w_out, groups, cpp)
        out = out[..., :cout_pg].reshape(plan.n, plan.h_out, plan.w_out,
                                         plan.cout)
    return out


# ---------------------------------------------------------------------------
# Backward kernels (DESIGN.md §5) — both cotangents are TrIM convolutions
# ---------------------------------------------------------------------------

def make_weight_grad_plan(x_shape, w_shape, *, stride: int = 1,
                          pad: int = 0, groups: int = 1,
                          dtype_bytes: int = 4,
                          tile_go: int | None = None,
                          tile_cout: int | None = None):
    """The exact plan :func:`trim_conv2d_weight_grad` executes."""
    return ConvPlan.build_weight_grad(
        x_shape, w_shape, stride=stride, pad=pad, groups=groups,
        dtype_bytes=dtype_bytes, tile_go=tile_go, tile_cout=tile_cout)


def transpose_conv_weights(w: jax.Array, groups: int = 1) -> jax.Array:
    """Flip the spatial taps and swap the channel roles per group:
    ``(KH, KW, Cin/g, Cout) -> (KH, KW, Cout/g, Cin)`` with the output
    (= forward input) channels group-major — the weight tensor of the
    input-gradient convolution."""
    kh, kw, cin_pg, cout = w.shape
    wt = w[::-1, ::-1].reshape(kh, kw, cin_pg, groups, cout // groups)
    return wt.transpose(0, 1, 4, 3, 2).reshape(kh, kw, cout // groups,
                                               groups * cin_pg)


@functools.partial(jax.jit, static_argnames=(
    "x_shape", "stride", "pad", "groups", "tile_h", "tile_cout",
    "dataflow", "interpret"))
def trim_conv2d_input_grad(g: jax.Array, w: jax.Array, *,
                           x_shape: tuple, stride: int = 1, pad: int = 0,
                           groups: int = 1, tile_h: int | None = None,
                           tile_cout: int | None = None,
                           dataflow: str = "carry",
                           interpret: bool | None = None) -> jax.Array:
    """Input cotangent of ``trim_conv2d`` — itself a TrIM convolution.

    g: (N, H_out, W_out, Cout) output cotangent; w: (KH, KW, Cin/g, Cout)
    the forward weights; ``x_shape``/``stride``/``pad`` describe the
    FORWARD problem.  The cotangent is stride-dilated, edge-padded by
    ``K-1-pad`` (plus the ``(dim+2p-K) % s`` residual on the low edges'
    opposite sides) and convolved at stride 1 with the flipped/transposed
    weights through the ordinary forward kernel — dataflow/tile knobs and
    traffic accounting apply unchanged (``ConvPlan.build_input_grad``).
    Returns dx with shape ``x_shape``.
    """
    geo = input_grad_geometry(x_shape, w.shape, stride=stride, pad=pad,
                              groups=groups)
    if stride > 1:
        gd = jnp.zeros(geo["g_dilated_shape"], g.dtype)
        gd = gd.at[:, ::stride, ::stride, :].set(g)
    else:
        gd = g
    gp = jnp.pad(gd, ((0, 0), geo["pad_h"], geo["pad_w"], (0, 0)))
    wt = transpose_conv_weights(w, groups)
    return trim_conv2d(gp, wt, stride=1, pad=0, tile_h=tile_h,
                       tile_cout=tile_cout, groups=groups,
                       dataflow=dataflow, interpret=interpret)


def _weight_grad_kernel(x_ref, g_ref, o_ref, *, kh: int, kw: int,
                        stride: int, tile_go: int, w_out: int):
    """One grid step: strip of cotangent rows x its overlapping ifmap
    window; the K x K taps are dense MXU matmuls accumulated into the
    weight-shaped fp32 output block, which is revisited (and therefore
    stays resident) across the sequential (batch, strip) sweep."""
    ni = pl.program_id(2)
    gs = pl.program_id(3)

    @pl.when(jnp.logical_and(ni == 0, gs == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    window = x_ref[0]                      # (window_rows, Wp, Cin/g)
    cin = window.shape[-1]
    s = stride
    gv = g_ref[0].reshape(tile_go * w_out, -1)   # (TGo*Wo, TCout)
    for ki in range(kh):
        for kj in range(kw):
            rows = window[ki: ki + (tile_go - 1) * s + 1: s,
                          kj: kj + (w_out - 1) * s + 1: s, :]
            acc = jnp.dot(rows.reshape(tile_go * w_out, cin).T, gv,
                          preferred_element_type=jnp.float32)
            o_ref[ki, kj] = o_ref[ki, kj] + acc


@functools.partial(jax.jit, static_argnames=(
    "kernel_size", "stride", "pad", "groups", "tile_go", "tile_cout",
    "interpret"))
def trim_conv2d_weight_grad(x: jax.Array, g: jax.Array, *,
                            kernel_size: tuple, stride: int = 1,
                            pad: int = 0, groups: int = 1,
                            tile_go: int | None = None,
                            tile_cout: int | None = None,
                            interpret: bool | None = None) -> jax.Array:
    """Weight cotangent of ``trim_conv2d`` — the conv of ifmap over
    cotangent, with the spatial axes contracted.

    x: (N, H, W, Cin) the forward input; g: (N, H_out, W_out, Cout) the
    output cotangent; ``kernel_size`` = (KH, KW) of the forward weights
    (not derivable from the shapes when ``(dim+2p-K) % s > 0``);
    ``stride``/``pad``/``groups`` as in the forward call.
    Returns dw with shape (KH, KW, Cin/groups, Cout) in ``x.dtype``.

    All geometry comes from ``ConvPlan.build_weight_grad``; grouped /
    depthwise problems run in the same single ``pallas_call`` (group is
    a grid axis, exactly as in the forward kernel).
    """
    interpret = resolve_interpret(interpret)
    n, h, w_in, cin = x.shape
    _, h_out, w_out, cout = g.shape
    kh, kw = kernel_size
    if (h_out != (h + 2 * pad - kh) // stride + 1
            or w_out != (w_in + 2 * pad - kw) // stride + 1):
        raise ValueError(
            f"cotangent shape {g.shape[1:3]} does not match the forward "
            f"geometry of x={x.shape[1:3]} K=({kh}, {kw}) "
            f"stride={stride} pad={pad}")
    plan = make_weight_grad_plan(
        x.shape, (kh, kw, cin // groups, cout), stride=stride, pad=pad,
        groups=groups, dtype_bytes=x.dtype, tile_go=tile_go,
        tile_cout=tile_cout)

    # --- layout: fold pad into HBM, round rows up to whole strips ----------
    bottom = plan.x_rows_padded - h - pad
    xp = jnp.pad(x, ((0, 0), (pad, max(bottom, 0)), (pad, pad), (0, 0)))
    if bottom < 0:
        xp = xp[:, :plan.x_rows_padded]
    assert xp.shape == plan.padded_x_shape, (xp.shape, plan)

    cpp, cout_pg = plan.cout_padded_per_group, plan.cout_per_group
    gk = g.reshape(n, h_out, w_out, groups, cout_pg)
    gk = jnp.pad(gk, ((0, 0), (0, plan.go_rows_padded - h_out), (0, 0),
                      (0, 0), (0, cpp - cout_pg)))
    gk = gk.reshape(plan.padded_g_shape)

    co_tiles, cin_pg = plan.co_tiles, plan.cin_per_group
    tgo_s = plan.tile_go * plan.stride
    in_specs = [
        # overlapping ifmap window of the strip's receptive field
        # (element offsets: successive windows share KH - s rows)
        pl.BlockSpec(plan.x_block,
                     lambda gr, co, ni, gs: (ni, gs * tgo_s, 0,
                                             gr * cin_pg),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec(plan.g_block,
                     lambda gr, co, ni, gs: (ni, gs, 0,
                                             gr * co_tiles + co)),
    ]
    kernel = functools.partial(
        _weight_grad_kernel, kh=plan.kh, kw=plan.kw, stride=plan.stride,
        tile_go=plan.tile_go, w_out=plan.w_out)

    compiler_params = None
    if not interpret:
        # the weight-shaped output block accumulates across (N, strip):
        # every axis is cross-step state -> all arbitrary
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",) * 4)

    dw_padded = pl.pallas_call(
        kernel,
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            plan.out_block,
            lambda gr, co, ni, gs: (0, 0, 0, gr * co_tiles + co)),
        out_shape=jax.ShapeDtypeStruct(plan.padded_out_shape, jnp.float32),
        compiler_params=compiler_params,
        interpret=interpret,
    )(xp, gk)

    dw = dw_padded.reshape(kh, kw, cin_pg, groups, cpp)[..., :cout_pg]
    return dw.reshape(kh, kw, cin_pg, cout).astype(x.dtype)


def hbm_traffic_model(n, h, width, cin, cout, k, stride=1, pad=0,
                      tile_h=8, tile_cout=128, dtype_bytes=4,
                      mode: str = "3dtrim") -> dict:
    """Analytical HBM bytes for the kernel — thin wrapper over
    ``ConvPlan.hbm_bytes`` kept for API compatibility.

    ``mode='trim'`` models strips that re-fetch their K-1 halo rows from
    HBM (no carry scratch) — the overhead the shadow registers eliminate,
    i.e. exactly what the ``dataflow="halo"`` kernel pays.
    """
    plan = ConvPlan(n=n, h=h, w=width, cin=cin, cout=cout, kh=k, kw=k,
                    stride=stride, pad=pad, dtype_bytes=dtype_bytes,
                    tile_h=tile_h, tile_cout=tile_cout)
    return plan.hbm_bytes(mode)
