"""Deterministic load-test harness for the serving engine (DESIGN.md §10).

Load tests must never depend on wall-clock: a CI box under contention
would turn every latency assertion flaky.  This module supplies the two
deterministic halves the serving tests and ``benchmarks/serve_bench.py``
share:

* **Seeded arrival generators** — :func:`poisson_arrivals` (open-loop
  exponential gaps), :func:`burst_arrivals` (synchronized request
  storms) and :func:`ramp_arrivals` (linearly increasing rate) all
  derive every timestamp from a ``numpy`` generator seeded by the
  caller, so the same seed always produces byte-identical traces.

* **A request-lifecycle recorder** — :class:`TraceRecorder` holds one
  :class:`RequestRecord` per request with its
  enqueue/batch/execute/complete timestamps (plus the bucket and
  replica that served it), and aggregates them into the latency
  percentiles and throughput the benchmark emits.

Timestamps are plain floats on whatever clock the caller drives —
:class:`VirtualClock` for the deterministic tests and replays,
``time.monotonic`` for the asyncio server in ``launch/serve_conv.py``.
The recorder never reads a clock itself.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "VirtualClock", "RequestRecord", "TraceRecorder",
    "poisson_arrivals", "burst_arrivals", "ramp_arrivals",
]


class VirtualClock:
    """A monotonic clock the test harness advances by hand.

    ``now()`` mirrors ``time.monotonic()`` so the serving engine can take
    either interchangeably; ``advance_to`` refuses to move backwards
    (virtual time is monotone, exactly like the real clock it stands in
    for)."""

    def __init__(self, start: float = 0.0) -> None:
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by {dt} (< 0)")
        self._now += dt
        return self._now

    def advance_to(self, t: float) -> float:
        if t < self._now:
            raise ValueError(f"cannot rewind to {t} (now {self._now})")
        self._now = float(t)
        return self._now


# ---------------------------------------------------------------------------
# Seeded arrival generators (open-loop: arrivals ignore service progress)
# ---------------------------------------------------------------------------

def poisson_arrivals(rate: float, n: int, *, seed: int = 0,
                     start: float = 0.0) -> list[float]:
    """``n`` Poisson-process arrival times at ``rate`` requests/second:
    i.i.d. exponential inter-arrival gaps, cumulatively summed from
    ``start``.  Deterministic per ``seed``."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return [float(t) for t in start + np.cumsum(gaps)]


def burst_arrivals(n_bursts: int, burst_size: int, gap: float, *,
                   jitter: float = 0.0, seed: int = 0,
                   start: float = 0.0) -> list[float]:
    """``n_bursts`` storms of ``burst_size`` near-simultaneous requests,
    ``gap`` seconds apart.  ``jitter`` spreads each burst's requests
    uniformly over ``[0, jitter)`` after the burst instant (0.0 keeps
    them exactly simultaneous — the FIFO-order stress case)."""
    if n_bursts < 0 or burst_size < 0:
        raise ValueError("n_bursts and burst_size must be >= 0")
    if gap < 0 or jitter < 0:
        raise ValueError("gap and jitter must be >= 0")
    rng = np.random.default_rng(seed)
    times: list[float] = []
    for b in range(n_bursts):
        t0 = start + b * gap
        offs = rng.uniform(0.0, jitter, size=burst_size) if jitter \
            else np.zeros(burst_size)
        times.extend(float(t0 + o) for o in np.sort(offs))
    return times


def ramp_arrivals(rate0: float, rate1: float, n: int, *, seed: int = 0,
                  start: float = 0.0) -> list[float]:
    """``n`` arrivals whose instantaneous rate ramps linearly from
    ``rate0`` to ``rate1`` over the trace: the i-th gap is exponential
    at the interpolated rate.  Models a traffic ramp-up (or drain, when
    ``rate1 < rate0``)."""
    if rate0 <= 0 or rate1 <= 0:
        raise ValueError("rates must be > 0")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = np.random.default_rng(seed)
    t, times = float(start), []
    for i in range(n):
        frac = i / max(n - 1, 1)
        rate = rate0 + (rate1 - rate0) * frac
        t += float(rng.exponential(1.0 / rate))
        times.append(t)
    return times


# ---------------------------------------------------------------------------
# Request lifecycle recording
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestRecord:
    """One request's lifecycle timestamps (all on the caller's clock).

    ``t_enqueue`` is stamped at submission, ``t_batch`` when the batcher
    pulled the request into a bucket, ``t_execute`` when its batch
    started executing, ``t_complete`` when the batch's results were
    published.  ``bucket``/``replica`` identify the compiled program and
    replica that served it; ``batch_real`` is how many real (non-pad)
    rows shared the batch."""

    rid: int
    t_enqueue: float
    t_batch: float | None = None
    t_execute: float | None = None
    t_complete: float | None = None
    bucket: int | None = None
    replica: str | None = None
    batch_real: int | None = None

    @property
    def latency(self) -> float:
        """Total enqueue-to-complete latency (the number users feel)."""
        if self.t_complete is None:
            raise ValueError(f"request {self.rid} never completed")
        return self.t_complete - self.t_enqueue

    @property
    def queue_wait(self) -> float:
        """Time spent queued before the batch started executing."""
        if self.t_execute is None:
            raise ValueError(f"request {self.rid} never executed")
        return self.t_execute - self.t_enqueue


class TraceRecorder:
    """Collects :class:`RequestRecord` lifecycles plus queue-depth and
    rejection accounting; aggregates the summary the benchmark emits."""

    def __init__(self) -> None:
        self.records: dict[int, RequestRecord] = {}
        self.rejected: list[tuple[int, float]] = []
        self.max_queue_depth = 0

    # -- lifecycle hooks (called by the engine) -----------------------------

    def enqueue(self, rid: int, t: float) -> RequestRecord:
        if rid in self.records:
            raise ValueError(f"duplicate request id {rid}")
        rec = RequestRecord(rid=rid, t_enqueue=t)
        self.records[rid] = rec
        return rec

    def batch(self, rid: int, t: float, *, bucket: int, replica: str,
              batch_real: int) -> None:
        rec = self.records[rid]
        rec.t_batch, rec.bucket = t, bucket
        rec.replica, rec.batch_real = replica, batch_real

    def execute(self, rid: int, t: float) -> None:
        self.records[rid].t_execute = t

    def complete(self, rid: int, t: float) -> None:
        rec = self.records[rid]
        if rec.t_complete is not None:
            raise ValueError(f"request {rid} completed twice")
        rec.t_complete = t

    def reject(self, rid: int, t: float) -> None:
        self.rejected.append((rid, t))

    def note_queue_depth(self, depth: int) -> None:
        self.max_queue_depth = max(self.max_queue_depth, depth)

    # -- aggregation --------------------------------------------------------

    def completed(self) -> list[RequestRecord]:
        """Completed records in completion order (ties: enqueue order)."""
        done = [r for r in self.records.values() if r.t_complete is not None]
        return sorted(done, key=lambda r: (r.t_complete, r.t_enqueue,
                                           r.rid))

    def latencies(self) -> list[float]:
        return [r.latency for r in self.completed()]

    def percentile(self, p: float) -> float:
        lat = self.latencies()
        if not lat:
            raise ValueError("no completed requests")
        return float(np.percentile(np.asarray(lat), p))

    def summary(self) -> dict:
        """The aggregate the benchmark reports: counts, latency
        percentiles (seconds), open-loop throughput (completions per
        second of timeline between first enqueue and last completion),
        and the per-bucket breakdown."""
        done = self.completed()
        out = {"count": len(done), "rejected": len(self.rejected),
               "max_queue_depth": self.max_queue_depth}
        if not done:
            return out
        lat = np.asarray([r.latency for r in done])
        t0 = min(r.t_enqueue for r in done)
        t1 = max(r.t_complete for r in done)
        span = max(t1 - t0, 1e-12)
        buckets: dict[int, list[float]] = {}
        for r in done:
            buckets.setdefault(int(r.bucket), []).append(r.latency)
        out.update(
            p50_s=float(np.percentile(lat, 50)),
            p99_s=float(np.percentile(lat, 99)),
            mean_s=float(lat.mean()),
            max_s=float(lat.max()),
            throughput_rps=len(done) / span,
            span_s=float(span),
            buckets={b: {"count": len(ls),
                         "p50_s": float(np.percentile(np.asarray(ls), 50)),
                         "p99_s": float(np.percentile(np.asarray(ls), 99))}
                     for b, ls in sorted(buckets.items())})
        return out
