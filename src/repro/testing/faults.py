"""Fault-injection harness for the guarded execution stack (DESIGN.md §9).

Context managers that break one tier (or one persistence path) in a
controlled, reversible way, so the chaos suite
(``tests/test_resilience.py``) can assert that every fallback edge of
``core.guard.run_chain`` still matches the ``ref`` oracle and emits
exactly the expected demotion events:

* :func:`lowering_failure` — the named conv tier raises
  :class:`InjectedFault` instead of lowering/running its kernel.
* :func:`nan_poison` — the named tier computes normally, then corrupts
  one output element to NaN (exercises the ``REPRO_CONV_GUARD=1``
  numerics guard).
* :func:`crash_before_publish` — the atomic-rename publish step of the
  autotune cache / checkpoint manager raises :class:`InjectedCrash`
  *before* the rename, simulating a mid-write process death: the
  published artifact must be untouched (and the next load must still
  see the previous consistent state).
* :func:`corrupt_cache` / :func:`flip_byte` / :func:`truncate_file` —
  on-disk corruption for the quarantine / integrity-verification tests.

Injection is by module-attribute patching of the exact names the
dispatch layer resolves at call time (``repro.kernels.ops.trim_conv2d``,
``...ops.sharded_conv2d``, ``repro.kernels.trim_conv2d_fused.
_fused_forward``) — the ``custom_vjp`` primal bodies look these up as
module globals per call, so a patch is seen without re-importing
anything.  Every manager restores the original attribute on exit and
yields a :class:`FaultHandle` whose ``calls`` counter records how many
times the fault actually fired (the memoized-demotion tests rely on it).

``python -m repro.testing.faults --report out.json`` runs a small conv
problem under each injected fault with the numerics guard on and dumps
``guard.events()`` — the CI chaos step uploads that JSON next to the
benchmark artifacts.
"""

from __future__ import annotations

import contextlib
import importlib
import json
import os

__all__ = [
    "InjectedFault", "InjectedCrash", "FaultHandle", "TIER_TARGETS",
    "PUBLISH_TARGETS", "lowering_failure", "nan_poison",
    "crash_before_publish", "corrupt_cache", "flip_byte", "truncate_file",
]


class InjectedFault(RuntimeError):
    """Raised by an injected kernel-lowering/runtime failure."""


class InjectedCrash(RuntimeError):
    """Raised by an injected mid-write crash (before the atomic rename)."""


class FaultHandle:
    """Returned by the injection context managers; ``calls`` counts how
    many times the injected fault actually fired."""

    def __init__(self) -> None:
        self.calls = 0


#: tier name -> (module, attribute) the dispatch layer resolves per call
TIER_TARGETS = {
    "fused": ("repro.kernels.trim_conv2d_fused", "_fused_forward"),
    "pallas": ("repro.kernels.ops", "trim_conv2d"),
    "sharded": ("repro.kernels.ops", "sharded_conv2d"),
    "q8": ("repro.kernels.ops", "_q8_forward"),
}

#: persistence path -> (module, attribute) of its patchable publish alias
PUBLISH_TARGETS = {
    "autotune": ("repro.core.autotune", "_publish"),
    "checkpoint": ("repro.checkpoint.manager", "_publish"),
}


@contextlib.contextmanager
def _patched(module_name: str, attr: str, make_replacement):
    """Patch ``module.attr`` with ``make_replacement(original)`` for the
    duration of the block; always restore."""
    mod = importlib.import_module(module_name)
    orig = getattr(mod, attr)
    setattr(mod, attr, make_replacement(orig))
    try:
        yield
    finally:
        setattr(mod, attr, orig)


@contextlib.contextmanager
def lowering_failure(tier: str, message: str = "injected lowering failure"):
    """Make the named conv tier (``fused``/``pallas``/``sharded``) raise
    :class:`InjectedFault` on every call."""
    mod, attr = TIER_TARGETS[tier]
    handle = FaultHandle()

    def make(orig):
        def boom(*args, **kwargs):
            handle.calls += 1
            raise InjectedFault(f"{tier}: {message}")
        return boom

    with _patched(mod, attr, make):
        yield handle


@contextlib.contextmanager
def nan_poison(tier: str = "pallas"):
    """Make the named tier compute normally, then poison one output
    element to NaN — detectable only by the ``REPRO_CONV_GUARD=1``
    numerics guard (eager execution)."""
    mod, attr = TIER_TARGETS[tier]
    handle = FaultHandle()

    def make(orig):
        def poisoned(*args, **kwargs):
            import jax.numpy as jnp
            handle.calls += 1
            out = orig(*args, **kwargs)
            return out.at[(0,) * out.ndim].set(jnp.nan)
        return poisoned

    with _patched(mod, attr, make):
        yield handle


@contextlib.contextmanager
def crash_before_publish(target: str):
    """Make the named persistence path (``autotune``/``checkpoint``)
    raise :class:`InjectedCrash` instead of performing its atomic rename:
    the write happened to the temp location, the publish never did."""
    mod, attr = PUBLISH_TARGETS[target]
    handle = FaultHandle()

    def make(orig):
        def crash(*args, **kwargs):
            handle.calls += 1
            raise InjectedCrash(f"{target}: crashed before publish")
        return crash

    with _patched(mod, attr, make):
        yield handle


def corrupt_cache(path: str, mode: str = "truncate") -> None:
    """Corrupt an autotune cache file in place.

    ``truncate``: cut the JSON mid-document; ``garbage``: non-JSON bytes;
    ``wrong_version``: valid JSON with an unknown schema version;
    ``empty``: zero bytes.
    """
    if mode == "truncate":
        with open(path, "r+", encoding="utf-8") as f:
            data = f.read()
            f.seek(0)
            f.write(data[: max(1, len(data) // 2)])
            f.truncate()
    elif mode == "garbage":
        with open(path, "wb") as f:
            f.write(b"\x00not json\xff")
    elif mode == "wrong_version":
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"version": 999, "entries": {}}, f)
    elif mode == "empty":
        with open(path, "wb"):
            pass
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")


def flip_byte(path: str, offset: int = 0) -> None:
    """XOR one byte of ``path`` (bit-flip corruption; offset from the
    middle of the file when the given offset is 0 and the file is big
    enough, so zip/npz headers stay intact and only sha256 catches it)."""
    size = os.path.getsize(path)
    if offset == 0 and size > 256:
        offset = size // 2
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def truncate_file(path: str, frac: float = 0.5) -> None:
    """Truncate ``path`` to ``frac`` of its size."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(0, int(size * frac)))


def _demo_report(out_path: str) -> None:
    """Run a small conv under injected faults with the numerics guard on
    and dump ``guard.events()`` — the CI chaos artifact."""
    os.environ.setdefault("REPRO_CONV_GUARD", "1")
    import numpy as np
    import jax.numpy as jnp
    from repro.core import guard
    from repro.kernels import ops, ref

    guard.reset()
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1, 16, 16, 8), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 8, 16), jnp.float32)
    oracle = ref.conv2d(x, w, activation="relu")

    with lowering_failure("pallas"):
        y = ops.conv2d(x, w, activation="relu", layer="demo-lowering")
    lowering_ok = bool(np.allclose(np.asarray(y), np.asarray(oracle),
                                   atol=1e-5))
    events = guard.events()

    guard.reset()        # forget the memo so the pallas tier runs again
    with nan_poison("pallas"):
        y2 = ops.conv2d(x, w, activation="relu", layer="demo-numerics")
    numerics_ok = bool(np.allclose(np.asarray(y2), np.asarray(oracle),
                                   atol=1e-5))
    events += guard.events()

    payload = {
        "guard_env": os.environ.get(guard.GUARD_ENV),
        "lowering_demotion_matches_ref": lowering_ok,
        "numerics_demotion_matches_ref": numerics_ok,
        "events": events,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_path}: {len(payload['events'])} events, "
          f"lowering_ok={lowering_ok} numerics_ok={numerics_ok}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--report", required=True,
                    help="write guard.events() JSON after a demo fault run")
    _demo_report(ap.parse_args().report)
