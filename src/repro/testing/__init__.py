"""Test-support utilities: the fault-injection harness (DESIGN.md §9).

Import the harness as ``from repro.testing import faults`` — the package
itself stays empty so ``python -m repro.testing.faults`` (the CI
guard-event demo) does not double-import the module.
"""
