"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16, mamba1 architecture.  [arXiv:2410.05355; unverified]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "falcon-mamba-7b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="ssm", n_layers=64, d_model=4096, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab=65024, ssm_state=16, d_conv=4,
    dt_rank=256, expand=2, scan_chunk=256)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab=128, ssm_state=8, dt_rank=8,
    scan_chunk=16, remat=False)

# attention-free: sub-quadratic — long_500k runs (state-space decode)
PLANS = default_plans(sub_quadratic=True, overrides={
    "train_4k": dict(n_micro=16),
})
