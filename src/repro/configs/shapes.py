"""Assigned input-shape cells and per-(arch, shape) execution plans."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ShapePlan:
    """One (architecture x input-shape) dry-run cell."""

    shape: str                  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    batch: int
    seq: int
    n_micro: int = 1
    fsdp: bool = False
    moment_dtype: str = "float32"
    accum_dtype: str = "float32"
    rules_overrides: dict = field(default_factory=dict)
    skip: str | None = None     # reason, for documented skips

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


TRAIN_4K = ShapePlan("train_4k", "train", batch=256, seq=4096)
PREFILL_32K = ShapePlan("prefill_32k", "prefill", batch=32, seq=32768)
DECODE_32K = ShapePlan("decode_32k", "decode", batch=128, seq=32768)
LONG_500K = ShapePlan("long_500k", "decode", batch=1, seq=524288)

FULL_ATTN_SKIP = ("pure full-attention stack: 524k-token decode requires "
                  "sub-quadratic attention (and its KV cache exceeds any "
                  "per-chip HBM at this batch); see DESIGN.md §5")


def default_plans(*, sub_quadratic: bool = False,
                  overrides: dict | None = None) -> dict:
    """The four assigned cells, with the long_500k skip rule applied."""
    plans = {
        "train_4k": TRAIN_4K,
        "prefill_32k": PREFILL_32K,
        "decode_32k": DECODE_32K,
        "long_500k": LONG_500K if sub_quadratic
        else LONG_500K.replace(skip=FULL_ATTN_SKIP),
    }
    for name, kw in (overrides or {}).items():
        plans[name] = plans[name].replace(**kw)
    return plans
