"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention (window 2048), pattern
(rec, rec, att) = 1:2 attention:recurrent.  [arXiv:2402.19427; hf]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "recurrentgemma-2b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="hybrid", n_layers=26, d_model=2560, n_heads=10,
    n_kv_heads=1, head_dim=256, d_ff=7680, vocab=256000, mlp="geglu",
    window=2048, block_pattern=("rec", "rec", "att"), lru_width=2560,
    logits_soft_cap=30.0, rope_theta=1e4,
    tie_embeddings=True)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=192, lru_width=64, vocab=128, window=8, attn_impl="ref",
    remat=False)

# RG-LRU + windowed attention: sub-quadratic — long_500k runs
PLANS = default_plans(sub_quadratic=True, overrides={
    "train_4k": dict(n_micro=4),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
