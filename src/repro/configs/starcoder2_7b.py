"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE, LayerNorm+bias, GeLU MLP.  [arXiv:2402.19173; hf]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "starcoder2-7b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense", n_layers=32, d_model=4608, n_heads=36,
    n_kv_heads=4, head_dim=128, d_ff=18432, vocab=49152, qkv_bias=True,
    norm="layernorm", mlp="gelu", rope_theta=1e5)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=2, head_dim=12,
    d_ff=288, vocab=128, attn_impl="ref", remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=8, fsdp=True),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
