"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "starcoder2-3b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense", n_layers=30, d_model=3072, n_heads=24,
    n_kv_heads=2, head_dim=128, d_ff=12288, vocab=49152, qkv_bias=True,
    norm="layernorm", mlp="gelu", rope_theta=1e5)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
    d_ff=192, vocab=128, attn_impl="ref", remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=8),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
