"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling.  The vision frontend is a STUB per the
assignment: input_specs() provides precomputed patch embeddings
(anyres: 1 base tile + 2x2 grid of 336px tiles @ 14px patches = 2880
tokens).  [hf:llava-hf/llava-v1.6-34b-hf; unverified]
"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "llava-next-34b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense", n_layers=60, d_model=7168, n_heads=56,
    n_kv_heads=8, head_dim=128, d_ff=20480, vocab=64000, rope_theta=5e6,
    frontend="vision", n_frontend_tokens=2880)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, head_dim=14,
    d_ff=224, vocab=128, n_frontend_tokens=8, attn_impl="ref", remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=8, fsdp=True),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
