"""The paper's own workload: VGG-16 / AlexNet CNN inference through the
3D-TrIM conv dataflow (kernels/trim_conv2d).  Not part of the 10-arch LM
dry-run matrix; used by benchmarks/ and examples/cnn_inference.py."""

ARCH_ID = "trim-cnn"

from repro.core.model import alexnet_layers, vgg16_layers  # noqa: F401
