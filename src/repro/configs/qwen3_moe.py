"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
(per expert) vocab=151936, MoE 128 experts top-8.  head_dim=128 per the HF
config (q/k projections are 32*128 > d_model).  [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-moe-30b-a3b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="moe", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, head_dim=128, d_ff=768, moe_dff=768, n_experts=128,
    top_k=8, vocab=151936, rope_theta=1e6)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=48, moe_dff=48, n_experts=8, top_k=2, vocab=128, attn_impl="ref",
    remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=16, fsdp=True),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
