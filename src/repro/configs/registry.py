"""Architecture registry: ``--arch <id>`` resolution, abstract input specs
per shape cell, numeric parameter counts and MODEL_FLOPS."""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapePlan
from repro.models import api
from repro.models.base import Param
from repro.models.config import ModelConfig

_MODULES = [
    "phi35_moe", "qwen3_moe", "falcon_mamba", "starcoder2_7b",
    "starcoder2_3b", "llama3_405b", "qwen25_3b", "llava_next_34b",
    "seamless_m4t", "recurrentgemma_2b",
]


def _load():
    table = {}
    for m in _MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        table[mod.ARCH_ID] = mod
    return table


_TABLE = None


def archs() -> list[str]:
    global _TABLE
    if _TABLE is None:
        _TABLE = _load()
    return list(_TABLE)


def get(arch_id: str):
    global _TABLE
    if _TABLE is None:
        _TABLE = _load()
    if arch_id not in _TABLE:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_TABLE)}")
    return _TABLE[arch_id]


# ---------------------------------------------------------------------------
# Numeric parameter counts (from the Param declaration tree)
# ---------------------------------------------------------------------------

def _size(p: Param) -> int:
    n = 1
    for s in p.shape:
        n *= s
    return n


def count_params(cfg: ModelConfig) -> int:
    tree = api.params(cfg)
    return sum(_size(p) for p in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, Param)))


def count_active_params(cfg: ModelConfig) -> int:
    """Active params per token: expert tensors scaled by top_k/n_experts."""
    tree = api.params(cfg)
    total = 0
    for p in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, Param)):
        n = _size(p)
        if "experts" in p.axes and len(p.shape) >= 3:
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        total += n
    return total


def _subtree_count(tree) -> int:
    return sum(_size(p) for p in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, Param)))


# ---------------------------------------------------------------------------
# Input specs per shape cell (pure ShapeDtypeStructs, no sharding)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, plan: ShapePlan) -> dict:
    b, s = plan.batch, plan.seq
    i32 = jnp.int32
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    sds = jax.ShapeDtypeStruct
    if plan.kind == "decode":
        return {"tokens": sds((b, 1), i32), "cache_len": sds((b,), i32)}
    nv = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    text = s - nv
    batch = {"tokens": sds((b, text), i32)}
    if plan.kind == "train":
        batch["labels"] = sds((b, text), i32)
    if cfg.frontend == "vision":
        batch["vision"] = sds((b, nv, cfg.d_model), act)
    if cfg.family == "encdec":
        batch["src"] = sds((b, s, cfg.d_model), act)
    return batch


def model_flops(cfg: ModelConfig, plan: ShapePlan) -> float:
    """MODEL_FLOPS per step: 6*N*D train, 2*N*D inference (active params)."""
    n = count_active_params(cfg)
    if cfg.family == "encdec":
        tree = api.params(cfg)
        n_enc = _subtree_count(tree["enc_blocks"])
        n_dec = _subtree_count(tree["dec_blocks"])
        n_emb = _subtree_count(tree["tok"])
        if plan.kind == "train":
            return 6.0 * plan.batch * plan.seq * (n_enc + n_dec + n_emb)
        if plan.kind == "prefill":
            return 2.0 * plan.batch * plan.seq * (n_enc + n_dec + n_emb)
        return 2.0 * plan.batch * (n_dec + n_emb)
    tokens = plan.batch * (plan.seq if plan.kind != "decode" else 1)
    mult = 6.0 if plan.kind == "train" else 2.0
    return mult * n * tokens
