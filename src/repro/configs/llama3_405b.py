"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab.  [arXiv:2407.21783; unverified]

Memory plan (per v5e chip, 16 GiB): FSDP (params+grads+moments sharded
over data x model = 256 ways) + bf16 moments + 16 microbatches + Megatron
sequence parallelism for the saved layer-boundary activations.
"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "llama3-405b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense", n_layers=126, d_model=16384, n_heads=128,
    n_kv_heads=8, head_dim=128, d_ff=53248, vocab=128256, rope_theta=5e5)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=208, vocab=128, attn_impl="ref", remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=16, fsdp=True, moment_dtype="bfloat16",
                     accum_dtype="bfloat16",
                     rules_overrides={"seq": "model"}),
    "prefill_32k": dict(fsdp=True),
    "decode_32k": dict(fsdp=True, rules_overrides={"seq": "model"}),
})
