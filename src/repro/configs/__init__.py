from repro.configs import registry  # noqa: F401
from repro.configs.shapes import ShapePlan  # noqa: F401
