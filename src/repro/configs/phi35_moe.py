"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="moe", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, head_dim=128, d_ff=6400, moe_dff=6400, n_experts=16,
    top_k=2, vocab=32064, rope_theta=1e4, norm="layernorm")

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, moe_dff=96, n_experts=4, top_k=2, vocab=128, attn_impl="ref",
    remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=16, fsdp=True),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
