"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16 -> MHA)
d_ff=8192 vocab=256206 — encoder-decoder, multimodal.

Interpretation: "24L" = 24 encoder + 24 decoder layers (matching the HF
text encoder/decoder of seamless-m4t-v2-large).  The speech frontend is a
STUB: input_specs() provides precomputed frame embeddings (B, L, d_model).
[arXiv:2308.11596; hf]
"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "seamless-m4t-large-v2"

CONFIG = ModelConfig(
    name=ARCH_ID, family="encdec", n_layers=48, enc_layers=24,
    dec_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, norm="layernorm", mlp="gelu",
    frontend="audio")

SMOKE = CONFIG.replace(
    n_layers=4, enc_layers=2, dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=128, attn_impl="ref",
    remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=4),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
