"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-3B; hf]"""

from repro.configs.shapes import default_plans
from repro.models.config import ModelConfig

ARCH_ID = "qwen2.5-3b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense", n_layers=36, d_model=2048, n_heads=16,
    n_kv_heads=2, head_dim=128, d_ff=11008, vocab=151936, qkv_bias=True,
    rope_theta=1e6)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=128, attn_impl="ref", remat=False)

PLANS = default_plans(overrides={
    "train_4k": dict(n_micro=8),
    "decode_32k": dict(rules_overrides={"seq": "model"}),
})
