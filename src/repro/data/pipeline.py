"""Deterministic, exactly-resumable synthetic data pipeline.

Every batch is a pure function of (seed, step), so the iterator state in a
checkpoint is just those two integers — a restart (even on a different
mesh) replays the stream with no gaps or repeats.  Tasks:

  * ``lm``    — uniform random tokens (throughput/dry-run work).
  * ``copy``  — second half of each sequence repeats the first half; a
    learnable task so examples/train_lm.py shows a falling loss.
  * ``arith`` — t_{i+1} = (t_i + t_{i-1}) mod vocab after a random prefix.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch: int = 8
    seq: int = 128
    vocab: int = 256
    task: str = "copy"
    seed: int = 0


class SyntheticStream:
    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step,
                "task": self.cfg.task}

    @staticmethod
    def from_state(cfg: DataConfig, state: dict) -> "SyntheticStream":
        return SyntheticStream(cfg, step=int(state["step"]))

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = make_batch(self.cfg, self.step)
        self.step += 1
        return batch


def make_batch(cfg: DataConfig, step: int) -> dict:
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))
    b, s, v = cfg.batch, cfg.seq, cfg.vocab
    if cfg.task == "copy":
        half = s // 2
        first = rng.integers(2, v, size=(b, half))
        toks = np.concatenate([first, first], axis=1)[:, :s]
    elif cfg.task == "arith":
        toks = rng.integers(2, v, size=(b, s))
        for i in range(2, s):
            toks[:, i] = (toks[:, i - 1] + toks[:, i - 2]) % v
    else:
        toks = rng.integers(0, v, size=(b, s))
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}
