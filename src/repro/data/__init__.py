from repro.data.pipeline import DataConfig, SyntheticStream, make_batch  # noqa: F401
