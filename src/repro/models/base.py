"""Minimal functional parameter framework with logical sharding axes.

Parameters are declared as ``Param`` leaves in nested dicts.  The same
declaration tree serves three consumers:

  * smoke tests     — ``init_params`` materializes real arrays;
  * the dry-run     — ``abstract_params`` builds ShapeDtypeStructs with
                      NamedShardings, no allocation;
  * the train step  — ``param_pspecs`` yields the PartitionSpec tree for
                      in/out shardings.

Logical axis names are resolved to mesh axes by the rules in
``repro.distributed.sharding``; an axis whose size does not divide the
mesh extent falls back to replication.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Param:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis per dim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Any = None                     # overrides the model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_params(tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Add a leading stacked-layers dim to every Param (for lax.scan)."""
    def f(p: Param) -> Param:
        return Param(shape=(n, *p.shape), axes=(axis_name, *p.axes),
                     init=p.init, scale=p.scale, dtype=p.dtype)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Param))


def init_params(tree: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Materialize real arrays (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, Param))
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        dt = p.dtype or dtype
        if p.init == "zeros":
            v = jnp.zeros(p.shape, dt)
        elif p.init == "ones":
            v = jnp.ones(p.shape, dt)
        else:
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            std = p.scale / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(k, p.shape, jnp.float32) * std).astype(dt)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree: Any, mesh, rules: dict, dtype=jnp.float32) -> Any:
    """ShapeDtypeStructs with NamedShardings — for .lower() without alloc."""
    from jax.sharding import NamedSharding

    def f(p: Param):
        spec = resolve_spec(p.shape, p.axes, mesh, rules)
        return jax.ShapeDtypeStruct(p.shape, p.dtype or dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Param))


def param_pspecs(tree: Any, mesh, rules: dict) -> Any:
    from jax.sharding import PartitionSpec
    def f(p: Param) -> PartitionSpec:
        return resolve_spec(p.shape, p.axes, mesh, rules)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, Param))


def resolve_spec(shape, axes, mesh, rules):
    """Logical axes -> PartitionSpec with divisibility fallback."""
    from jax.sharding import PartitionSpec
    used: set = set()
    entries = []
    for size, name in zip(shape, axes):
        mesh_axes = rules.get(name) if name else None
        if mesh_axes is None:
            entries.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        # drop axes already used by another dim or not dividing the size
        valid = []
        extent = 1
        for ax in mesh_axes:
            if ax in used or ax not in mesh.shape:
                continue
            if size % (extent * mesh.shape[ax]) == 0:
                valid.append(ax)
                extent *= mesh.shape[ax]
        if not valid:
            entries.append(None)
        else:
            used.update(valid)
            entries.append(tuple(valid) if len(valid) > 1 else valid[0])
    return PartitionSpec(*entries)


def tree_bytes_per_dev(tree: Any, mesh, rules, default_bytes: int = 2
                       ) -> float:
    """Per-device resident bytes of a Param tree under the given rules."""
    total = 0.0
    for p in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, Param)):
        spec = resolve_spec(p.shape, p.axes, mesh, rules)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in ((entry,) if isinstance(entry, str) else entry):
                shards *= mesh.shape[ax]
        nbytes = default_bytes
        if p.dtype is not None:
            nbytes = jnp.dtype(p.dtype).itemsize
        size = 1
        for s in p.shape:
            size *= s
        total += size * nbytes / shards
    return total


def shard_activation(x: jax.Array, axes: tuple, rules: dict, mesh=None):
    """with_sharding_constraint by logical activation axes (inside jit)."""
    from jax.sharding import NamedSharding
    from jax._src.mesh import thread_resources
    mesh = mesh or thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return x
    spec = resolve_spec(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
