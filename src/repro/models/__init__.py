from repro.models import api, layers, transformer, mamba, rglru  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
