"""Mamba-1 (falcon-mamba-7b family): selective SSM with causal conv1d.

The temporal conv uses the ``trim_conv1d`` dataflow (Pallas on TPU; the
jnp oracle under jit elsewhere).  The selective scan is evaluated with a
*chunked associative scan*: the sequence is split into chunks; within a
chunk a log-depth ``jax.lax.associative_scan`` runs (flop-countable, no
while loop); the (B, D_inner, S) boundary state is carried across chunks.
This is the TPU-friendly image of the CUDA selective-scan kernel: the
(B, L, D_inner, S) tensor is only ever materialized one chunk at a time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models.base import Param, shard_activation, stack_params
from repro.models.config import ModelConfig


def mixer_params(cfg: ModelConfig) -> dict:
    d, din, s, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return {
        "w_in": Param((d, 2 * din), ("embed", "mlp")),
        "conv_w": Param((cfg.d_conv, din), (None, "mlp"), scale=0.5),
        "conv_b": Param((din,), ("mlp",), init="zeros"),
        "w_x": Param((din, r + 2 * s), ("mlp", None)),
        "w_dt": Param((r, din), (None, "mlp")),
        "dt_bias": Param((din,), ("mlp",), init="zeros"),
        "a_log": Param((din, s), ("mlp", None), init="ones"),
        "d_skip": Param((din,), ("mlp",), init="ones"),
        "w_out": Param((din, d), ("mlp", "embed")),
    }


def _scan_chunk(a, bx, h0):
    """Associative scan within one chunk.  a, bx: (B, C, Din, S)."""
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2
    a_cum, h_local = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = h_local + a_cum * h0[:, None]
    return h, h[:, -1]


def ssm_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict,
              h0: jax.Array | None = None):
    """Selective scan.  x: (B, L, Din) post-conv/SiLU activations.

    Returns (y (B, L, Din), h_last (B, Din, S)).
    """
    b, length, din = x.shape
    s = cfg.ssm_state
    x_dbl = x @ p["w_x"]
    dt, bmat, cmat = jnp.split(x_dbl, [cfg.dt_rank, cfg.dt_rank + s], axis=-1)
    dt = jax.nn.softplus(dt @ p["w_dt"] + p["dt_bias"])       # (B, L, Din)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # (Din, S)

    if h0 is None:
        h0 = jnp.zeros((b, din, s), jnp.float32)
    chunk = min(cfg.scan_chunk, length)
    n_chunks = -(-length // chunk)
    pad = n_chunks * chunk - length

    def one_chunk(h0, dt_c, x_c, b_c, c_c):
        a_bar = jnp.exp(dt_c[..., None] * a)                  # (B,C,Din,S)
        bx = dt_c[..., None] * b_c[:, :, None, :] * x_c[..., None]
        h, h_last = _scan_chunk(a_bar, bx, h0)
        return jnp.einsum("bcds,bcs->bcd", h, c_c), h_last

    if cfg.unroll_layers:
        # Δ-cost mode: Python loop so HloCostAnalysis sees every chunk
        ys = []
        for ic in range(n_chunks):
            sl = slice(ic * chunk, min((ic + 1) * chunk, length))
            y_c, h0 = one_chunk(h0, dt[:, sl].astype(jnp.float32),
                                x[:, sl].astype(jnp.float32),
                                bmat[:, sl].astype(jnp.float32),
                                cmat[:, sl].astype(jnp.float32))
            ys.append(y_c)
        y = jnp.concatenate(ys, axis=1).astype(x.dtype)
    else:
        def resh(t):
            tp = jnp.pad(t.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
            return tp.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)

        def body(carry, xs):
            y_c, h_last = jax.checkpoint(one_chunk)(carry, *xs)
            return h_last, y_c

        h0, ys = jax.lax.scan(body, h0, (resh(dt), resh(x),
                                         resh(bmat), resh(cmat)))
        y = ys.swapaxes(0, 1).reshape(b, n_chunks * chunk, din)
        y = y[:, :length].astype(x.dtype)
    y = y + x * p["d_skip"]
    return y, h0


def mixer_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict, *,
                state=None):
    """Full mamba mixer.  state=(conv_state, ssm_state) enables decode mode
    (L == 1); returns (y, new_state)."""
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard_activation(xin, ("batch", None, "mlp"), rules)
    if state is None:
        xc = ops.depthwise_conv1d(xin, p["conv_w"], impl="ref") + p["conv_b"]
        xc = jax.nn.silu(xc)
        y, h_last = ssm_apply(p, xc, cfg, rules)
        new_state = None
    else:
        conv_state, h0 = state
        conv_state, xc = ops.depthwise_conv1d_step(
            conv_state, xin[:, 0], p["conv_w"])
        xc = jax.nn.silu(xc + p["conv_b"])[:, None]
        y, h_last = ssm_apply(p, xc, cfg, rules, h0=h0)
        new_state = (conv_state, h_last)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    return shard_activation(out, ("batch", "seq", "act_embed"), rules), \
        new_state


def block_params(cfg: ModelConfig) -> dict:
    return {"ln": L.norm_params(cfg), "mixer": mixer_params(cfg)}


def lm_params(cfg: ModelConfig) -> dict:
    return {
        "tok": L.embedding_params(cfg),
        "blocks": stack_params(block_params(cfg), cfg.n_layers),
        "ln_f": L.norm_params(cfg),
    }


def make_state(cfg: ModelConfig, batch: int):
    """Decode state per layer (stacked): conv window + SSM state."""
    return {
        "conv": Param((cfg.n_layers, batch, cfg.d_conv - 1, cfg.d_inner),
                      ("layers", "batch", None, "mlp"), init="zeros"),
        "ssm": Param((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                     ("layers", "batch", "mlp", None), init="zeros",
                     dtype=jnp.float32),
    }


def lm_apply(params: dict, tokens: jax.Array, cfg: ModelConfig, rules: dict,
             *, state=None, cache_len=None):
    """tokens (B, S) -> logits.  ``state`` enables one-token decode."""
    x = L.embed_apply(params["tok"], tokens, cfg, rules)

    def one(pi, x, st):
        y, new_st = mixer_apply(pi["mixer"], L.norm_apply(pi["ln"], x, cfg),
                                cfg, rules, state=st)
        return x + y, new_st

    if cfg.remat:
        one = jax.checkpoint(one,
                             policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll_layers or state is not None:
        new_conv, new_ssm = [], []
        for i in range(cfg.n_layers):
            pi = jax.tree.map(lambda a: a[i], params["blocks"])
            st = None if state is None else \
                (state["conv"][i], state["ssm"][i])
            x, nst = one(pi, x, st)
            if nst is not None:
                new_conv.append(nst[0])
                new_ssm.append(nst[1])
        new_state = None
        if new_conv:
            new_state = {"conv": jnp.stack(new_conv),
                         "ssm": jnp.stack(new_ssm)}
    else:
        def body(x, pi):
            x, _ = one(pi, x, None)
            return x, None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        new_state = None

    x = L.norm_apply(params["ln_f"], x, cfg)
    logits = L.head_apply(params["tok"], x, cfg, rules)
    return logits, new_state, 0.0
