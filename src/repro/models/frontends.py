"""Modality frontend stubs (per assignment: [vlm]/[audio] entries specify
the transformer BACKBONE only; the frontend provides precomputed patch /
frame embeddings through ``input_specs()``).

``reference_vision_stem`` is a *demonstration* patch-embed stem built on
the trim_conv2d kernel — used by examples/cnn_inference.py, not by the
dry-run path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def reference_vision_stem(images: jax.Array, patch_w: jax.Array,
                          impl: str = "pallas") -> jax.Array:
    """images: (N, H, W, 3); patch_w: (P, P, 3, D) -> (N, (H/P)*(W/P), D).

    A patch-embed is a stride-P conv — the trim_conv2d kernel handles it
    (non-overlapping windows: the carry path is simply never warm).
    """
    p = patch_w.shape[0]
    feat = ops.conv2d(images, patch_w, stride=p, padding="valid", impl=impl)
    n, hp, wp, d = feat.shape
    return feat.reshape(n, hp * wp, d)


def anyres_tile_count(image_hw: tuple[int, int], tile: int = 336,
                      patch: int = 14) -> int:
    """LLaVA-NeXT anyres: number of vision tokens for an image resolution
    (base tile + grid tiles), used to size input_specs."""
    h, w = image_hw
    grid = (-(-h // tile)) * (-(-w // tile))
    per_tile = (tile // patch) ** 2
    return (1 + grid) * per_tile
