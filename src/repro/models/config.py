"""Model configuration shared by every architecture family."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"      # dense | moe | ssm | hybrid | encdec
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int | None = None
    qkv_bias: bool = False
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    mlp: str = "swiglu"        # swiglu | gelu
    rope_theta: float = 1e4
    logits_soft_cap: float | None = None
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    capacity_factor: float = 1.25
    shared_expert_dff: int = 0     # dense expert alongside routed ones

    # SSM (mamba1)
    ssm_state: int = 0
    d_conv: int = 4
    dt_rank: int = 0
    expand: int = 2
    scan_chunk: int = 256

    # hybrid (RG-LRU)
    window: int | None = None      # local attention window
    block_pattern: tuple = ()      # e.g. ("rec", "rec", "att")
    lru_width: int = 0

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0

    # modality frontend stub
    frontend: str | None = None    # vision | audio
    n_frontend_tokens: int = 0

    # execution knobs
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"     # chunked | chunked_unroll | ref | pallas
    attn_chunk: int = 1024
    remat: bool = True
    unroll_layers: bool = False    # True for dry-run Δ-cost compiles
    moe_impl: str = "gmm"          # gmm (capacity-grouped matmul)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            din, s, r = self.d_inner, self.ssm_state, self.dt_rank
            per = (d * 2 * din + self.d_conv * din + din * (r + 2 * s)
                   + r * din + din * s + din + din * d)
            return self.n_layers * per + emb
        att = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * d
        if self.family == "moe":
            ffn = self.n_experts * 3 * d * self.moe_dff + d * self.n_experts \
                + 3 * d * self.shared_expert_dff
        elif self.mlp == "swiglu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.family == "hybrid":
            n_att = sum(1 for i in range(self.n_layers)
                        if self.pattern_at(i) == "att")
            n_rec = self.n_layers - n_att
            w = self.lru_width or d
            rec = 2 * d * w + w * d + self.d_conv * w + 3 * w * w // 1 \
                + 2 * w   # lru gates (block-diagonal approximated dense/8)
            return n_att * (att + ffn) + n_rec * (rec + ffn) + emb
        if self.family == "encdec":
            enc = self.enc_layers * (att + ffn)
            dec = self.dec_layers * (2 * att + ffn)   # self + cross
            return enc + dec + emb
        return self.n_layers * (att + ffn) + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * self.moe_dff)
        return dense + self.n_layers * (self.top_k * 3 * d * self.moe_dff)

    def pattern_at(self, i: int) -> str:
        if not self.block_pattern:
            return "att"
        return self.block_pattern[i % len(self.block_pattern)]
