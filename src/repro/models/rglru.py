"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

Block pattern (cfg.block_pattern), e.g. ("rec", "rec", "att") for the 1:2
attention:recurrent ratio.  The recurrent mixer is: linear branch + GeLU
gate branch, temporal conv (trim_conv1d dataflow), RG-LRU diagonal
recurrence evaluated with a single associative scan (state is (B, L, W) —
no state dimension, so no chunking is needed), gated output projection.

Local attention layers use a ring-buffer KV cache bounded by the window,
which is what makes the 500k-token decode cell feasible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models.base import Param, shard_activation
from repro.models.config import ModelConfig

_C = 8.0  # RG-LRU constant


def rec_mixer_params(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "w_x": Param((d, w), ("embed", "mlp")),
        "w_gate": Param((d, w), ("embed", "mlp")),
        "conv_w": Param((cfg.d_conv, w), (None, "mlp"), scale=0.5),
        "conv_b": Param((w,), ("mlp",), init="zeros"),
        "w_a": Param((w, w), ("mlp", None), scale=0.1),
        "b_a": Param((w,), ("mlp",), init="zeros"),
        "w_i": Param((w, w), ("mlp", None), scale=0.1),
        "b_i": Param((w,), ("mlp",), init="zeros"),
        "lam": Param((w,), ("mlp",), init="ones"),
        "w_out": Param((w, d), ("mlp", "embed")),
    }


def _rg_lru(xb, r, i, lam, h0=None):
    """h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)."""
    log_a = -_C * jax.nn.softplus(lam) * r                    # (B, L, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * xb)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h + a_cum * h0[:, None]
    return h, h[:, -1]


def rec_mixer_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict, *,
                    state=None):
    """state=(conv_state, h) -> decode mode.  Returns (y, new_state)."""
    xb = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    xb = shard_activation(xb, ("batch", None, "mlp"), rules)
    if state is None:
        xb = ops.depthwise_conv1d(xb, p["conv_w"], impl="ref") + p["conv_b"]
        h0 = None
        new_conv = None
    else:
        conv_state, h0 = state
        new_conv, xb1 = ops.depthwise_conv1d_step(conv_state, xb[:, 0],
                                                  p["conv_w"])
        xb = (xb1 + p["conv_b"])[:, None]
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    h, h_last = _rg_lru(xf, r, i, p["lam"].astype(jnp.float32), h0=h0)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    y = shard_activation(y, ("batch", "seq", "act_embed"), rules)
    return y, (None if state is None else (new_conv, h_last))


def block_params(cfg: ModelConfig, kind: str) -> dict:
    p = {"ln_mix": L.norm_params(cfg), "ln_mlp": L.norm_params(cfg),
         "mlp": L.mlp_params(cfg)}
    if kind == "att":
        p["att"] = L.attention_params(cfg)
    else:
        p["rec"] = rec_mixer_params(cfg)
    return p


def lm_params(cfg: ModelConfig) -> dict:
    blocks = {f"layer_{i}": block_params(cfg, cfg.pattern_at(i))
              for i in range(cfg.n_layers)}
    return {"tok": L.embedding_params(cfg), "blocks": blocks,
            "ln_f": L.norm_params(cfg)}


def make_state(cfg: ModelConfig, batch: int):
    """Per-layer decode state: ring KV cache (att) or conv+LRU (rec)."""
    w = cfg.lru_width or cfg.d_model
    win = cfg.window
    state = {}
    for i in range(cfg.n_layers):
        if cfg.pattern_at(i) == "att":
            state[f"layer_{i}"] = {
                "k": Param((batch, win, cfg.n_kv_heads, cfg.hd),
                           ("batch", None, "kv_heads", None), init="zeros"),
                "v": Param((batch, win, cfg.n_kv_heads, cfg.hd),
                           ("batch", None, "kv_heads", None), init="zeros"),
            }
        else:
            state[f"layer_{i}"] = {
                "conv": Param((batch, cfg.d_conv - 1, w),
                              ("batch", None, "mlp"), init="zeros"),
                "h": Param((batch, w), ("batch", "mlp"), init="zeros",
                           dtype=jnp.float32),
            }
    return state


def lm_apply(params: dict, tokens: jax.Array, cfg: ModelConfig, rules: dict,
             *, state=None, cache_len=None):
    x = L.embed_apply(params["tok"], tokens, cfg, rules)
    if cache_len is not None:
        positions = jnp.reshape(cache_len, (-1, 1)) - 1
    else:
        positions = jnp.arange(x.shape[1])[None]
    new_state = {} if state is not None else None

    def att_layer(pi, x, st):
        h = L.norm_apply(pi["ln_mix"], x, cfg)
        if st is None:
            y, _ = L.attention_apply(pi["att"], h, cfg, rules,
                                     positions=positions, causal=True,
                                     window=cfg.window)
            nst = None
        else:
            # ring-buffer insert at (pos - 1) mod window; attention over the
            # valid prefix min(pos, window) — permutation-invariant in keys.
            pos = jnp.max(cache_len)
            q = jnp.einsum("bld,dhk->blhk", h, pi["att"]["wq"])
            k = jnp.einsum("bld,dhk->blhk", h, pi["att"]["wk"])
            v = jnp.einsum("bld,dhk->blhk", h, pi["att"]["wv"])
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            slot = (pos - 1) % cfg.window
            kc = jax.lax.dynamic_update_slice_in_dim(st["k"], k, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(st["v"], v, slot, axis=1)
            o = ops.decode_attention(q, kc, vc,
                                     jnp.minimum(cache_len, cfg.window),
                                     soft_cap=cfg.logits_soft_cap)
            y = jnp.einsum("blhk,hkd->bld", o, pi["att"]["wo"])
            nst = {"k": kc, "v": vc}
        x = x + y
        h = L.mlp_apply(pi["mlp"], L.norm_apply(pi["ln_mlp"], x, cfg),
                        cfg, rules)
        return x + h, nst

    def rec_layer(pi, x, st):
        h = L.norm_apply(pi["ln_mix"], x, cfg)
        y, nst = rec_mixer_apply(pi["rec"], h, cfg, rules,
                                 state=None if st is None else
                                 (st["conv"], st["h"]))
        x = x + y
        h = L.mlp_apply(pi["mlp"], L.norm_apply(pi["ln_mlp"], x, cfg),
                        cfg, rules)
        nst_d = None if nst is None else {"conv": nst[0], "h": nst[1]}
        return x + h, nst_d

    for i in range(cfg.n_layers):
        pi = params["blocks"][f"layer_{i}"]
        st = None if state is None else state[f"layer_{i}"]
        fn = att_layer if cfg.pattern_at(i) == "att" else rec_layer
        if cfg.remat and state is None:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        x, nst = fn(pi, x, st)
        if new_state is not None:
            new_state[f"layer_{i}"] = nst

    x = L.norm_apply(params["ln_f"], x, cfg)
    logits = L.head_apply(params["tok"], x, cfg, rules)
    if cfg.logits_soft_cap:
        logits = cfg.logits_soft_cap * jnp.tanh(logits / cfg.logits_soft_cap)
    return logits, new_state, 0.0
