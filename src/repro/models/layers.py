"""Shared transformer layers: norms, RoPE, GQA attention, MLP, MoE.

Every ``*_params`` function returns a tree of ``Param`` declarations with
logical sharding axes; every ``*_apply`` function is pure and consumes the
materialized (or abstract) tree.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import guard
from repro.kernels import ops
from repro.models.base import Param, shard_activation
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_params(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": Param((d,), ("act_embed",), init="ones",
                        dtype=jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = Param((d,), ("act_embed",), init="zeros",
                          dtype=jnp.float32)
    return p


def norm_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, L, H, D); positions: (B, L) or (L,)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, L, D/2)
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (self- or cross-), with optional KV cache
# ---------------------------------------------------------------------------

def attention_params(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": Param((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Param((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Param((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Param((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Param((h, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = Param((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = Param((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return p


def attention_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict, *,
                    positions: jax.Array | None = None,
                    kv_cache: tuple | None = None,
                    cache_len=None,
                    causal: bool = True,
                    window: int | None = None,
                    encoder_out: jax.Array | None = None,
                    is_cross: bool = False,
                    use_rope: bool = True):
    """Returns (y, new_kv_cache).

    Modes:
      * train / prefill:  kv_cache is None -> attends within ``x`` (or to
        ``encoder_out`` for cross-attention); returns fresh (k, v).
      * decode:           kv_cache=(k, v).  Self-attention appends the new
        token at ``cache_len - 1``; cross-attention reads the static cache.
    """
    b, lq, _ = x.shape
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if is_cross and kv_cache is not None:
        k = v = None                   # static encoder K/V: nothing to project
    else:
        kv_src = encoder_out if encoder_out is not None else x
        k = jnp.einsum("bld,dhk->blhk", kv_src, p["wk"])
        v = jnp.einsum("bld,dhk->blhk", kv_src, p["wv"])
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
    if use_rope and not is_cross:
        if positions is None:
            positions = jnp.arange(lq)[None]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, ("batch", None, "heads", None), rules)

    if kv_cache is not None:
        kc, vc = kv_cache
        if not is_cross:              # self-attention decode: append token
            idx = jnp.max(cache_len) - 1
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, idx, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, idx, axis=1)
            o = ops.decode_attention(q, kc, vc, cache_len,
                                     soft_cap=cfg.logits_soft_cap,
                                     window=window)
        else:                          # cross-attention decode: static cache
            o = ops.attention(q, kc, vc, causal=False,
                              soft_cap=cfg.logits_soft_cap, impl="ref")
        new_cache = (kc, vc)
    else:
        o = ops.attention(q, k, v, causal=causal and encoder_out is None,
                          soft_cap=cfg.logits_soft_cap, window=window,
                          impl=cfg.attn_impl, chunk=cfg.attn_chunk)
        # train/prefill: do not thread caches through the stack (a scanned
        # stack would materialize all-layer K/V; production prefill writes
        # the cache seq-sharded instead — see EXPERIMENTS.md §Dry-run)
        new_cache = None
    y = jnp.einsum("blhk,hkd->bld", o, p["wo"])
    return shard_activation(y, ("batch", "seq", "act_embed"), rules), new_cache


# ---------------------------------------------------------------------------
# Convolution layers (3D-TrIM kernel path; CNN frontends / vision towers)
# ---------------------------------------------------------------------------

def conv2d_params(k: int, cin: int, cout: int, *, groups: int = 1,
                  bias: bool = True) -> dict:
    """Declarations for one (grouped) conv layer on the trim_conv2d path."""
    # init_params scales by 1/sqrt(shape[-2]) == 1/sqrt(cin/groups); the
    # extra 1/k recovers He-style 1/sqrt(K^2 * cin/groups) for conv taps
    p = {"w": Param((k, k, cin // groups, cout), (None, None, None, None),
                    scale=1.0 / k)}
    if bias:
        p["b"] = Param((cout,), (None,), init="zeros")
    return p


def conv2d_apply(p: dict, x: jax.Array, *, stride: int = 1,
                 padding: str = "same", groups: int = 1,
                 activation: str | None = "relu",
                 impl: str = "pallas",
                 mesh=None, rules: dict | None = None,
                 layer: str | None = None) -> jax.Array:
    """One conv layer with the bias + activation epilogue fused into the
    Pallas kernel (single HBM round-trip for the output).  Accepts either
    raw params (``{"w", "b"}``) or a tree packed by
    :func:`conv2d_pack_params` (``{"packed"}``) — the packed form skips
    the per-call weight pad/reshape.  ``mesh``/``rules`` select the
    sharded halo-exchange path (DESIGN.md §6; raw params only — packed
    weights freeze a single-device layout).  ``layer`` names this layer
    in guard demotion events (DESIGN.md §9)."""
    if "packed" in p:
        return ops.conv2d(x, p["packed"], stride=stride, padding=padding,
                          impl=impl, activation=activation,
                          mesh=mesh, rules=rules, layer=layer)
    return ops.conv2d(x, p["w"], stride=stride, padding=padding, impl=impl,
                      feature_group_count=groups, bias=p.get("b"),
                      activation=activation, mesh=mesh, rules=rules,
                      layer=layer)


def conv2d_pack_params(p: dict, *, groups: int = 1,
                       tile_cout: int | None = None,
                       tile_h: int | None = None,
                       dataflow: str | None = None,
                       x_shape=None, stride: int = 1,
                       padding: str = "same") -> dict:
    """Pack one conv layer's materialized params at load time.

    Performs the pad/reshape to the kernel's ``padded_weight_shape`` (and
    the padded bias row) exactly once; the returned tree is consumed
    transparently by :func:`conv2d_apply`.  With ``x_shape`` given, the
    autotune cache fills any unset tile/dataflow knob so the forward pass
    runs entirely on cached plans.
    """
    return {"packed": ops.pack_conv2d_weights(
        p["w"], p.get("b"), groups=groups, tile_cout=tile_cout,
        tile_h=tile_h, dataflow=dataflow, x_shape=x_shape, stride=stride,
        padding=padding)}


def calibrate_conv2d(p: dict, x_batch: jax.Array, *, groups: int = 1,
                     stride: int = 1, padding: str = "same",
                     tile_cout: int | None = None,
                     tile_h: int | None = None,
                     dataflow: str | None = None) -> dict:
    """Post-training int8 calibration of one conv layer (DESIGN.md §11).

    Observes the sample batch's activation range for the per-tensor
    affine calibration — ``scale = (max - min) / 255`` over the
    ``[-128, 127]`` grid with the range widened to contain 0.0 so the
    zero point (the quantized image of 0.0, which also pads 'same'
    borders) is representable — quantizes the weights per-out-channel
    symmetric (``ref.weight_scales_int8``) and packs everything into a
    quantized :class:`~repro.kernels.ops.PackedConv2dWeights`.  The
    returned ``{"packed": ...}`` tree replaces ``{"w", "b"}`` and is
    consumed transparently by :func:`conv2d_apply`, which then runs the
    int8 tier chain of ``ops.conv2d``.
    """
    xf = x_batch.astype(jnp.float32)
    lo = jnp.minimum(jnp.min(xf), 0.0)
    hi = jnp.maximum(jnp.max(xf), 0.0)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    zp = jnp.clip(jnp.round(-128.0 - lo / scale),
                  -128, 127).astype(jnp.int32)
    return {"packed": ops.quantize_conv2d_weights(
        p["w"], p.get("b"), x_scale=scale, x_zero_point=zp, groups=groups,
        tile_cout=tile_cout, tile_h=tile_h, dataflow=dataflow,
        x_shape=x_batch.shape, stride=stride, padding=padding)}


def depthwise_separable_params(k: int, cin: int, cout: int,
                               *, bias: bool = True) -> dict:
    """MobileNet-style depthwise 3x3 + pointwise 1x1 block."""
    return {"dw": conv2d_params(k, cin, cin, groups=cin, bias=bias),
            "pw": conv2d_params(1, cin, cout, bias=bias)}


def depthwise_separable_pack_params(p: dict, *, x_shape=None,
                                    stride: int = 1) -> dict:
    """Load-time packing of a depthwise-separable block (both convs)."""
    cin = p["dw"]["w"].shape[3]
    dw_shape = pw_shape = x_shape
    if x_shape is not None and stride != 1:
        n, h, w, _ = x_shape
        pw_shape = (n, -(-h // stride), -(-w // stride), cin)
    return {"dw": conv2d_pack_params(p["dw"], groups=cin, x_shape=dw_shape,
                                     stride=stride),
            "pw": conv2d_pack_params(p["pw"], x_shape=pw_shape)}


def depthwise_separable_apply(p: dict, x: jax.Array, *, stride: int = 1,
                              activation: str | None = "relu",
                              impl: str = "pallas",
                              mesh=None,
                              rules: dict | None = None) -> jax.Array:
    h = conv2d_apply(p["dw"], x, stride=stride, groups=x.shape[-1],
                     activation=activation, impl=impl, mesh=mesh,
                     rules=rules)
    return conv2d_apply(p["pw"], h, activation=activation, impl=impl,
                        mesh=mesh, rules=rules)


def simple_cnn_params(*, cin: int = 3, channels=(8, 16), n_classes: int = 10,
                      k: int = 3, depthwise_stage: bool = True) -> dict:
    """A small CIFAR-shaped classifier running entirely on trim kernels.

    Per stage: a stride-1 conv (fused ReLU) followed by a stride-2 conv
    for downsampling — pooling as strided convolution keeps every op on
    the differentiable Pallas path.  ``depthwise_stage`` inserts a
    depthwise 3x3 before the last downsample so training exercises the
    grouped backward kernels too.  The head is global mean pooling + a
    dense projection.
    """
    p, prev = {}, cin
    for i, c in enumerate(channels):
        p[f"conv{i}"] = conv2d_params(k, prev, c)
        p[f"down{i}"] = conv2d_params(k, c, c)
        prev = c
    if depthwise_stage:
        p["dw"] = conv2d_params(k, prev, prev, groups=prev)
    p["head"] = {"w": Param((prev, n_classes), (None, None)),
                 "b": Param((n_classes,), (None,), init="zeros")}
    return p


def simple_cnn_apply(p: dict, x: jax.Array, *, impl: str = "pallas",
                     mesh=None, rules: dict | None = None) -> jax.Array:
    """Forward pass of :func:`simple_cnn_params`.  x: (N, H, W, Cin);
    returns (N, n_classes) logits.  The depthwise stage is applied iff
    the params carry one (inferred from the tree, like the stage
    count).  With ``mesh``/``rules`` every conv runs the sharded
    halo-exchange path (data + spatial parallelism, DESIGN.md §6)."""
    n_stages = sum(1 for k in p if k.startswith("conv"))
    for i in range(n_stages):
        x = conv2d_apply(p[f"conv{i}"], x, activation="relu", impl=impl,
                         mesh=mesh, rules=rules)
        if "dw" in p and i == n_stages - 1:
            x = conv2d_apply(p["dw"], x, groups=x.shape[-1],
                             activation="relu", impl=impl, mesh=mesh,
                             rules=rules)
        x = conv2d_apply(p[f"down{i}"], x, stride=2, activation="relu",
                         impl=impl, mesh=mesh, rules=rules)
    x = x.mean(axis=(1, 2))                       # global mean pool
    return x @ p["head"]["w"] + p["head"]["b"]


def cnn_params_from_layers(layers_list, *, n_classes: int | None = None,
                           bias: bool = True) -> dict:
    """Parameter declarations for a whole conv topology (DESIGN.md §7).

    ``layers_list`` is a ``list[core.model.ConvLayer]`` — e.g.
    ``core.netplan.network_layers("vgg16")`` or a
    ``core.netplan.scale_layers`` reduction of it.  One ``conv{i}``
    entry per layer; ``n_classes`` adds a global-mean-pool linear head.
    Consumed by :func:`cnn_apply_from_layers` (and packable layer-by-
    layer with :func:`cnn_pack_params`).
    """
    p = {}
    for i, l in enumerate(layers_list):
        p[f"conv{i}"] = conv2d_params(l.kernel, l.in_channels,
                                      l.out_channels, groups=l.groups,
                                      bias=bias)
    if n_classes is not None:
        d = layers_list[-1].out_channels
        p["head"] = {"w": Param((d, n_classes), (None, None)),
                     "b": Param((n_classes,), (None,), init="zeros")}
    return p


def cnn_pack_params(p: dict, layers_list, *, n: int = 1) -> dict:
    """Load-time packing of a whole topology's conv weights.

    Threads the activation shape through the layers (pooling included)
    so each ``conv2d_pack_params`` call keys the autotune cache with the
    exact shape ``ops.conv2d`` will see — after an
    ``autotune.tune_network`` sweep the packed forward pass runs
    entirely on cached plans."""
    from repro.core.netplan import layer_kernel_problem
    packed = dict(p)
    for i, l in enumerate(layers_list):
        if l.kernel > ops.MAX_NATIVE_K:
            continue    # kernel-tiled path re-slices raw weights (§4)
        # the shared layer -> executed-problem mapping (validates that
        # the layer's padding is reproducible by the execution path)
        _, _, _, padding = layer_kernel_problem(l, n=n)
        packed[f"conv{i}"] = conv2d_pack_params(
            p[f"conv{i}"], groups=l.groups,
            x_shape=(n, l.ifmap, l.ifmap, l.in_channels),
            stride=l.stride, padding=padding)
    return packed


def _maxpool(x: jax.Array, stride: int, window: int) -> jax.Array:
    """Max pooling (VGG 2x2/s2, AlexNet overlapping 3x3/s2)."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


def _cnn_apply_layer_range(p: dict, layers_list, pools, x: jax.Array,
                           lo: int, hi: int, *, activation, impl, mesh,
                           rules) -> jax.Array:
    """Per-layer execution of layers ``[lo, hi)`` — one ``conv2d`` call
    (plus the inferred max-pool) per layer.  Shared by the plain forward
    pass and by the fused path's depth-1 groups."""
    from repro.core.netplan import layer_kernel_problem
    for i in range(lo, hi):
        l, (ps, pw) = layers_list[i], pools[i]
        # derive (and validate) the padding mode through the shared
        # layer -> executed-problem mapping: a topology whose paper
        # padding this path cannot reproduce fails loudly here instead
        # of silently running a different network than NetworkPlan bills
        _, _, _, padding = layer_kernel_problem(l, n=x.shape[0])
        x = conv2d_apply(p[f"conv{i}"], x, stride=l.stride,
                         padding=padding, groups=l.groups,
                         activation=activation, impl=impl, mesh=mesh,
                         rules=rules, layer=l.name)
        if ps > 1 or pw > 1:      # (1, w>1): stride-1 overlapping pool
            x = _maxpool(x, ps, pw)
    return x


def cnn_apply_from_layers(p: dict, layers_list, x: jax.Array, *,
                          activation: str | None = "relu",
                          impl: str = "pallas", mesh=None,
                          rules: dict | None = None,
                          fused: bool = False,
                          fuse_plan=None) -> jax.Array:
    """Forward pass of a conv topology built by
    :func:`cnn_params_from_layers`: each conv runs on the trim kernel
    path (bias + activation fused; packed params and cached plans when
    the tree was packed/tuned), with the topology's max-pooling inferred
    from the spatial dims between consecutive layers
    (``core.netplan.infer_pools``).  Returns class logits when the tree
    has a head, else the final feature map.

    ``fused=True`` executes each residency group of a
    :class:`~repro.core.fuse_plan.FusedGroupPlan` as one megakernel
    (conv→[pool]→conv chains with interior activations VMEM-resident,
    DESIGN.md §8) instead of one ``pallas_call`` per layer; depth-1
    groups fall back to the per-layer path, so outputs are bit-identical
    either way.  Pass ``fuse_plan`` to reuse a prebuilt (e.g. autotuned)
    plan; otherwise one is built for ``x``'s batch.  The fused path
    needs raw (unpacked) conv params and is single-device —
    ``mesh``/``rules`` select the sharded per-layer engine instead.
    """
    from repro.core.netplan import infer_pools
    pools = list(infer_pools(layers_list))
    if fused or fuse_plan is not None:
        if mesh is not None or rules is not None:
            raise ValueError(
                "fused execution is single-device; drop mesh/rules or "
                "run the per-layer sharded path (fused=False)")
        from repro.core.fuse_plan import FusedGroupPlan
        from repro.kernels.trim_conv2d_fused import fused_group_apply
        if fuse_plan is None:
            fuse_plan = FusedGroupPlan.build(list(layers_list),
                                             n=x.shape[0])
        for g in fuse_plan.groups:
            lo, hi = g.start, g.start + g.depth
            if not g.fused:
                x = _cnn_apply_layer_range(
                    p, layers_list, pools, x, lo, hi,
                    activation=activation, impl=impl, mesh=None,
                    rules=None)
                continue
            weights, biases = [], []
            for i in range(lo, hi):
                lp = p[f"conv{i}"]
                if "packed" in lp:
                    raise ValueError(
                        f"conv{i}: fused execution needs raw conv "
                        "params ({'w', 'b'}); packed trees freeze the "
                        "per-layer kernel layout — skip cnn_pack_params "
                        "on the fused path")
                weights.append(lp["w"])
                biases.append(lp.get("b"))
            # guarded megakernel (DESIGN.md §9): a lowering/runtime
            # failure of the whole-group kernel demotes this group to
            # per-layer execution, which itself demotes conv-by-conv
            label = f"{layers_list[lo].name}..{layers_list[hi - 1].name}"

            def _fused_tier(x=x, weights=weights, biases=biases, g=g):
                return fused_group_apply(x, weights, biases, group=g,
                                         activation=activation)

            def _per_layer_tier(x=x, lo=lo, hi=hi):
                return _cnn_apply_layer_range(
                    p, layers_list, pools, x, lo, hi,
                    activation=activation, impl=impl, mesh=None,
                    rules=None)

            key = f"fused:d{g.depth}:n{g.n}:{g.signature}:{x.dtype}"
            x = guard.run_chain(key, [("fused", _fused_tier),
                                      ("pallas", _per_layer_tier)],
                                layer=label)
    else:
        x = _cnn_apply_layer_range(p, layers_list, pools, x, 0,
                                   len(layers_list),
                                   activation=activation, impl=impl,
                                   mesh=mesh, rules=rules)
    if "head" not in p:
        return x
    x = x.mean(axis=(1, 2))                       # global mean pool
    return x @ p["head"]["w"] + p["head"]["b"]


def cnn_params_from_graph(graph, *, n_classes: int | None = None,
                          bias: bool = True) -> dict:
    """Parameter declarations for a DAG topology (DESIGN.md §12).

    ``graph`` is anything ``core.netplan.graph_nodes`` resolves — a name
    ("resnet18" | "unet"), a ``list[GraphNode]`` or a linear topology.
    One entry per conv node, keyed by the NODE name (graphs have no
    layer order to index by); joins carry no params.  ``n_classes``
    adds a global-mean-pool linear head over the terminal node's
    channels.  Consumed by :func:`cnn_apply_from_graph`."""
    from repro.core.netplan import graph_nodes
    nodes = graph_nodes(graph)
    p, ch = {}, {}
    for nd in nodes:
        if nd.name == "head":
            raise ValueError(
                'node name "head" is reserved for the linear classifier '
                "head — rename the graph node")
        if nd.op == "conv":
            l = nd.layer
            p[nd.name] = conv2d_params(l.kernel, l.in_channels,
                                       l.out_channels, groups=l.groups,
                                       bias=bias)
            ch[nd.name] = l.out_channels
        elif nd.op == "concat":
            ch[nd.name] = sum(ch[s] for s in nd.inputs)
        else:
            ch[nd.name] = ch[nd.inputs[0]]
    if n_classes is not None:
        d = ch[nodes[-1].name]
        p["head"] = {"w": Param((d, n_classes), (None, None)),
                     "b": Param((n_classes,), (None,), init="zeros")}
    return p


def cnn_pack_params_from_graph(p: dict, graph, *, n: int = 1) -> dict:
    """Load-time packing of a DAG topology's conv weights — the graph
    analogue of :func:`cnn_pack_params`: each conv node's kernel-seen
    shape keys the autotune cache, so a ``tune_graph`` sweep makes the
    packed forward pass run entirely on cached plans."""
    from repro.core.netplan import graph_nodes, layer_kernel_problem
    packed = dict(p)
    for nd in graph_nodes(graph):
        if nd.op != "conv" or nd.layer.kernel > ops.MAX_NATIVE_K:
            continue
        l = nd.layer
        _, _, _, padding = layer_kernel_problem(l, n=n)
        packed[nd.name] = conv2d_pack_params(
            p[nd.name], groups=l.groups,
            x_shape=(n, l.ifmap, l.ifmap, l.in_channels),
            stride=l.stride, padding=padding)
    return packed


def _upsample_nearest(x: jax.Array, scale: int) -> jax.Array:
    """Nearest-neighbour spatial upsampling (U-Net decoder)."""
    return jnp.repeat(jnp.repeat(x, scale, axis=1), scale, axis=2)


def _graph_conv_node(p: dict, nd, x: jax.Array, *, activation, impl,
                     mesh, rules) -> jax.Array:
    """One graph conv node: the trim conv (padding validated through the
    shared layer -> executed-problem mapping) plus its epilogue pool."""
    from repro.core.netplan import layer_kernel_problem
    l = nd.layer
    _, _, _, padding = layer_kernel_problem(l, n=x.shape[0])
    y = conv2d_apply(p[nd.name], x, stride=l.stride, padding=padding,
                     groups=l.groups, activation=activation, impl=impl,
                     mesh=mesh, rules=rules, layer=l.name)
    if nd.pool > 1 or nd.pool_window > 1:
        y = _maxpool(y, nd.pool, nd.pool_window)
    return y


def cnn_apply_from_graph(p: dict, graph, x: jax.Array, *,
                         activation: str | None = "relu",
                         impl: str = "pallas", mesh=None,
                         rules: dict | None = None,
                         fused: bool = False,
                         fuse_plan=None) -> jax.Array:
    """Forward pass of a DAG topology built by
    :func:`cnn_params_from_graph`: nodes execute in topological order —
    conv nodes on the trim kernel path (tuned / packed / guarded, same
    engine as the chains), joins as their jnp epilogues (elementwise
    add, channel concat, max pool, nearest upsample).  Returns the
    terminal node's activation, or class logits when the tree has a
    head.

    ``fused=True`` partitions the graph into fusable linear segments
    between joins (``core.fuse_plan.graph_segments``) and executes each
    multi-conv segment exactly like today's chains —
    :func:`cnn_apply_from_layers` with a per-segment
    :class:`~repro.core.fuse_plan.FusedGroupPlan` — so fused and
    per-node execution are bit-identical (tested).  Pass ``fuse_plan``
    (a prebuilt :class:`~repro.core.fuse_plan.GraphFusePlan`) to reuse
    tuned segment plans.  The fused path needs raw conv params and is
    single-device."""
    from repro.core.netplan import graph_nodes
    nodes = graph_nodes(graph)
    by = {nd.name: nd for nd in nodes}
    seg_of: dict[str, tuple] = {}
    if fused or fuse_plan is not None:
        if mesh is not None or rules is not None:
            raise ValueError(
                "fused execution is single-device; drop mesh/rules or "
                "run the per-node path (fused=False)")
        if fuse_plan is not None:
            segs = list(fuse_plan.segments)
        else:
            from repro.core.fuse_plan import graph_segments
            segs = [(names, None) for names, _ in graph_segments(nodes)]
        for names, plan in segs:
            seg_of[names[0]] = (names, plan)

    outs: dict[str, jax.Array] = {}
    executed: set[str] = set()
    last = None
    for nd in nodes:
        if nd.name in executed:
            continue
        if nd.name in seg_of and len(seg_of[nd.name][0]) > 1:
            names, plan = seg_of[nd.name]
            seg_nodes = [by[nm] for nm in names]
            conv_nodes = [sn for sn in seg_nodes if sn.op == "conv"]
            first, tail = seg_nodes[0], seg_nodes[-1]
            xin = outs[first.inputs[0]] if first.inputs else x
            p_sub = {f"conv{i}": p[sn.name]
                     for i, sn in enumerate(conv_nodes)}
            y = cnn_apply_from_layers(
                p_sub, [sn.layer for sn in conv_nodes], xin,
                activation=activation, impl=impl, fused=True,
                fuse_plan=plan)
            if tail.pool > 1 or tail.pool_window > 1:
                y = _maxpool(y, tail.pool, tail.pool_window)
            executed.update(names)
            outs[tail.name] = y
            last = tail.name
            continue
        if nd.op == "conv":
            xin = outs[nd.inputs[0]] if nd.inputs else x
            y = _graph_conv_node(p, nd, xin, activation=activation,
                                 impl=impl, mesh=mesh, rules=rules)
        elif nd.op == "pool":
            y = _maxpool(outs[nd.inputs[0]], nd.pool, nd.pool_window)
        elif nd.op == "add":
            y = outs[nd.inputs[0]]
            for s in nd.inputs[1:]:
                y = y + outs[s]
        elif nd.op == "concat":
            y = jnp.concatenate([outs[s] for s in nd.inputs], axis=-1)
        else:                                     # upsample
            y = _upsample_nearest(outs[nd.inputs[0]], nd.scale)
        outs[nd.name] = y
        executed.add(nd.name)
        last = nd.name
    y = outs[last]
    if "head" not in p:
        return y
    y = y.mean(axis=(1, 2))                       # global mean pool
    return y @ p["head"]["w"] + p["head"]["b"]


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def mlp_params(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_gate": Param((d, f), ("embed", "mlp")),
                "w_up": Param((d, f), ("embed", "mlp")),
                "w_down": Param((f, d), ("mlp", "embed"))}
    return {"w_up": Param((d, f), ("embed", "mlp")),
            "b_up": Param((f,), ("mlp",), init="zeros"),
            "w_down": Param((f, d), ("mlp", "embed")),
            "b_down": Param((d,), ("act_embed",), init="zeros")}


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    h = shard_activation(h, ("batch", None, "mlp"), rules)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return shard_activation(y, ("batch", "seq", "act_embed"), rules)


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity-grouped matmul)
# ---------------------------------------------------------------------------

def moe_params(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_dff
    p = {
        "router": Param((d, e), ("embed", "experts"), scale=0.1),
        "w_gate": Param((e, d, f), ("experts", "embed", "mlp")),
        "w_up": Param((e, d, f), ("experts", "embed", "mlp")),
        "w_down": Param((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.shared_expert_dff:
        p["shared"] = {
            "w_gate": Param((d, cfg.shared_expert_dff), ("embed", "mlp")),
            "w_up": Param((d, cfg.shared_expert_dff), ("embed", "mlp")),
            "w_down": Param((cfg.shared_expert_dff, d), ("mlp", "embed")),
        }
    return p


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict):
    """GShard-style token-choice top-k with *grouped* capacity dispatch.

    Tokens are grouped by sequence (the group dim is batch-sharded), so
    every gather/scatter in the dispatch is a *batched* op over a sharded
    leading dim — SPMD shards it instead of all-gathering the operands.
    The expert einsum is (g, e, c, d) x (e, d, f) with g on the data axis
    and e on the model axis (expert parallelism); the data->expert
    boundary at the capacity buffer is the MoE all-to-all.  HLO flops
    reflect the useful expert compute: T*k*cf * 3*D*F.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # decode (s == 1): a single group over the whole batch keeps the
    # capacity waste bounded (cap ~ B*k/E instead of 1 per sequence).
    xg = x.reshape(1, b, d) if s == 1 else x
    g, tg, _ = xg.shape
    xg = shard_activation(xg, ("batch", None, None), rules)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (g, tg, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                     1e-9)                     # renormalize

    cap = max(int(math.ceil(tg * k / e * cfg.capacity_factor)), 1)

    def _dispatch_one(xg1, idx1, val1):
        """One group: sort tokens by expert, scatter into capacity slots.

        vmapped over groups so every gather/scatter carries an explicit
        batch dim that the SPMD partitioner shards (a flat multi-dim
        scatter would be replicated on every device).
        """
        flat_e = idx1.reshape(tg * k)
        flat_t = jnp.repeat(jnp.arange(tg), k)
        flat_g = val1.reshape(tg * k).astype(x.dtype)
        order = jnp.argsort(flat_e)                            # stable
        seg, tok, gts = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(tg * k) - starts[seg]
        keep = rank < cap
        slot = jnp.where(keep, seg * cap + rank, e * cap)      # overflow
        rows = xg1[tok] * keep[:, None].astype(x.dtype)
        buf1 = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(rows)
        return buf1[:-1], slot, tok, gts, keep, counts

    buf, slot, tok, gts, keep, counts = jax.vmap(_dispatch_one)(
        xg, gate_idx, gate_vals)
    buf = buf.reshape(g, e, cap, d)
    # the data->expert all-to-all boundary (expert parallelism)
    buf = shard_activation(buf, ("batch", "experts", None, None), rules)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = shard_activation(h, ("batch", "experts", None, "mlp"), rules)
    yexp = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    yexp = shard_activation(yexp, ("batch", "experts", None, None), rules)

    def _combine_one(yexp1, slot1, tok1, gts1, keep1):
        back = yexp1.reshape(e * cap, d)[jnp.clip(slot1, 0, e * cap - 1)]
        contrib = jnp.where(keep1[:, None], back, 0.0) * gts1[:, None]
        return jnp.zeros((tg, d), x.dtype).at[tok1].add(contrib)

    y = jax.vmap(_combine_one)(yexp, slot, tok, gts, keep)
    y = shard_activation(y, ("batch", None, None), rules)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xg, cfg, rules)

    # load-balancing auxiliary loss (Switch-style), averaged over groups
    me = probs.mean(axis=1)                                    # (g, e)
    ce = counts.astype(jnp.float32) / (tg * k)                 # (g, e)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    return (shard_activation(y.reshape(b, s, d),
                             ("batch", "seq", "act_embed"), rules), aux)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embedding_params(cfg: ModelConfig) -> dict:
    p = {"embed": Param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                        scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = Param((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return p


def embed_apply(p: dict, tokens: jax.Array, cfg: ModelConfig, rules: dict):
    x = jnp.take(p["embed"], tokens, axis=0)
    return shard_activation(x, ("batch", "seq", "act_embed"), rules)


def head_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict):
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w
    return shard_activation(logits, ("batch", None, "vocab"), rules)
