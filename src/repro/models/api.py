"""Unified model API: one entry point per (family x mode).

``params(cfg)``                   -> Param declaration tree
``forward(params, batch, cfg)``   -> (logits, aux)          [train/prefill]
``decode(params, batch, state, cfg)`` -> (logits, new_state)
``decode_state(cfg, batch, max_len)`` -> Param tree for the decode state

Batch dict keys: ``tokens``/``labels`` (LM), plus ``vision`` (B, Nv, D)
for VLM and ``src`` (B, Ls, D) for enc-dec.  Decode adds ``cache_len``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import mamba, rglru, transformer
from repro.models.base import Param
from repro.models.config import ModelConfig


def params(cfg: ModelConfig):
    if cfg.family == "ssm":
        return mamba.lm_params(cfg)
    if cfg.family == "hybrid":
        return rglru.lm_params(cfg)
    if cfg.family == "encdec":
        return transformer.encdec_params(cfg)
    return transformer.lm_params(cfg)


def forward(p, batch: dict, cfg: ModelConfig, rules: dict):
    """Full-sequence forward (training / prefill).  Returns (logits, aux)."""
    if cfg.family == "ssm":
        logits, _, aux = mamba.lm_apply(p, batch["tokens"], cfg, rules)
    elif cfg.family == "hybrid":
        logits, _, aux = rglru.lm_apply(p, batch["tokens"], cfg, rules)
    elif cfg.family == "encdec":
        logits, _, _, aux = transformer.encdec_apply(
            p, batch["src"], batch["tokens"], cfg, rules)
    else:
        logits, _, aux = transformer.lm_apply(
            p, batch["tokens"], cfg, rules,
            vision_embeds=batch.get("vision"))
    return logits, aux


def decode_state(cfg: ModelConfig, batch: int, max_len: int):
    """Param declaration tree for the decode-time state."""
    if cfg.family == "ssm":
        return mamba.make_state(cfg, batch)
    if cfg.family == "hybrid":
        return rglru.make_state(cfg, batch)
    state = {"caches": transformer.make_caches(cfg, batch, max_len)}
    if cfg.family == "encdec":
        state["caches"] = transformer.make_caches(cfg, batch, max_len,
                                                  cfg.dec_layers)
        state["cross"] = transformer.make_caches(cfg, batch,
                                                 cfg.n_frontend_tokens or 1,
                                                 cfg.dec_layers)
    return state


def decode(p, batch: dict, state, cfg: ModelConfig, rules: dict):
    """One-token decode step.  batch: tokens (B, 1), cache_len (B,).

    Returns (logits (B, 1, V), new_state).
    """
    cache_len = batch["cache_len"]
    if cfg.family == "ssm":
        dcfg = cfg.replace(unroll_layers=True)
        logits, new_state, _ = mamba.lm_apply(
            p, batch["tokens"], dcfg, rules,
            state=state, cache_len=cache_len)
        return logits, new_state
    if cfg.family == "hybrid":
        logits, new_state, _ = rglru.lm_apply(
            p, batch["tokens"], cfg, rules, state=state,
            cache_len=cache_len)
        return logits, new_state
    if cfg.family == "encdec":
        logits, caches, cross, _ = transformer.encdec_apply(
            p, None, batch["tokens"], cfg, rules,
            caches=state["caches"], cache_len=cache_len,
            cross_caches=state["cross"])
        return logits, {"caches": caches, "cross": cross}
    logits, caches, _ = transformer.lm_apply(
        p, batch["tokens"], cfg, rules, caches=state["caches"],
        cache_len=cache_len)
    return logits, {"caches": caches}


def loss_fn(logits: jax.Array, labels: jax.Array, aux=0.0,
            aux_weight: float = 0.01):
    """Mean next-token cross-entropy (+ MoE load-balance aux)."""
    if logits.shape[1] != labels.shape[1]:       # VLM: vision prefix
        logits = logits[:, -labels.shape[1]:]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux_weight * aux
