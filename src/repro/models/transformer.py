"""Decoder-only LM (dense / MoE / VLM) and encoder-decoder stacks."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import Param, shard_activation, stack_params
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# One decoder block
# ---------------------------------------------------------------------------

def block_params(cfg: ModelConfig, cross: bool = False) -> dict:
    p = {
        "ln_att": L.norm_params(cfg),
        "att": L.attention_params(cfg),
        "ln_mlp": L.norm_params(cfg),
    }
    if cross:
        p["ln_cross"] = L.norm_params(cfg)
        p["cross"] = L.attention_params(cfg)
    if cfg.family == "moe":
        p["moe"] = L.moe_params(cfg)
    else:
        p["mlp"] = L.mlp_params(cfg)
    return p


def block_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: dict, *,
                positions=None, kv_cache=None, cache_len=None,
                causal: bool = True, encoder_out=None, cross_cache=None):
    """Returns (x, new_kv_cache, new_cross_cache, aux_loss)."""
    h, new_cache = L.attention_apply(
        p["att"], L.norm_apply(p["ln_att"], x, cfg), cfg, rules,
        positions=positions, kv_cache=kv_cache, cache_len=cache_len,
        causal=causal, window=cfg.window)
    x = x + h
    new_cross = cross_cache
    if encoder_out is not None or cross_cache is not None:
        h, new_cross = L.attention_apply(
            p["cross"], L.norm_apply(p["ln_cross"], x, cfg), cfg, rules,
            encoder_out=encoder_out, kv_cache=cross_cache,
            is_cross=True, causal=False, use_rope=False)
        x = x + h
    z = L.norm_apply(p["ln_mlp"], x, cfg)
    if cfg.family == "moe":
        h, aux = L.moe_apply(p["moe"], z, cfg, rules)
    else:
        h, aux = L.mlp_apply(p["mlp"], z, cfg, rules), 0.0
    return x + h, new_cache, new_cross, aux


# ---------------------------------------------------------------------------
# Stacked decoder (scan or unrolled)
# ---------------------------------------------------------------------------

def _run_blocks(blocks_p, x, cfg: ModelConfig, rules, *, positions,
                caches, cache_len, causal=True, encoder_out=None,
                cross_caches=None, n_layers=None):
    """Run the layer stack.  caches/cross_caches: stacked (L, ...) or None."""
    n = n_layers or cfg.n_layers
    aux_total = 0.0

    def one(pi, x, ci, xci):
        return block_apply(pi, x, cfg, rules, positions=positions,
                           kv_cache=ci, cache_len=cache_len, causal=causal,
                           encoder_out=encoder_out, cross_cache=xci)

    if cfg.remat:
        one = jax.checkpoint(one,
                             policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll_layers:
        new_caches, new_cross = [], []
        for i in range(n):
            pi = jax.tree.map(lambda a: a[i], blocks_p)
            ci = jax.tree.map(lambda a: a[i], caches) if caches is not None \
                else None
            xci = jax.tree.map(lambda a: a[i], cross_caches) \
                if cross_caches is not None else None
            x, nc, nxc, aux = one(pi, x, ci, xci)
            aux_total += aux
            new_caches.append(nc)
            new_cross.append(nxc)
        stack = lambda lst: jax.tree.map(lambda *a: jnp.stack(a), *lst) \
            if lst and lst[0] is not None else None
        return x, stack(new_caches), stack(new_cross), aux_total

    def body(carry, xs):
        x, aux = carry
        pi, ci, xci = xs
        x, nc, nxc, a = one(pi, x, ci, xci)
        return (x, aux + a), (nc, nxc)

    (x, aux_total), (new_caches, new_cross) = jax.lax.scan(
        body, (x, 0.0), (blocks_p, caches, cross_caches))
    return x, new_caches, new_cross, aux_total


# ---------------------------------------------------------------------------
# Decoder-only LM
# ---------------------------------------------------------------------------

def lm_params(cfg: ModelConfig) -> dict:
    p = {
        "tok": L.embedding_params(cfg),
        "blocks": stack_params(block_params(cfg), cfg.n_layers),
        "ln_f": L.norm_params(cfg),
    }
    if cfg.frontend == "vision":
        p["vision_proj"] = Param((cfg.d_model, cfg.d_model),
                                 ("embed", "act_embed"))
    return p


def make_caches(cfg: ModelConfig, batch: int, max_len: int,
                n_layers: int | None = None):
    """Abstract/zero KV caches, stacked over layers."""
    n = n_layers or cfg.n_layers
    shape = (n, batch, max_len, cfg.n_kv_heads, cfg.hd)
    axes = ("layers", "batch", "seq", "kv_heads", None)
    return {"k": Param(shape, axes, init="zeros"),
            "v": Param(shape, axes, init="zeros")}


def lm_apply(params: dict, tokens: jax.Array, cfg: ModelConfig, rules: dict,
             *, positions=None, caches=None, cache_len=None,
             vision_embeds=None):
    """tokens: (B, S) -> logits (B, S[+Nv], vocab).

    decode mode: S == 1 with ``caches``/``cache_len`` set.
    """
    x = L.embed_apply(params["tok"], tokens, cfg, rules)
    if vision_embeds is not None:
        v = vision_embeds.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([v, x], axis=1)
    if positions is None:
        if cache_len is not None:
            positions = (jnp.reshape(cache_len, (-1, 1)) - 1)
        else:
            positions = jnp.arange(x.shape[1])[None]
    cache_tuples = (caches["k"], caches["v"]) if caches is not None else None
    x, new_caches, _, aux = _run_blocks(
        params["blocks"], x, cfg, rules, positions=positions,
        caches=cache_tuples, cache_len=cache_len)
    x = L.norm_apply(params["ln_f"], x, cfg)
    logits = L.head_apply(params["tok"], x, cfg, rules)
    if cfg.logits_soft_cap:
        logits = cfg.logits_soft_cap * jnp.tanh(
            logits / cfg.logits_soft_cap)
    out_caches = None
    if new_caches is not None:
        out_caches = {"k": new_caches[0], "v": new_caches[1]}
    return logits, out_caches, aux


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless-m4t backbone; frontend is a stub)
# ---------------------------------------------------------------------------

def encdec_params(cfg: ModelConfig) -> dict:
    enc_cfg = cfg
    return {
        "tok": L.embedding_params(cfg),
        "enc_blocks": stack_params(block_params(enc_cfg), cfg.enc_layers),
        "enc_ln": L.norm_params(cfg),
        "dec_blocks": stack_params(block_params(cfg, cross=True),
                                   cfg.dec_layers),
        "dec_ln": L.norm_params(cfg),
    }


def encdec_apply(params: dict, src_embeds: jax.Array, tgt_tokens: jax.Array,
                 cfg: ModelConfig, rules: dict, *, caches=None,
                 cache_len=None, cross_caches=None):
    """src_embeds: (B, Ls, D) frame embeddings from the audio stub.

    Training/prefill: full encoder + causal decoder.
    Decode: ``caches`` for decoder self-attn, ``cross_caches`` holding the
    projected encoder K/V (encoder is not re-run).
    """
    enc = None
    if cross_caches is None:
        enc = shard_activation(src_embeds, ("batch", "seq", "act_embed"),
                               rules)
        enc, _, _, _ = _run_blocks(params["enc_blocks"], enc, cfg, rules,
                                   positions=jnp.arange(enc.shape[1])[None],
                                   caches=None, cache_len=None, causal=False,
                                   n_layers=cfg.enc_layers)
        enc = L.norm_apply(params["enc_ln"], enc, cfg)

    x = L.embed_apply(params["tok"], tgt_tokens, cfg, rules)
    if cache_len is not None:
        positions = jnp.reshape(cache_len, (-1, 1)) - 1
    else:
        positions = jnp.arange(x.shape[1])[None]
    cache_tuples = (caches["k"], caches["v"]) if caches is not None else None
    xc = (cross_caches["k"], cross_caches["v"]) if cross_caches is not None \
        else None
    x, new_caches, new_cross, aux = _run_blocks(
        params["dec_blocks"], x, cfg, rules, positions=positions,
        caches=cache_tuples, cache_len=cache_len, causal=True,
        encoder_out=enc, cross_caches=xc, n_layers=cfg.dec_layers)
    x = L.norm_apply(params["dec_ln"], x, cfg)
    logits = L.head_apply(params["tok"], x, cfg, rules)
    out_c = {"k": new_caches[0], "v": new_caches[1]} if new_caches is not None \
        else None
    out_xc = {"k": new_cross[0], "v": new_cross[1]} if new_cross is not None \
        else None
    return logits, out_c, out_xc, aux
