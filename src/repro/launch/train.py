"""Production training driver: config -> mesh -> pjit train loop with
checkpoint/restart, straggler watchdog and metrics logging.

Usage (CPU container: keep the model small):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 200 --batch 16 --seq 64 --ckpt-dir /tmp/ckpt

Fault tolerance: every --ckpt-every steps the full train state + data
iterator state is written atomically; on startup the latest checkpoint is
restored automatically (exact resume — see tests/test_checkpoint.py).
A watchdog tracks a step-time EMA and flags stragglers (in multi-host
deployments the flag triggers requeue/despawn via the cluster manager;
here it logs).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import DataConfig, SyntheticStream
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models.base import init_params
from repro.optim import AdamWConfig


class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the running EMA."""

    def __init__(self, threshold: float = 3.0, alpha: float = 0.1):
        self.ema = None
        self.threshold = threshold
        self.alpha = alpha
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if slow:
            self.flagged += 1
        return slow


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--task", default="copy")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    mod = registry.get(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    cfg = cfg.replace(dtype="float32")
    rules = make_rules()
    mesh = make_host_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          decay_steps=args.steps)
    dc = DataConfig(batch=args.batch, seq=args.seq, vocab=cfg.vocab,
                    task=args.task)

    with mesh:
        jstep, decl, st_shard = steps.jit_train_step(
            cfg, opt_cfg, rules, mesh, n_micro=args.n_micro)
        state = init_params(decl, jax.random.PRNGKey(0), jnp.float32)
        stream = SyntheticStream(dc)

        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            restored, manifest = mgr.restore(state)
            if restored is not None:
                state = jax.tree.map(jnp.asarray, restored)
                stream = SyntheticStream.from_state(
                    dc, manifest["data_state"])
                print(f"resumed from step {manifest['step']}")

        watchdog = StragglerWatchdog()
        start = int(state["step"])
        for i in range(start, args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, next(stream))
            state, metrics = jstep(state, batch)
            metrics["loss"].block_until_ready()
            dt = time.time() - t0
            if watchdog.observe(dt):
                print(f"[watchdog] step {i} straggled: {dt:.3f}s "
                      f"(ema {watchdog.ema:.3f}s)")
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms",
                      flush=True)
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state,
                         meta={"data_state": stream.state(),
                               "arch": args.arch,
                               "mesh": list(mesh.shape.values())})
        if mgr:
            mgr.save(args.steps, state,
                     meta={"data_state": stream.state(),
                           "arch": args.arch})
    print(json.dumps({"final_loss": float(metrics["loss"]),
                      "steps": args.steps,
                      "straggler_flags": watchdog.flagged}))


if __name__ == "__main__":
    main()
