"""Production mesh construction.

Called as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types on jax versions that have
    them; older versions (< 0.5) are Auto-only and take no kwarg."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips.  Multi-pod: 2 x (16, 16) = 512.

    The 'pod' axis composes with 'data' for batch/gradient sharding; the
    'model' axis carries TP/EP/SP.  Scaling beyond 2 pods is increasing
    the pod extent — no code changes.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (smoke tests / examples)."""
    n = jax.device_count()
    return compat_make_mesh((n // model, model), ("data", "model"))


def make_conv_mesh(data: int, spatial: int):
    """The conv mesh (DESIGN.md §6): images over 'data', output H-strips
    over 'model' — the axes ``distributed.sharding.CONV_RULES`` maps the
    conv's logical axes onto.  Uses the first ``data * spatial`` local
    devices (force host CPU devices with ``launch.hostdevices`` first)."""
    import numpy as np
    ndev = data * spatial
    if ndev > jax.device_count():
        raise ValueError(
            f"need {ndev} devices, have {jax.device_count()} — force "
            f"host CPU devices before the first jax import "
            f"(launch.hostdevices)")
    devs = np.array(jax.devices()[:ndev]).reshape(data, spatial)
    return jax.sharding.Mesh(devs, ("data", "model"))
