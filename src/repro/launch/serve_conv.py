"""Async conv serving front end — the production shell around
``core.serving.ServingEngine`` (DESIGN.md §10).

The engine itself is a deterministic state machine; this module gives it
the asyncio shell real traffic needs: ``submit`` returns an awaitable
per request, a background batcher task drains the queue into bucket
batches (waiting up to ``max_wait_s`` for a partial batch to fill —
the latency/throughput knob of continuous batching), and forwards run
in a worker thread so the event loop keeps accepting requests while a
batch executes.

The CLI drives the whole serving path once, end to end: build a scaled
topology, prewarm the plan cache + JIT programs across the bucket grid,
replay a seeded Poisson arrival trace as real asyncio clients, and
report latency percentiles, throughput and the degradation stats:

  PYTHONPATH=src python -m repro.launch.serve_conv --net vgg16 \
      --scale 32 --requests 32 --buckets 1,2,4 --rate 200
  PYTHONPATH=src python -m repro.launch.serve_conv --smoke
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
import time

import numpy as np

from repro.core.serving import QueueFull, ServingEngine


class AsyncConvServer:
    """Asyncio shell over a :class:`ServingEngine`.

    ``await submit(x)`` resolves to the request's output row once its
    batch completes.  A single batcher task serializes ``engine.step``
    calls (replica dispatch stays round-robin inside the engine); the
    forward runs in the default executor so the loop stays responsive.
    ``max_wait_s`` bounds how long a partial batch waits for company —
    0 serves immediately (latency-optimal), larger values trade p50 for
    bigger buckets (throughput-optimal).
    """

    def __init__(self, engine: ServingEngine, *, max_wait_s: float = 0.002,
                 clock=time.monotonic) -> None:
        self.engine = engine
        self.max_wait_s = float(max_wait_s)
        self.clock = clock
        self._rids = itertools.count()
        self._futures: dict[int, asyncio.Future] = {}
        self._wake = asyncio.Event()
        self._task: asyncio.Task | None = None
        self._closing = False

    async def __aenter__(self) -> "AsyncConvServer":
        self._task = asyncio.get_running_loop().create_task(self._serve())
        return self

    async def __aexit__(self, *exc) -> None:
        await self.drain()
        self._closing = True
        self._wake.set()
        await self._task

    async def submit(self, x) -> np.ndarray:
        """Enqueue one request and await its result row.  Raises
        :class:`QueueFull` immediately when the engine queue is at
        capacity — backpressure reaches the client as an exception, not
        an unbounded buffer."""
        rid = next(self._rids)
        fut = asyncio.get_running_loop().create_future()
        try:
            self.engine.submit(rid, x, now=self.clock())
        except QueueFull:
            self.engine.recorder.reject(rid, self.clock())
            raise
        self._futures[rid] = fut
        self._wake.set()
        return await fut

    async def drain(self) -> None:
        """Wait until every accepted request has completed."""
        while self._futures or self.engine.pending():
            await asyncio.sleep(0)
            if self._futures:
                await asyncio.wait(list(self._futures.values()),
                                   timeout=0.05)

    async def _serve(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            if self.engine.pending() == 0:
                if self._closing:
                    return
                await self._wake.wait()
                self._wake.clear()
                continue
            # let a partial batch fill: yield to the loop briefly when
            # the queue has not reached the largest bucket yet
            if (self.max_wait_s > 0
                    and self.engine.pending() < self.engine.grid.max_bucket):
                await asyncio.sleep(self.max_wait_s)
            out, _ = await loop.run_in_executor(
                None, lambda: self.engine.step(now=self.clock()))
            for rid, row in out:
                fut = self._futures.pop(rid, None)
                if fut is not None and not fut.done():
                    fut.set_result(row)


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def _build_engine(args):
    import jax
    from repro.core import network_layers, scale_layers
    from repro.core.model import ConvLayer
    from repro.models import layers as mlayers
    from repro.models.base import init_params

    if args.net:
        topo = scale_layers(network_layers(args.net), args.scale)
    else:                       # smoke topology: small, fast, 3 layers
        topo = [ConvLayer("s0", ifmap=16, in_channels=3, out_channels=8,
                          kernel=3, stride=1, padding=1),
                ConvLayer("s1", ifmap=16, in_channels=8, out_channels=8,
                          kernel=3, stride=2, padding=1),
                ConvLayer("s2", ifmap=8, in_channels=8, out_channels=16,
                          kernel=3, stride=1, padding=1)]
    params = init_params(
        mlayers.cnn_params_from_layers(topo, n_classes=args.classes),
        jax.random.PRNGKey(0))
    buckets = tuple(int(b) for b in args.buckets.split(","))
    engine = ServingEngine.for_topology(
        topo, params, buckets=buckets, n_replicas=args.replicas,
        fused=args.fused, max_queue=args.max_queue)
    t0 = time.perf_counter()
    recs = engine.prewarm()
    n_tuned = sum(len(r["layers"]) for r in recs.values())
    print(f"prewarm: {len(buckets)} buckets x {len(topo)} layers "
          f"({n_tuned} tune records"
          f"{', fused groups seeded' if args.fused else ''}) + "
          f"{len(buckets) * args.replicas} compiles in "
          f"{time.perf_counter() - t0:.2f}s — no request hits a cold "
          "tune or first-call compile")
    return engine, topo


async def _run(args) -> None:
    from repro.testing.load import poisson_arrivals

    engine, topo = _build_engine(args)
    shape = (topo[0].ifmap, topo[0].ifmap, topo[0].in_channels)
    rng = np.random.default_rng(args.seed)
    xs = rng.standard_normal((args.requests,) + shape).astype(np.float32)
    arrivals = poisson_arrivals(args.rate, args.requests, seed=args.seed)

    async with AsyncConvServer(engine,
                               max_wait_s=args.max_wait_ms / 1e3) as srv:
        t0 = time.monotonic()

        async def client(i: int):
            await asyncio.sleep(max(0.0, t0 + arrivals[i]
                                    - time.monotonic()))
            try:
                return await srv.submit(xs[i])
            except QueueFull:
                return None

        outs = await asyncio.gather(*[client(i)
                                      for i in range(args.requests)])

    served = [o for o in outs if o is not None]
    s = engine.recorder.summary()
    st = engine.stats()
    print(f"served {len(served)}/{args.requests} "
          f"(rejected {st['rejected']}) at "
          f"{s.get('throughput_rps', 0.0):.1f} req/s — "
          f"p50 {s.get('p50_s', 0.0) * 1e3:.2f}ms "
          f"p99 {s.get('p99_s', 0.0) * 1e3:.2f}ms; "
          f"bucket batches {st['bucket_batches']}; "
          f"cold tunes {st['cold_tunes']}")
    for name, rep in st["replicas"].items():
        if rep["degraded"]:
            falls = ";".join(f"{e['tier']}->{e['to']}"
                             for e in rep["guard_events"])
            print(f"DEGRADED {name}: kept serving via {falls}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--net", default=None,
                    choices=["vgg16", "alexnet", "mobilenet"],
                    help="serve a scaled paper topology (default: a "
                         "small smoke CNN)")
    ap.add_argument("--scale", type=int, default=32,
                    help="channel divisor for --net")
    ap.add_argument("--fused", action="store_true",
                    help="serve fused residency-group megakernels "
                         "(DESIGN.md §8)")
    ap.add_argument("--buckets", default="1,2,4",
                    help="comma-separated batch bucket grid")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel serving replicas")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="how long a partial batch waits to fill")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run (CI)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.net, args.requests = None, min(args.requests, 8)
        args.rate = min(args.rate, 500.0)
    asyncio.run(_run(args))


if __name__ == "__main__":
    main()
