"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without real hardware:
``.lower().compile()`` must succeed on the single-pod (16,16) mesh and the
2-pod (2,16,16) mesh for every assigned cell; ``memory_analysis()`` proves
the state fits per-chip HBM; ``cost_analysis()`` + the HLO collective
parse feed §Roofline.

Cost methodology (see EXPERIMENTS.md §Dry-run): XLA's HloCostAnalysis
counts while-loop bodies once, so scanned layer stacks would be
undercounted.  Each cell therefore runs

  1. the FULL compile (scan over layers, real microbatching) -> memory
     analysis + the production collective schedule, and
  2. two small Δ-compiles with 1 and 2 *unrolled* layers (n_micro=1)
     -> per-layer flop/byte/collective deltas, extrapolated:
         cost(L) = cost(1) + (L-1) * (cost(2) - cost(1))
     (hybrid archs solve per-kind deltas from 4 compiles).

Validation of the extrapolation against a fully-unrolled compile is in
tests/test_dryrun_validation.py.
"""

# The VERY FIRST lines, before any other import (jax locks the device
# count on first init):
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import registry                      # noqa: E402
from repro.core import roofline as rl                   # noqa: E402
from repro.distributed import steps                     # noqa: E402
from repro.distributed.sharding import make_rules       # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.models import api                            # noqa: E402
from repro.models.base import abstract_params, tree_bytes_per_dev  # noqa: E402
from repro.optim import AdamWConfig                     # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts")


def _opt_cfg(plan):
    return AdamWConfig(moment_dtype=jnp.bfloat16
                       if plan.moment_dtype == "bfloat16" else jnp.float32)


def _rules(plan, mesh):
    return make_rules(fsdp=plan.fsdp, **plan.rules_overrides)


def build_cell(cfg, plan, mesh, *, n_micro=None, delta_mode=False):
    """Returns (jitted, arg_specs) ready to .lower(*arg_specs)."""
    rules = _rules(plan, mesh)
    exec_over = dict(dtype="bfloat16")
    if delta_mode:
        # unrolled layers + unrolled chunks for flop counting; chunk sizes
        # are raised so at most ~8 chunks unroll (identical flops, far
        # smaller HLO -> tractable compile on this 1-core container)
        exec_over.update(unroll_layers=True, attn_impl="chunked_unroll",
                         attn_chunk=max(cfg.attn_chunk, plan.seq // 8),
                         scan_chunk=max(cfg.scan_chunk, plan.seq // 8))
    cfg = cfg.replace(**exec_over)
    batch = registry.input_specs(cfg, plan)
    b_shard = steps.batch_shardings(batch, mesh, rules)
    batch = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        batch, b_shard)

    if plan.kind == "train":
        nm = n_micro if n_micro is not None else plan.n_micro
        # a microbatch must cover every batch shard (pod x data), else the
        # partitioner falls back to replication inside the micro loop
        baxes = rules.get("batch", ("pod", "data"))
        baxes = (baxes,) if isinstance(baxes, str) else baxes
        shards = 1
        for ax in baxes:
            shards *= mesh.shape.get(ax, 1)
        nm = max(1, min(nm, plan.batch // max(shards, 1)))
        opt_cfg = _opt_cfg(plan)
        decl = steps.train_state_decl(cfg, opt_cfg)
        st_shard = steps.state_shardings(decl, mesh, rules)
        state = abstract_params(decl, mesh, rules, jnp.bfloat16)
        accum = jnp.bfloat16 if plan.accum_dtype == "bfloat16" \
            else jnp.float32
        fn = steps.make_train_step(cfg, opt_cfg, rules,
                                   1 if delta_mode else nm,
                                   accum_dtype=accum)
        jitted = jax.jit(fn, in_shardings=(st_shard, b_shard),
                         out_shardings=(st_shard, None),
                         donate_argnums=(0,))
        return jitted, (state, batch)

    params_decl = api.params(cfg)
    p_shard = steps.state_shardings(params_decl, mesh, rules)
    params = abstract_params(params_decl, mesh, rules, jnp.bfloat16)

    if plan.kind == "prefill":
        fn = steps.make_prefill_step(cfg, rules)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        return jitted, (params, batch)

    # decode
    state_decl = api.decode_state(cfg, plan.batch, plan.seq)
    st_shard = steps.state_shardings(state_decl, mesh, rules)
    state = abstract_params(state_decl, mesh, rules, jnp.bfloat16)
    fn = steps.make_decode_step(cfg, rules)
    jitted = jax.jit(fn, in_shardings=(p_shard, st_shard, b_shard),
                     out_shardings=(None, st_shard), donate_argnums=(1,))
    return jitted, (params, state, batch)


def _costs(compiled, n_dev):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    colls = rl.parse_collectives(compiled.as_text(), n_dev)
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)),
                coll=dict(colls.by_kind))


def _combine(base, delta, n_extra):
    out = dict(flops=base["flops"] + n_extra * delta["flops"],
               bytes=base["bytes"] + n_extra * delta["bytes"], coll={})
    kinds = set(base["coll"]) | set(delta["coll"])
    for k in kinds:
        out["coll"][k] = base["coll"].get(k, 0.0) \
            + n_extra * delta["coll"].get(k, 0.0)
    return out


def delta_extrapolate(cfg, plan, mesh):
    """Per-layer Δ-cost extrapolation (see module docstring)."""
    n_dev = mesh.size
    if cfg.family == "hybrid":
        sizes = [1, 2, 3, 6]
        c = {}
        for L in sizes:
            pat = cfg.block_pattern
            sub = cfg.replace(n_layers=L)
            jitted, args = build_cell(sub, plan, mesh, delta_mode=True)
            with mesh:
                c[L] = _costs(jitted.lower(*args).compile(), n_dev)
        d_rec = _combine(c[2], c[1], -1)            # c2 - c1
        d3 = _combine(c[6], c[3], -1)               # 2*rec + att
        d_att = _combine(d3, d_rec, -2)
        base = _combine(c[1], d_rec, -1)
        n_att = sum(1 for i in range(cfg.n_layers)
                    if cfg.pattern_at(i) == "att")
        n_rec = cfg.n_layers - n_att
        total = _combine(_combine(base, d_rec, n_rec), d_att, n_att)
        return total
    if cfg.family == "encdec":
        c1 = _delta_compile(cfg.replace(enc_layers=1, dec_layers=1,
                                        n_layers=2), plan, mesh)
        c2 = _delta_compile(cfg.replace(enc_layers=2, dec_layers=2,
                                        n_layers=4), plan, mesh)
        delta = _combine(c2, c1, -1)
        return _combine(c1, delta, cfg.enc_layers - 1)
    c1 = _delta_compile(cfg.replace(n_layers=1), plan, mesh)
    c2 = _delta_compile(cfg.replace(n_layers=2), plan, mesh)
    delta = _combine(c2, c1, -1)
    return _combine(c1, delta, cfg.n_layers - 1)


def _delta_compile(cfg, plan, mesh):
    jitted, args = build_cell(cfg, plan, mesh, delta_mode=True)
    with mesh:
        return _costs(jitted.lower(*args).compile(), mesh.size)


def analytic_hbm_bytes(cfg, plan, mesh, rules, opt_cfg) -> float:
    """Compulsory per-device HBM traffic per step (fused-TPU model).

    The CPU backend's ``bytes accessed`` counts unfused operator traffic
    and overestimates a fused TPU executable by ~10x, so the roofline
    memory term uses this analytic minimum instead (HLO bytes are kept in
    the record as an upper bound).  Terms:

      train   n_micro * 2 * P  (fwd+bwd weight reads per microbatch)
              + 2 * (P + Mu + Nu)   (optimizer read+write)
              + 3 * Act             (save, bwd read, recompute write)
              + logits traffic
      prefill P + 2 * Act + KV-cache write
      decode  P + KV/state read    (the classic decode bound)
    """
    p_dev = tree_bytes_per_dev(api.params(cfg), mesh, rules, 2)
    baxes = rules.get("batch", ("pod", "data"))
    baxes = (baxes,) if isinstance(baxes, str) else baxes
    bshards = 1
    for ax in baxes:
        if ax in mesh.shape:
            bshards *= mesh.shape[ax]
    bshards = min(bshards, plan.batch)
    d_act = cfg.d_inner if cfg.family == "ssm" else cfg.d_model
    vocab_shards = mesh.shape.get("model", 1) if cfg.vocab % \
        mesh.shape.get("model", 1) == 0 else 1

    if plan.kind == "decode":
        state_dev = tree_bytes_per_dev(
            api.decode_state(cfg, plan.batch, plan.seq), mesh, rules, 2)
        return p_dev + state_dev
    tokens_dev = plan.batch * plan.seq / bshards
    act = cfg.n_layers * tokens_dev * d_act * 2
    logits = tokens_dev * (cfg.vocab / vocab_shards) * 4
    if plan.kind == "train":
        mom = 2 * p_dev * (1 if opt_cfg.moment_dtype == jnp.bfloat16 else 2)
        return (plan.n_micro * 2 * p_dev + 2 * (p_dev + mom)
                + 3 * act + 2 * logits)
    cache_dev = tree_bytes_per_dev(
        api.decode_state(cfg, plan.batch, plan.seq), mesh, rules, 2)
    return p_dev + 2 * act + logits + cache_dev


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             skip_delta: bool = False) -> dict:
    mod = registry.get(arch)
    cfg, plan = mod.CONFIG, mod.PLANS[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}/{shape}/{mesh_name}"
    if plan.skip:
        return {"cell": cell_id, "status": "skip", "reason": plan.skip}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    jitted, args = build_cell(cfg, plan, mesh)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    full = _costs(compiled, mesh.size)
    row = {
        "cell": cell_id, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "n_devices": mesh.size,
        "kind": plan.kind,
        "memory": {
            "argument_gib": mem.argument_size_in_bytes / 2**30,
            "output_gib": mem.output_size_in_bytes / 2**30,
            "temp_gib": mem.temp_size_in_bytes / 2**30,
            "peak_gib": (mem.temp_size_in_bytes
                         + mem.argument_size_in_bytes) / 2**30,
        },
        "full_compile_costs": full,
        "model_flops_total": registry.model_flops(cfg, plan),
    }
    if not skip_delta:
        t1 = time.time()
        row["costs"] = delta_extrapolate(cfg, plan, mesh)
        row["delta_compile_s"] = round(time.time() - t1, 1)
    else:
        row["costs"] = full
    rules = _rules(plan, mesh)
    bytes_min = analytic_hbm_bytes(cfg.replace(dtype="bfloat16"), plan,
                                   mesh, rules, _opt_cfg(plan))
    row["hbm_bytes_hlo_upper"] = row["costs"]["bytes"]
    row["hbm_bytes_analytic"] = bytes_min
    terms = rl.RooflineTerms(
        cell=cell_id,
        flops_per_dev=row["costs"]["flops"],
        hbm_bytes_per_dev=bytes_min,
        coll_bytes_per_dev=sum(row["costs"]["coll"].values()),
        coll_by_kind=row["costs"]["coll"],
        peak_memory_bytes=(mem.temp_size_in_bytes
                           + mem.argument_size_in_bytes),
        model_flops_per_dev=row["model_flops_total"] / mesh.size,
    )
    row["roofline"] = terms.as_row()
    return row


_DEFAULT_OUT = None


def _persist(results, out):
    global _DEFAULT_OUT
    if out is None:
        if _DEFAULT_OUT is None:
            _DEFAULT_OUT = os.path.join(os.path.abspath(ARTIFACTS),
                                        f"dryrun_{int(time.time())}.json")
        out = _DEFAULT_OUT
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--skip-delta", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    arch_list = registry.archs() if args.arch == "all" else [args.arch]
    shape_list = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
                  if args.shape == "all" else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in arch_list:
        for shape in shape_list:
            for mp in meshes:
                try:
                    row = run_cell(arch, shape, multi_pod=mp,
                                   skip_delta=args.skip_delta)
                except Exception as e:  # a failure here is a system bug
                    row = {"cell": f"{arch}/{shape}/"
                           f"{'pod2x16x16' if mp else 'pod16x16'}",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                status = row["status"]
                extra = ""
                if status == "ok":
                    r = row["roofline"]
                    extra = (f" compile={row['compile_s']}s "
                             f"peak={r['peak_memory_gib']:.2f}GiB "
                             f"dom={r['dominant']}"
                             f" frac={r['roofline_fraction']:.3f}")
                print(f"[{status}] {row['cell']}{extra}", flush=True)
                if status == "error":
                    print(row["trace"], flush=True)
                results.append(row)
                _persist(results, args.out)

    out = _persist(results, args.out)
    print("wrote", out)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"cells: {n_ok} ok, {n_skip} documented skips, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
