"""Force N host CPU devices — must run before the first jax import.

XLA reads ``--xla_force_host_platform_device_count`` once, at backend
initialization, so every entry point that wants a multi-device CPU run
(examples, the ``--shard`` benchmark, the multidevice test harness) has
to set the flag before anything imports jax.  This module is therefore
deliberately jax-free: entry scripts import it first, call the helper,
and only then import jax.
"""

from __future__ import annotations

import os
import sys

FLAG = "xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> None:
    """Append the host-device flag to XLA_FLAGS (no-op for n <= 1 or
    when a count is already forced, e.g. by the caller's environment)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} --{FLAG}={n}".strip()


def force_host_device_count_from_argv(flag: str = "--devices") -> None:
    """Read ``--devices N`` / ``--devices=N`` straight from ``sys.argv``
    (argparse runs far too late — jax is imported at module scope) and
    force N devices."""
    argv = sys.argv
    for i, tok in enumerate(argv):
        if tok == flag and i + 1 < len(argv):
            force_host_device_count(int(argv[i + 1]))
            return
        if tok.startswith(flag + "="):
            force_host_device_count(int(tok.split("=", 1)[1]))
            return
