"""Batched serving driver: prefill a batch of prompts, then greedy decode
with the per-family state (KV caches / SSM states / ring buffers).

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --smoke --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.models.base import init_params


def serve_batch(cfg, params, prompts: jnp.ndarray, gen: int, rules,
                greedy: bool = True):
    """prompts: (B, P) int32.  Returns (B, P+gen) generated sequences."""
    b, p = prompts.shape
    max_len = p + gen + 1
    state = init_params(api.decode_state(cfg, b, max_len),
                        jax.random.PRNGKey(0), jnp.float32)
    decode = jax.jit(steps.make_decode_step(cfg, rules),
                     donate_argnums=(1,))
    seqs = [prompts]
    # prefill token-by-token through the decode path (state-exact for every
    # family; a fused prefill kernel is the production fast path)
    tok = prompts[:, :1]
    for t in range(1, max_len):
        batch = {"tokens": tok,
                 "cache_len": jnp.full((b,), t, jnp.int32)}
        nxt, state = decode(params, state, batch)
        if t < p:                      # still consuming the prompt
            tok = prompts[:, t:t + 1]
        else:
            tok = nxt[:, None]
            seqs.append(tok)
        if len(seqs) == gen + 1:
            break
    return jnp.concatenate(seqs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    mod = registry.get(args.arch)
    cfg = (mod.SMOKE if args.smoke else mod.CONFIG).replace(dtype="float32")
    if cfg.family == "encdec":
        raise SystemExit("use examples/serve_lm.py for enc-dec serving")
    rules = make_rules()
    mesh = make_host_mesh(model=args.model_parallel)
    with mesh:
        params = init_params(api.params(cfg), jax.random.PRNGKey(0),
                             jnp.float32)
        prompts = jnp.asarray(
            np.random.default_rng(0).integers(2, cfg.vocab,
                                              (args.batch, args.prompt_len)),
            jnp.int32)
        t0 = time.time()
        out = serve_batch(cfg, params, prompts, args.gen, rules)
        out.block_until_ready()
        dt = time.time() - t0
    n_tok = args.batch * args.gen
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s batch-aggregate)")
    print("sample:", np.asarray(out[0])[:24])


if __name__ == "__main__":
    main()
