"""pjit train / prefill / decode step builders.

``make_train_step`` implements: microbatched gradient accumulation
(lax.scan over microbatches — keeps the gradient all-reduce off the
critical path: SPMD materializes it once, after the last microbatch),
global-norm clipping, AdamW with sharded moments, and donation of the
train state.  All sharding is expressed through NamedShardings derived
from the Param declarations + logical rules, so the same code runs on the
single-pod and multi-pod production meshes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import api
from repro.models.base import Param, param_pspecs
from repro.models.config import ModelConfig
from repro.optim import adamw


# ---------------------------------------------------------------------------
# State declaration
# ---------------------------------------------------------------------------

def train_state_decl(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig) -> dict:
    p = api.params(cfg)
    moment = lambda q: Param(q.shape, q.axes, init="zeros",
                             dtype=opt_cfg.moment_dtype)
    is_p = lambda x: isinstance(x, Param)
    return {
        "params": p,
        "opt": {"mu": jax.tree.map(moment, p, is_leaf=is_p),
                "nu": jax.tree.map(moment, p, is_leaf=is_p)},
        "step": Param((), (), init="zeros", dtype=jnp.int32),
    }


def state_shardings(decl, mesh, rules):
    specs = param_pspecs(decl, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_tree, mesh, rules):
    axes = rules.get("batch", ("pod", "data"))
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.shape)

    def spec_for(leaf):
        valid, extent = [], 1
        size = leaf.shape[0] if hasattr(leaf, "shape") and leaf.shape \
            else None
        for ax in axes:
            if size is not None and size % (extent * mesh.shape[ax]) != 0:
                break
            valid.append(ax)
            extent *= mesh.shape[ax]
        if not valid:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, P(tuple(valid) if len(valid) > 1 else valid[0]))

    return jax.tree.map(spec_for, batch_tree)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    rules: dict, n_micro: int = 1,
                    accum_dtype=jnp.float32):
    def loss_for(params, mb):
        logits, aux = api.forward(params, mb, cfg, rules)
        return api.loss_fn(logits, mb["labels"], aux)

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, grads) = jax.value_and_grad(loss_for)(params, batch)
        else:
            def resh(x):
                return x.reshape(n_micro, x.shape[0] // n_micro,
                                 *x.shape[1:])
            micro = jax.tree.map(resh, batch)

            def acc_fn(carry, mb):
                loss_acc, gacc = carry
                l, g = jax.value_and_grad(loss_for)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (loss_acc + l, gacc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        new_params, new_opt, metrics = adamw.apply_updates(
            params, grads, state["opt"], state["step"], opt_cfg)
        metrics["loss"] = loss
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def jit_train_step(cfg, opt_cfg, rules, mesh, *, n_micro: int = 1,
                   batch_tree: dict | None = None):
    """jit with explicit in/out shardings and state donation."""
    decl = train_state_decl(cfg, opt_cfg)
    st_shard = state_shardings(decl, mesh, rules)
    step = make_train_step(cfg, opt_cfg, rules, n_micro)
    b_shard = batch_shardings(batch_tree or {"tokens": 0, "labels": 0},
                              mesh, rules)
    return jax.jit(step,
                   in_shardings=(st_shard, b_shard),
                   out_shardings=(st_shard, None),
                   donate_argnums=(0,)), decl, st_shard


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, rules: dict):
    def prefill(params, batch):
        logits, aux = api.forward(params, batch, cfg, rules)
        # next-token from the last position (greedy)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return logits, next_tok
    return prefill


def make_decode_step(cfg: ModelConfig, rules: dict):
    def decode(params, state, batch):
        logits, new_state = api.decode(params, batch, state, cfg, rules)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_state
    return decode


def jit_decode_step(cfg, rules, mesh, batch: int, max_len: int):
    state_decl = api.decode_state(cfg, batch, max_len)
    st_shard = state_shardings(state_decl, mesh, rules)
    params_decl = api.params(cfg)
    p_shard = state_shardings(params_decl, mesh, rules)
    step = make_decode_step(cfg, rules)
    baxes = batch_shardings({"tokens": 0, "cache_len": 0}, mesh, rules)
    return (jax.jit(step,
                    in_shardings=(p_shard, st_shard, baxes),
                    out_shardings=(None, st_shard),
                    donate_argnums=(1,)),
            params_decl, state_decl, p_shard, st_shard)
