"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / SP / FSDP).

Production meshes (launch/mesh.py):
  single-pod: (16, 16)    = ('data', 'model')
  multi-pod:  (2, 16, 16) = ('pod', 'data', 'model')

The 'pod' axis composes with 'data' for batch sharding, so scaling out is
adding pod extent; cross-pod traffic is only the gradient all-reduce.
FSDP ('zero3') additionally shards the parameters' embed dim over 'data'
(kept *within* a pod so parameter all-gathers never cross pods).
"""

from __future__ import annotations

BASE_RULES: dict = {
    # activations
    "batch": ("pod", "data"),
    "act_embed": None,
    "tokens": ("pod", "data"),    # flattened token dim (MoE dispatch)
    "seq": None,                  # set to 'data' for sequence parallelism
    # params
    "embed": None,                # set to 'data' by fsdp=True (ZeRO-3)
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",           # expert parallelism
    "layers": None,
}


def make_rules(fsdp: bool = False, seq_parallel: bool = False,
               **overrides) -> dict:
    rules = dict(BASE_RULES)
    if fsdp:
        rules["embed"] = ("data", "pod")
    if seq_parallel:
        rules["seq"] = "data"
    rules.update(overrides)
    return rules


# ---------------------------------------------------------------------------
# Conv sharding rules (spatial parallelism — DESIGN.md §6)
# ---------------------------------------------------------------------------
#
# The conv path has exactly two shardable logical axes: the batch (data
# parallelism over images) and the output H-strips (spatial parallelism —
# the multi-device image of the kernel's on-chip strips, whose K-1
# boundary rows become a real neighbor halo exchange).  Channels stay
# unsharded: the TrIM dataflow keeps a full Cin slice resident per strip.

CONV_RULES: dict = {
    "batch": ("pod", "data"),     # images -> data axis
    "strips": "model",            # output H-strips -> model axis
}


def make_conv_rules(**overrides) -> dict:
    """Conv rules with overrides (e.g. ``strips=None`` to disable spatial
    parallelism, or ``strips="data"`` on a spatial-only mesh)."""
    rules = dict(CONV_RULES)
    rules.update(overrides)
    return rules


def batch_spec(mesh, rules):
    from jax.sharding import NamedSharding, PartitionSpec
    axes = rules.get("batch", ("pod", "data"))
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.shape)
    return NamedSharding(mesh, PartitionSpec(axes if len(axes) > 1
                                             else (axes[0] if axes else None)))
