from repro.distributed import sharding, steps  # noqa: F401
