from repro.checkpoint.manager import (CheckpointCorruptError,  # noqa: F401
                                      CheckpointManager)
