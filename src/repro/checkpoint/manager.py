"""Fault-tolerant checkpointing: atomic, resumable, mesh-elastic.

Layout: ``<dir>/step_<N>/`` holding one ``arrays.npz`` (flattened
key -> array) and ``manifest.json`` (step, config hash, data-iterator
state, mesh shape, rng).  Writes go to ``step_<N>.tmp`` and are
``os.rename``d into place, so a crash mid-write never corrupts the latest
checkpoint; ``restore`` picks the newest complete step.

Elasticity: arrays are stored unsharded (single-process container); on
restore they are ``device_put`` against the *current* mesh's shardings, so
a job can come back on a different mesh shape (tested in
tests/test_checkpoint.py).  The multi-host production path (shard-per-host
files + index) keeps the same manifest contract.

Integrity (DESIGN.md §9): ``save`` writes a ``sha256.json`` sidecar
(digest per payload file) inside the temp dir before the atomic publish;
``restore`` verifies the digests *before* deserializing and raises
:class:`CheckpointCorruptError` on any mismatch — a bit-flip or
truncation surfaces as a diagnosable integrity error, not a zipfile
traceback.  ``restore(..., verify=False)`` is the escape hatch for
salvaging a damaged checkpoint; checkpoints from before the sidecar
existed restore with a warning.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import warnings

import jax
import numpy as np

#: files whose digests the sha256 sidecar covers
_PAYLOAD_FILES = ("arrays.npz", "manifest.json")

# patchable alias: the fault harness (repro.testing.faults) swaps this
# to simulate a crash after the temp write but before the publish
_publish = os.rename


class CheckpointCorruptError(RuntimeError):
    """A checkpoint payload does not match its sha256 sidecar."""


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat):
    def rebuild(t, prefix=""):
        if isinstance(t, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix[:-1]]
    return rebuild(template)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------
    def save(self, step: int, state, *, meta: dict | None = None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
                  if hasattr(v, "shape")}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {"step": step, "keys": sorted(arrays.keys())}
        manifest.update(meta or {})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, default=str)
        # integrity sidecar, written before the publish so a published
        # step always carries its digests
        digests = {name: _sha256(os.path.join(tmp, name))
                   for name in _PAYLOAD_FILES}
        with open(os.path.join(tmp, "sha256.json"), "w") as f:
            json.dump(digests, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        _publish(tmp, final)                        # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, name,
                                                    "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify_step(self, step: int) -> None:
        """Check the step's payload files against the sha256 sidecar.

        Raises :class:`CheckpointCorruptError` on any mismatch or
        missing payload.  Checkpoints written before the sidecar existed
        (no ``sha256.json``) warn and pass unverified.
        """
        path = os.path.join(self.dir, f"step_{step:08d}")
        sidecar = os.path.join(path, "sha256.json")
        if not os.path.exists(sidecar):
            warnings.warn(
                f"checkpoint step {step} predates integrity sidecars "
                "(no sha256.json) — restoring unverified", RuntimeWarning,
                stacklevel=2)
            return
        with open(sidecar) as f:
            digests = json.load(f)
        for name, want in digests.items():
            fpath = os.path.join(path, name)
            if not os.path.exists(fpath):
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: payload {name} missing")
            got = _sha256(fpath)
            if got != want:
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: {name} sha256 mismatch "
                    f"(stored {want[:12]}…, actual {got[:12]}…) — the "
                    "file is corrupt (bit-flip/truncation); restore an "
                    "older step or pass verify=False to salvage")

    def restore(self, state_template, step: int | None = None,
                shardings=None, *, verify: bool = True):
        """Rebuild ``state_template``'s structure with stored arrays.

        ``shardings``: optional matching tree of NamedShardings for the
        *current* mesh (elastic restart).  ``verify=True`` (default)
        checks the sha256 sidecar *before* deserializing and raises
        :class:`CheckpointCorruptError` on corruption; ``verify=False``
        skips the check (salvage escape hatch).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        if verify:
            self.verify_step(step)
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {k: data[k] for k in data.files}
        state = _unflatten_into(state_template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return state, manifest
