"""End-to-end behaviour tests: train a tiny LM on the learnable synthetic
task, checkpoint mid-run, serve greedily from the trained weights."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticStream
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.models import ModelConfig, api
from repro.models.base import init_params
from repro.optim import AdamWConfig

RULES = make_rules()


def test_end_to_end_train_checkpoint_serve(tmp_path):
    cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=160, vocab=32, attn_impl="ref",
                      remat=False)
    opt = AdamWConfig(lr=3e-2, warmup_steps=5, decay_steps=200)
    dc = DataConfig(batch=16, seq=32, vocab=32, task="copy", seed=0)
    stream = SyntheticStream(dc)
    step = jax.jit(steps.make_train_step(cfg, opt, RULES))
    state = init_params(steps.train_state_decl(cfg, opt),
                        jax.random.PRNGKey(0), jnp.float32)

    mgr = CheckpointManager(str(tmp_path))
    losses = []
    for i in range(120):
        batch = jax.tree.map(jnp.asarray, next(stream))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if i == 20:
            mgr.save(i, state, meta={"data_state": stream.state()})
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
    assert mgr.latest_step() == 20

    # greedy serving from the trained weights: the copy task is predictable
    # in its second half, so decode should reproduce the copied prefix.
    params = state["params"]
    toks = jax.tree.map(jnp.asarray, next(stream))["tokens"][:2]
    half = 16
    prefix = toks[:, :half]
    logits, _ = api.forward(params, {"tokens": prefix}, cfg, RULES)
    # teacher-forced continuation accuracy on the copy region
    full_logits, _ = api.forward(params, {"tokens": toks}, cfg, RULES)
    pred = jnp.argmax(full_logits[:, half - 1:-1], -1)
    target = toks[:, half:]
    acc = float((pred == target).mean())
    assert acc > 0.10, f"copy accuracy {acc} (chance ~1/32)"


def test_decode_step_jit_and_state_donation():
    cfg = ModelConfig(family="dense", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=1, d_ff=64, vocab=32, attn_impl="ref",
                      remat=False)
    params = init_params(api.params(cfg), jax.random.PRNGKey(0), jnp.float32)
    decode = jax.jit(steps.make_decode_step(cfg, RULES), donate_argnums=(1,))
    state = init_params(api.decode_state(cfg, 2, 8), jax.random.PRNGKey(1),
                        jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for t in range(1, 6):
        batch = {"tokens": tok, "cache_len": jnp.full((2,), t, jnp.int32)}
        nxt, state = decode(params, state, batch)
        tok = nxt[:, None]
    assert tok.shape == (2, 1)
    assert int(tok.max()) < cfg.vocab
