"""Differential tests for the fused residency-group executor
(DESIGN.md §8): the megakernel must be a *pure perf transform* — fused
== per-layer == ref forward (bitwise for the Pallas pair, 1e-5 vs the
XLA oracle) and gradients, across a topology x dataflow x residency
grid — plus the depth-1 fallback, packed-params rejection and shape
validation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fuse_plan import FusedGroupPlan, build_group
from repro.core.model import ConvLayer
from repro.core.netplan import network_layers, scale_layers
from repro.kernels.trim_conv2d_fused import (fused_group_apply,
                                             reference_chain)
from repro.models import layers as mlayers
from repro.models.base import init_params


def _close(a, b, tol=1e-5):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-9
    assert float(np.abs(a - b).max()) / scale < tol


# hand-rolled chains exercising the geometry corners: 'same' stacks with
# an even pool, a strided-valid head with an overlapping (odd) pool and
# a pointwise tail, and a pool-free stack
def _chain_same():
    return [ConvLayer("c0", 12, 3, 4, 3, 1, 1),
            ConvLayer("c1", 12, 4, 6, 3, 1, 1),     # pool 2/2 -> 6
            ConvLayer("c2", 6, 6, 8, 3, 1, 1)]


def _chain_strided():
    return [ConvLayer("s0", 17, 3, 4, 5, 2, 0),     # valid -> 7, pool 2/3
            ConvLayer("s1", 3, 4, 8, 1, 1, 0),      # pointwise
            ConvLayer("s2", 3, 8, 8, 3, 1, 1)]


def _chain_nopool():
    return [ConvLayer("p0", 9, 2, 4, 3, 1, 1),
            ConvLayer("p1", 9, 4, 4, 3, 1, 1),
            ConvLayer("p2", 9, 4, 6, 3, 1, 1)]


TOPOLOGIES = {
    "same_pool": _chain_same,
    "strided_valid": _chain_strided,
    "nopool": _chain_nopool,
    "alexnet_x32": lambda: scale_layers(network_layers("alexnet"), 32),
}


def _setup(topo_name, n=2, seed=0):
    topo = TOPOLOGIES[topo_name]()
    params = init_params(mlayers.cnn_params_from_layers(topo),
                         jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        (n, topo[0].ifmap, topo[0].ifmap, topo[0].in_channels)),
        jnp.float32)
    return topo, params, x


# ---------------------------------------------------------------------------
# fused_group_apply vs reference_chain (single group, all strip heights)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", ["same_pool", "strided_valid"])
def test_group_apply_bitmatches_reference(topo_name):
    topo, params, x = _setup(topo_name)
    weights = [params[f"conv{i}"]["w"] for i in range(len(topo))]
    biases = [params[f"conv{i}"]["b"] for i in range(len(topo))]
    ref = None
    h_last = build_group(topo, 0, n=x.shape[0]).last.h_pool
    for t in sorted({1, 2, h_last}):
        g = build_group(topo, 0, n=x.shape[0], strip_rows=t)
        y = fused_group_apply(x, weights, biases, group=g)
        if ref is None:
            ref = reference_chain(x, weights, biases, group=g)
            # identical tap order + epilogue: bitwise vs the per-layer
            # Pallas chain, 1e-5 vs the XLA oracle
            assert jnp.array_equal(y, ref), f"strip_rows={t}"
            oracle = reference_chain(x, weights, biases, group=g,
                                     impl="ref")
            _close(y, oracle)
        else:
            assert jnp.array_equal(y, ref), f"strip_rows={t}"


def test_group_apply_gradients_match_reference():
    topo, params, x = _setup("same_pool")
    weights = tuple(params[f"conv{i}"]["w"] for i in range(len(topo)))
    biases = tuple(params[f"conv{i}"]["b"] for i in range(len(topo)))
    g = build_group(topo, 0, n=x.shape[0], strip_rows=2)

    def loss_fused(x_, ws, bs):
        return (fused_group_apply(x_, list(ws), list(bs),
                                  group=g) ** 2).sum()

    def loss_ref(x_, ws, bs):
        return (reference_chain(x_, ws, bs, group=g) ** 2).sum()

    gf = jax.grad(loss_fused, argnums=(0, 1, 2))(x, weights, biases)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, weights, biases)
    for a, b in zip(jax.tree_util.tree_leaves(gf),
                    jax.tree_util.tree_leaves(gr)):
        _close(a, b)


# ---------------------------------------------------------------------------
# whole-network: fused == per-layer == ref across the grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("dataflow", ["carry", "halo"])
@pytest.mark.parametrize("residency", ["always", "auto", "never"])
def test_network_fused_matches_per_layer(topo_name, dataflow, residency):
    topo, params, x = _setup(topo_name)
    plan = FusedGroupPlan.build(topo, n=x.shape[0], residency=residency,
                                dataflow=dataflow)
    ref = mlayers.cnn_apply_from_layers(params, topo, x)
    fus = mlayers.cnn_apply_from_layers(params, topo, x, fuse_plan=plan)
    assert jnp.array_equal(ref, fus), \
        (topo_name, dataflow, residency,
         [(g.start, g.depth) for g in plan.groups])


@pytest.mark.parametrize("topo_name", ["same_pool", "strided_valid"])
def test_network_fused_matches_xla_oracle(topo_name):
    topo, params, x = _setup(topo_name)
    plan = FusedGroupPlan.build(topo, n=x.shape[0], residency="always")
    assert any(g.fused for g in plan.groups), "grid point never fused"
    fus = mlayers.cnn_apply_from_layers(params, topo, x, fuse_plan=plan)
    oracle = mlayers.cnn_apply_from_layers(params, topo, x, impl="ref")
    _close(fus, oracle)


def test_network_fused_gradients_match_per_layer():
    topo, params, x = _setup("same_pool")
    plan = FusedGroupPlan.build(topo, n=x.shape[0], residency="always")
    assert any(g.fused for g in plan.groups)

    gf = jax.grad(lambda p: (mlayers.cnn_apply_from_layers(
        p, topo, x, fuse_plan=plan) ** 2).sum())(params)
    gr = jax.grad(lambda p: (mlayers.cnn_apply_from_layers(
        p, topo, x) ** 2).sum())(params)
    for a, b in zip(jax.tree_util.tree_leaves(gf),
                    jax.tree_util.tree_leaves(gr)):
        _close(a, b)


# ---------------------------------------------------------------------------
# depth-1 fallback + API validation
# ---------------------------------------------------------------------------

def test_depth1_plan_is_per_layer(monkeypatch):
    """max_depth=1 groups must run the ordinary per-layer engine — the
    megakernel is never invoked and outputs are identical."""
    import repro.models.layers as mod
    topo, params, x = _setup("same_pool")
    plan = FusedGroupPlan.build(topo, n=x.shape[0], max_depth=1)
    assert all(not g.fused for g in plan.groups)
    assert plan.executed_hbm_bytes()["total"] == plan.never_hbm_bytes()

    calls = []
    import repro.kernels.trim_conv2d_fused as fmod
    real = fmod.fused_group_apply
    monkeypatch.setattr(fmod, "fused_group_apply",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    ref = mod.cnn_apply_from_layers(params, topo, x)
    fus = mod.cnn_apply_from_layers(params, topo, x, fuse_plan=plan)
    assert not calls, "depth-1 group dispatched the megakernel"
    assert jnp.array_equal(ref, fus)


def test_fused_rejects_packed_params():
    topo, params, x = _setup("same_pool")
    packed = mlayers.cnn_pack_params(params, topo, n=x.shape[0])
    plan = FusedGroupPlan.build(topo, n=x.shape[0], residency="always")
    assert any(g.fused for g in plan.groups)
    with pytest.raises(ValueError, match="packed"):
        mlayers.cnn_apply_from_layers(packed, topo, x, fuse_plan=plan)


def test_fused_rejects_mesh():
    topo, params, x = _setup("same_pool")
    with pytest.raises(ValueError, match="single-device"):
        mlayers.cnn_apply_from_layers(params, topo, x, fused=True,
                                      rules={"batch": "data"})


def test_group_apply_shape_validation():
    topo, params, x = _setup("same_pool")
    weights = [params[f"conv{i}"]["w"] for i in range(len(topo))]
    biases = [params[f"conv{i}"]["b"] for i in range(len(topo))]
    g = build_group(topo, 0, n=x.shape[0])
    with pytest.raises(ValueError, match="weights"):
        fused_group_apply(x, weights[:-1], biases[:-1], group=g)
    with pytest.raises(ValueError, match="stage-0"):
        fused_group_apply(x[:, :-1], weights, biases, group=g)
    bad = list(weights)
    bad[1] = jnp.zeros((5, 5) + weights[1].shape[2:], x.dtype)
    with pytest.raises(ValueError, match="weight"):
        fused_group_apply(x, bad, biases, group=g)


def test_group_apply_none_biases():
    topo, params, x = _setup("nopool")
    weights = [params[f"conv{i}"]["w"] for i in range(len(topo))]
    zeros = [jnp.zeros_like(params[f"conv{i}"]["b"])
             for i in range(len(topo))]
    g = build_group(topo, 0, n=x.shape[0], strip_rows=3)
    y_none = fused_group_apply(x, weights, [None] * len(topo), group=g)
    y_zero = fused_group_apply(x, weights, zeros, group=g)
    assert jnp.array_equal(y_none, y_zero)
