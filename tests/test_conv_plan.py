"""ConvPlan subsystem tests: the plan is the single source of truth for
strip/tile/traffic math — the kernel's actual padded layouts and grids must
be byte-identical to the analytical model, for dense, strided, grouped and
depthwise geometries (VGG-16 and MobileNet layers included)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvPlan, mobilenet_layers, vgg16_layers
from repro.core.conv_plan import Conv1dPlan
from repro.core.roofline import conv_plan_roofline
from repro.kernels import ops, ref
from repro.kernels.trim_conv2d import (hbm_traffic_model, make_plan,
                                       trim_conv2d)

RNG = np.random.default_rng(11)


def _allclose(a, b, tol=2e-3):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-6
    assert float(np.abs(a - b).max()) / scale < tol


# ---------------------------------------------------------------------------
# Plan <-> kernel consistency (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layer", [vgg16_layers()[2], vgg16_layers()[7],
                                   mobilenet_layers()[0],   # depthwise 3x3
                                   mobilenet_layers()[1]])  # pointwise 1x1
def test_plan_is_shared_by_kernel_and_model(layer):
    """Kernel grid geometry and analytical HBM bytes come from the SAME
    ConvPlan for VGG-16 and depthwise MobileNet layers."""
    plan = layer.plan()
    # the plan the kernel executes for these arrays is the same object
    groups = layer.groups
    kplan = make_plan(
        (1, layer.ifmap, layer.ifmap, layer.in_channels),
        (layer.kernel, layer.kernel, layer.in_channels // groups,
         layer.out_channels),
        stride=layer.stride, pad=layer.padding, groups=groups)
    assert plan == kplan
    # grid covers the whole problem exactly
    n, g, strips, co = plan.grid
    assert (n, g) == (1, groups)
    assert strips * plan.th_out >= plan.h_out + plan.delta
    assert co * plan.tile_cout >= plan.cout // groups
    # analytical input bytes == the padded array the kernel DMAs, exactly
    t = plan.hbm_bytes("3dtrim")
    assert t["input"] == math.prod(plan.padded_input_shape) \
        * plan.dtype_bytes
    assert t["output"] == plan.n * plan.h_out * plan.w_out * plan.cout \
        * plan.dtype_bytes
    # roofline reads the same plan
    terms = conv_plan_roofline(layer.name, plan)
    assert terms.hbm_bytes_per_dev == t["total"]
    assert terms.flops_per_dev == plan.flops == layer.macs * 2


def test_traffic_equals_actual_padded_bytes():
    """ConvPlan traffic == the byte counts of the arrays the kernel builds:
    run the kernel and check the padded layouts it asserts against."""
    x = jnp.asarray(RNG.standard_normal((2, 17, 13, 4)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 4, 10)) * .3, jnp.float32)
    plan = make_plan(x.shape, w.shape, stride=2, pad=1, tile_h=4,
                     tile_cout=4)
    out = trim_conv2d(x, w, stride=2, pad=1, tile_h=4, tile_cout=4)
    assert out.shape == (plan.n, plan.h_out, plan.w_out, plan.cout)
    t = plan.hbm_bytes("3dtrim")
    # input: padded array fetched strip-by-strip, each strip exactly once
    assert t["input"] == math.prod(plan.padded_input_shape) * 4
    # output: the useful (sliced) result the caller receives
    assert t["output"] == out.size * 4
    # weights: one full (unpadded) weight stream per strip sweep
    assert t["weights"] == w.size * 4 * plan.g_tiles
    # trim mode re-fetches K-1 halo rows per strip after the first
    halo = plan.hbm_bytes("trim")["input"] - t["input"]
    assert halo == (plan.g_tiles - 1) * (plan.kh - 1) * plan.wp \
        * plan.cin * 4 * plan.n
    _allclose(out, ref.conv2d(jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0))),
                              w, stride=2, padding="valid"))


def test_legacy_traffic_wrapper_delegates_to_plan():
    a = hbm_traffic_model(1, 224, 224, 64, 64, 3, tile_h=8, mode="3dtrim")
    b = hbm_traffic_model(1, 224, 224, 64, 64, 3, tile_h=8, mode="trim")
    plan = ConvPlan(n=1, h=224, w=224, cin=64, cout=64, kh=3, kw=3,
                    tile_h=8)
    assert a == plan.hbm_bytes("3dtrim")
    assert b == plan.hbm_bytes("trim")
    assert b["input"] > a["input"] and a["overhead_pct"] == 0.0


def test_plan_validation():
    with pytest.raises(ValueError):
        ConvPlan(n=1, h=8, w=8, cin=4, cout=8, kh=3, kw=3, stride=2,
                 tile_h=3)              # tile_h not a stride multiple
    with pytest.raises(ValueError):
        ConvPlan(n=1, h=8, w=8, cin=4, cout=9, kh=3, kw=3, groups=2)
    with pytest.raises(ValueError):
        make_plan((1, 8, 8, 4), (3, 3, 4, 8), groups=2)  # cin mismatch
    with pytest.raises(ValueError):
        ConvPlan(n=1, h=8, w=8, cin=4, cout=8, kh=3, kw=3, tile_h=0)
    with pytest.raises(ValueError):
        ConvPlan(n=1, h=8, w=8, cin=4, cout=8, kh=3, kw=3, tile_cout=0)


# ---------------------------------------------------------------------------
# Oversized-strip canonicalization (tile_h > H_out — DESIGN.md §6 fix):
# instead of padding/billing ever more rows that neither dataflow reads
# (inconsistently between carry and halo), any tile_h beyond the
# full-height strip clamps to it, so both dataflows and every consumer
# see one canonical single-strip plan.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataflow", ["carry", "halo"])
@pytest.mark.parametrize("stride", [1, 2, 3])
def test_oversized_tile_h_clamps_canonically(dataflow, stride):
    full = ConvPlan(n=1, h=13, w=9, cin=3, cout=5, kh=3, kw=3,
                    stride=stride, dataflow=dataflow,
                    tile_h=((13 - 3) // stride + 1
                            + (3 - 1) // stride) * stride)
    for oversize in (full.tile_h + stride, 10 * full.tile_h, 997 * stride):
        plan = ConvPlan(n=1, h=13, w=9, cin=3, cout=5, kh=3, kw=3,
                        stride=stride, dataflow=dataflow, tile_h=oversize)
        # identical plan: same padding, same grid, same traffic
        assert plan == full
        assert plan.g_tiles == 1
        assert plan.padded_input_shape == full.padded_input_shape
        assert plan.hbm_bytes() == full.hbm_bytes()
    # both dataflows agree on the clamp (the bug class this fixes:
    # carry and halo padded layouts diverging for tile_h > H_out)
    a = ConvPlan(n=1, h=13, w=9, cin=3, cout=5, kh=3, kw=3, stride=stride,
                 dataflow="carry", tile_h=500 * stride)
    b = ConvPlan(n=1, h=13, w=9, cin=3, cout=5, kh=3, kw=3, stride=stride,
                 dataflow="halo", tile_h=500 * stride)
    assert a.tile_h == b.tile_h
    assert a.padded_input_shape == b.padded_input_shape


@pytest.mark.parametrize("dataflow", ["carry", "halo"])
def test_oversized_tile_h_kernel_matches_oracle(dataflow):
    """The kernel executes the clamped plan correctly for tile_h far
    beyond H_out, for both dataflows and stride > 1."""
    x = jnp.asarray(RNG.standard_normal((2, 11, 9, 4)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 4, 6)) * .3, jnp.float32)
    for stride in (1, 2):
        want = ref.conv2d(x, w, stride=stride, padding="valid")
        got = trim_conv2d(x, w, stride=stride, tile_h=1000 * stride,
                          dataflow=dataflow)
        _allclose(got, want)


def test_oversized_tile_go_clamps():
    """WeightGradPlan mirrors the clamp: a cotangent strip taller than
    the whole cotangent is the full-height strip."""
    plan = ConvPlan.build_weight_grad((1, 12, 10, 4), (3, 3, 4, 6),
                                      stride=2, tile_go=999)
    assert plan.tile_go == plan.h_out
    assert plan.go_tiles == 1
    small = ConvPlan.build_weight_grad((1, 12, 10, 4), (3, 3, 4, 6),
                                       stride=2, tile_go=plan.h_out)
    assert plan == small
    with pytest.raises(ValueError):
        ConvPlan.build_weight_grad((1, 12, 10, 4), (3, 3, 4, 6),
                                   tile_go=0)


# ---------------------------------------------------------------------------
# Kernel edge geometry vs the oracle — both dataflows (the halo-vs-carry
# numerical-equivalence acceptance grid)
# ---------------------------------------------------------------------------

DATAFLOWS = ["carry", "halo"]


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_conv2d_even_kernel_strided(dataflow):
    """stride > 1 with K even exercises the (K-1) % s != 0 row offset."""
    x = jnp.asarray(RNG.standard_normal((1, 18, 15, 5)), jnp.float32)
    for k, s in [(4, 2), (2, 2), (4, 3), (6, 2)]:
        w = jnp.asarray(RNG.standard_normal((k, k, 5, 6)) * .2, jnp.float32)
        _allclose(ops.conv2d(x, w, stride=s, padding="valid",
                             dataflow=dataflow),
                  ref.conv2d(x, w, stride=s, padding="valid"))


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_conv2d_tile_h_not_dividing_h_out(dataflow):
    """h_out = 14 with tile_h in {3, 4, 5}: bottom strips are ragged."""
    x = jnp.asarray(RNG.standard_normal((1, 16, 10, 4)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 4, 8)) * .3, jnp.float32)
    want = ref.conv2d(x, w, padding="valid")
    for th in (3, 4, 5):
        _allclose(trim_conv2d(x, w, tile_h=th, dataflow=dataflow), want)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_conv2d_cout_not_dividing_tile_cout(dataflow):
    """cout = 10 with tile_cout = 4: the last cout tile is zero-padded."""
    x = jnp.asarray(RNG.standard_normal((1, 12, 9, 3)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 3, 10)) * .3, jnp.float32)
    _allclose(trim_conv2d(x, w, tile_cout=4, dataflow=dataflow),
              ref.conv2d(x, w, padding="valid"))


def test_halo_equals_carry_bitwise_across_geometries():
    """The two dataflows consume identical window contents, so they must
    agree exactly (not just to tolerance) across stride/pad/group edges."""
    for (h, w, cin, cout, k, s, pad, g) in [
            (16, 10, 4, 8, 3, 1, 0, 1), (17, 13, 5, 6, 4, 2, 1, 1),
            (12, 11, 8, 8, 3, 2, 1, 4), (9, 9, 6, 6, 5, 3, 2, 2),
            (8, 8, 4, 4, 1, 1, 0, 1)]:
        x = jnp.asarray(RNG.standard_normal((2, h, w, cin)), jnp.float32)
        wt = jnp.asarray(RNG.standard_normal((k, k, cin // g, cout)) * .3,
                         jnp.float32)
        a = trim_conv2d(x, wt, stride=s, pad=pad, groups=g,
                        dataflow="carry")
        b = trim_conv2d(x, wt, stride=s, pad=pad, groups=g,
                        dataflow="halo")
        assert jnp.array_equal(a, b), (h, w, k, s, pad, g)


def test_halo_plan_geometry_and_traffic():
    """Halo plan: overlapping window block, K-1 extra top rows, and the
    plan's own accounting equals the legacy 'trim' mode."""
    plan = ConvPlan(n=1, h=32, w=32, cin=16, cout=32, kh=3, kw=3,
                    tile_h=8, dataflow="halo")
    assert plan.halo_in_block == (1, 8 + 2, plan.wp, 16)
    assert plan.halo_padded_input_shape == \
        (1, 2 + plan.rows_padded, plan.wp, 16)
    assert plan.traffic_mode == "trim"
    assert plan.hbm_bytes() == plan.hbm_bytes("trim")
    carry = ConvPlan(n=1, h=32, w=32, cin=16, cout=32, kh=3, kw=3,
                     tile_h=8)
    assert carry.traffic_mode == "3dtrim"
    assert carry.hbm_bytes() == carry.hbm_bytes("3dtrim")
    # halo pays (g_tiles - 1) * (K-1) extra rows; carry pays none
    assert plan.hbm_bytes()["input"] > carry.hbm_bytes()["input"]
    assert plan.halo_rows() == (plan.g_tiles - 1) * 2
    assert carry.halo_rows() == 0
    # resident sets agree to within the kh=1 scratch floor
    assert abs(plan.vmem_resident_bytes - carry.vmem_resident_bytes) \
        <= plan.wp * plan.cin_per_group * plan.dtype_bytes
    with pytest.raises(ValueError):
        ConvPlan(n=1, h=8, w=8, cin=4, cout=8, kh=3, kw=3,
                 dataflow="weird")


# ---------------------------------------------------------------------------
# Grouped / depthwise + fused epilogue (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("groups,cin,cout", [(2, 8, 6), (4, 8, 8),
                                             (8, 8, 8), (8, 8, 16)])
def test_grouped_conv_vs_oracle(groups, cin, cout):
    x = jnp.asarray(RNG.standard_normal((2, 12, 11, cin)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, cin // groups, cout)) * .3,
                    jnp.float32)
    for stride, padding in [(1, "same"), (2, "valid")]:
        _allclose(
            ops.conv2d(x, w, stride=stride, padding=padding,
                       feature_group_count=groups),
            ref.conv2d(x, w, stride=stride, padding=padding,
                       feature_group_count=groups))


def test_depthwise_conv2d_helper():
    x = jnp.asarray(RNG.standard_normal((1, 14, 14, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 1, 8)) * .3, jnp.float32)
    b = jnp.asarray(RNG.standard_normal((8,)), jnp.float32)
    _allclose(ops.depthwise_conv2d(x, w, bias=b, activation="relu"),
              ref.conv2d(x, w, feature_group_count=8, bias=b,
                         activation="relu"))


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu"])
def test_fused_epilogue_vs_oracle(activation):
    x = jnp.asarray(RNG.standard_normal((2, 10, 10, 6)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 6, 12)) * .3, jnp.float32)
    b = jnp.asarray(RNG.standard_normal((12,)), jnp.float32)
    _allclose(ops.conv2d(x, w, bias=b, activation=activation),
              ref.conv2d(x, w, bias=b, activation=activation))


def test_fused_epilogue_kernel_tiled_path():
    """K > MAX_NATIVE_K: epilogue applied once after the adder tree."""
    x = jnp.asarray(RNG.standard_normal((1, 30, 30, 3)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((11, 11, 3, 4)) * .1, jnp.float32)
    b = jnp.asarray(RNG.standard_normal((4,)), jnp.float32)
    _allclose(
        ops.conv2d(x, w, stride=4, padding="valid", bias=b,
                   activation="relu"),
        ref.conv2d(x, w, stride=4, padding="valid", bias=b,
                   activation="relu"), tol=5e-3)


# ---------------------------------------------------------------------------
# 1D plan
# ---------------------------------------------------------------------------

def test_conv1d_plan_geometry():
    plan = Conv1dPlan.build((2, 100, 24), (4, 24))
    assert plan.grid == (2, 1, 1)
    assert plan.length_padded >= 100
    assert plan.carry_shape == (3, 24)
    t = plan.hbm_bytes("3dtrim")
    assert t["input"] == math.prod(plan.padded_input_shape) * 4
    assert plan.hbm_bytes("trim")["total"] >= t["total"]
    assert plan.arithmetic_intensity() > 0
