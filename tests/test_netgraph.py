"""Differential DAG test layer (DESIGN.md §12): NetworkGraph planning
and the graph executor against an independent pure-XLA oracle.

The oracle executor below re-implements the DAG walk from scratch on
``kernels.ref`` convs + jnp joins — it shares nothing with
``models/layers.cnn_apply_from_graph`` except the GraphNode topology —
so forward and both gradients of the resnet18/unet zoo are genuinely
differential.  Planning tests pin the residency pass's per-edge
semantics: the dataflow x residency grid, forced spills under a zero
budget, the skip-edge re-fetch byte formula, and the full-scale
resnet18 goldens the CI ratio gate relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GraphFusePlan, NetworkGraph, NetworkPlan,
                        PoolInferenceError, autotune, graph_nodes,
                        scale_graph)
from repro.core.fuse_plan import graph_segments
from repro.core.model import ConvLayer, GraphNode, resnet18_graph, \
    unet_graph
from repro.core.netplan import pool_between
from repro.kernels import ref
from repro.models import layers as mlayers
from repro.models.base import init_params


def tiny_graph(net: str):
    """Execution-sized variants of the DAG zoo (CPU interpret mode)."""
    if net == "resnet18":
        return scale_graph(resnet18_graph(image=32, base=8), 2)
    return unet_graph(image=16, base=4, depth=2)


def _source(nodes):
    return next(nd for nd in nodes if not nd.inputs)


def _inputs(nodes, n=2, seed=0):
    src = _source(nodes)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(
        (n, src.layer.ifmap, src.layer.ifmap, src.layer.in_channels)),
        jnp.float32)


def ref_graph_apply(p, nodes, x):
    """Independent DAG oracle: ``ref.conv2d`` (+ bias/relu epilogue and
    reduce_window pooling) per conv node, jnp joins — written against
    the GraphNode spec, not against the production executor."""
    outs = {}
    for nd in nodes:
        if nd.op == "conv":
            v = x if not nd.inputs else outs[nd.inputs[0]]
            l = nd.layer
            v = ref.conv2d(v, p[nd.name]["w"], stride=l.stride,
                           padding="same" if l.padding else "valid",
                           bias=p[nd.name].get("b"), activation="relu")
            if nd.pool > 1 or nd.pool_window > 1:
                v = jax.lax.reduce_window(
                    v, -jnp.inf, jax.lax.max,
                    (1, nd.pool_window, nd.pool_window, 1),
                    (1, nd.pool, nd.pool, 1), "VALID")
            outs[nd.name] = v
        elif nd.op == "pool":
            outs[nd.name] = jax.lax.reduce_window(
                outs[nd.inputs[0]], -jnp.inf, jax.lax.max,
                (1, nd.pool_window, nd.pool_window, 1),
                (1, nd.pool, nd.pool, 1), "VALID")
        elif nd.op == "add":
            outs[nd.name] = outs[nd.inputs[0]] + outs[nd.inputs[1]]
        elif nd.op == "concat":
            outs[nd.name] = jnp.concatenate(
                [outs[s] for s in nd.inputs], axis=-1)
        elif nd.op == "upsample":
            v = outs[nd.inputs[0]]
            v = jnp.repeat(v, nd.scale, axis=1)
            outs[nd.name] = jnp.repeat(v, nd.scale, axis=2)
        else:                                    # pragma: no cover
            raise AssertionError(nd.op)
    return outs[nodes[-1].name]


def _close(a, b, tol=1e-5):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-9
    assert float(np.abs(a - b).max()) / scale < tol


# ---------------------------------------------------------------------------
# Differential: production graph executor vs the in-test oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", ["resnet18", "unet"])
def test_graph_forward_matches_oracle(net):
    nodes = graph_nodes(tiny_graph(net))
    p = init_params(mlayers.cnn_params_from_graph(nodes),
                    jax.random.PRNGKey(0))
    x = _inputs(nodes)
    want = ref_graph_apply(p, nodes, x)
    got = mlayers.cnn_apply_from_graph(p, nodes, x, impl="pallas")
    assert got.shape == want.shape
    _close(got, want)


@pytest.mark.parametrize("net", ["resnet18", "unet"])
def test_graph_gradients_match_oracle(net):
    """Both gradients — d/dx and d/dparams — of a scalar loss through
    the whole DAG, kernel path vs the oracle."""
    nodes = graph_nodes(tiny_graph(net))
    p = init_params(mlayers.cnn_params_from_graph(nodes),
                    jax.random.PRNGKey(1))
    x = _inputs(nodes, seed=1)

    def loss_prod(p_, x_):
        return (mlayers.cnn_apply_from_graph(p_, nodes, x_,
                                             impl="pallas") ** 2).sum()

    def loss_ref(p_, x_):
        return (ref_graph_apply(p_, nodes, x_) ** 2).sum()

    gp, gx = jax.grad(loss_prod, argnums=(0, 1))(p, x)
    rp, rx = jax.grad(loss_ref, argnums=(0, 1))(p, x)
    _close(gx, rx)
    for a, b in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(rp)):
        _close(a, b)


@pytest.mark.parametrize("net", ["resnet18", "unet"])
def test_graph_fused_bitmatches_per_layer(net):
    """Fused segment execution is a pure perf transform: the graph
    executor with GraphFusePlan megakernels returns the bit-identical
    tensor of the per-layer walk."""
    nodes = graph_nodes(tiny_graph(net))
    p = init_params(mlayers.cnn_params_from_graph(nodes),
                    jax.random.PRNGKey(2))
    x = _inputs(nodes, seed=2)
    per_layer = mlayers.cnn_apply_from_graph(p, nodes, x, impl="pallas")
    fused = mlayers.cnn_apply_from_graph(p, nodes, x, impl="pallas",
                                         fused=True)
    assert jnp.array_equal(per_layer, fused)
    # a prebuilt plan routes identically
    plan = GraphFusePlan.build(nodes, n=x.shape[0])
    fused2 = mlayers.cnn_apply_from_graph(p, nodes, x, impl="pallas",
                                          fused=True, fuse_plan=plan)
    assert jnp.array_equal(per_layer, fused2)


def test_graph_head_logits_and_packed_params():
    """n_classes adds the linear head over the terminal node; packed
    params run through the same walk."""
    nodes = graph_nodes(tiny_graph("resnet18"))
    p = init_params(mlayers.cnn_params_from_graph(nodes, n_classes=5),
                    jax.random.PRNGKey(3))
    x = _inputs(nodes, seed=3)
    y = mlayers.cnn_apply_from_graph(p, nodes, x, impl="pallas")
    assert y.shape == (x.shape[0], 5)
    want = ref_graph_apply(p, nodes, x)
    want = want.mean(axis=(1, 2)) @ p["head"]["w"] + p["head"]["b"]
    _close(y, want)
    pk = mlayers.cnn_pack_params_from_graph(p, nodes, n=x.shape[0])
    y_pk = mlayers.cnn_apply_from_graph(pk, nodes, x)
    _close(y_pk, want)


# ---------------------------------------------------------------------------
# Residency pass: the dataflow x residency grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", ["resnet18", "unet"])
@pytest.mark.parametrize("dataflow", ["carry", "halo"])
@pytest.mark.parametrize("residency", ["auto", "always", "never"])
def test_residency_grid(net, dataflow, residency):
    gp = NetworkGraph.build(net, dataflow=dataflow, residency=residency)
    pos = {nd.name: i for i, nd in enumerate(gp.nodes)}
    for e in gp.edges:
        assert e.boundaries == (pos[e.producer], pos[e.consumer])
        assert e.span >= 1
    if residency == "always":
        assert all(e.resident for e in gp.edges)
        assert gp.spilled_edge_bytes == 0
    if residency == "never":
        assert not any(e.resident for e in gp.edges)
        assert gp.boundary_occupancy() == [0] * (gp.n_nodes - 1)
    if residency == "auto":
        assert all(o <= gp.residency_budget
                   for o in gp.boundary_occupancy())
    # OPs are a property of the topology, not the residency policy
    never = NetworkGraph.build(net, dataflow=dataflow,
                               residency="never")
    assert gp.ops == never.ops
    for mode in ("3dtrim", "trim"):
        assert gp.hbm_bytes(mode)["total"] <= \
            never.hbm_bytes(mode)["total"]


@pytest.mark.parametrize("net", ["resnet18", "unet"])
def test_zero_budget_forces_every_spill(net):
    """residency_budget=0 under "auto" must refuse every edge — the
    skip edges re-fetch, and the totals equal the "never" policy."""
    gp = NetworkGraph.build(net, residency_budget=0)
    assert not any(e.resident for e in gp.edges)
    assert all(e.state == "refetch" for e in gp.edges)
    assert all(e.refetch_bytes == e.bytes for e in gp.edges)
    never = NetworkGraph.build(net, residency="never")
    for mode in ("3dtrim", "trim"):
        assert gp.hbm_bytes(mode) == never.hbm_bytes(mode)
        assert gp.accesses(mode) == never.accesses(mode)
    # skip edges exist and span > 1 boundary on both zoo nets
    assert any(e.span > 1 for e in gp.edges)


def test_skip_edge_refetch_byte_formula():
    """The re-fetch cost of a spilled skip edge is exactly the pooled
    activation it carries: n * out^2 * channels * dtype_bytes."""
    gp = NetworkGraph.build("resnet18", residency="never")
    edges = {(e.producer, e.consumer): e for e in gp.edges}
    skip = edges[("pool1", "l1b0_add")]
    assert skip.span > 1                       # a true skip connection
    assert skip.bytes == 56 * 56 * 64 * 4 == 802816
    assert skip.refetch_bytes == skip.bytes
    # the join consumer bills exactly its non-resident in-edges
    join = next(s for s in gp.steps if s.name == "l1b0_add")
    assert join.hbm_bytes()["input"] == \
        edges[("l1b0_conv2", "l1b0_add")].bytes + skip.bytes
    # and a join read shows up in the paper-metric denominator
    assert join.accesses() == join.hbm_bytes()["input"] // 4
    assert join.macs == 0 and join.ops == 0


# ---------------------------------------------------------------------------
# Full-scale resnet18 goldens (the CI ratio gate's numbers)
# ---------------------------------------------------------------------------

def test_resnet18_arch_golden_values():
    gp = NetworkGraph.build("resnet18")
    assert gp.n_nodes == 29
    assert len(gp.conv_steps) == 20
    assert len(gp.edges) == 36
    arch = gp.arch_compare()
    assert arch["improvement"] == \
        pytest.approx(3.245935585013433, rel=1e-6)
    assert arch["improvement"] > 2.0           # the CI gate
    cmp = gp.compare()
    assert cmp["ops_per_macc_3dtrim"] == \
        pytest.approx(161.41412898595303, rel=1e-6)
    assert cmp["ops_per_macc_trim"] == \
        pytest.approx(161.38439581808308, rel=1e-6)
    # at batch 1 every edge fits the 8 MB budget
    assert all(e.resident for e in gp.edges)
    assert max(gp.boundary_occupancy()) == 3211264


def test_unet_arch_golden_values():
    gp = NetworkGraph.build("unet")
    assert len(gp.conv_steps) == 13
    assert gp.arch_compare()["improvement"] == \
        pytest.approx(3.788476083401472, rel=1e-6)


# ---------------------------------------------------------------------------
# Graph construction + segmentation semantics
# ---------------------------------------------------------------------------

def test_graph_validation_rejects_broken_topologies():
    l = ConvLayer("x", 8, 3, 4, kernel=3, padding=1)
    with pytest.raises(ValueError, match="duplicate node name"):
        NetworkGraph.build([GraphNode("a", "conv", (), l),
                           GraphNode("a", "conv", ("a",),
                                     ConvLayer("x", 8, 4, 4, kernel=3,
                                               padding=1))])
    with pytest.raises(ValueError, match="topological"):
        NetworkGraph.build([GraphNode("a", "conv", ("missing",), l)])
    with pytest.raises(ValueError, match="exactly one input"):
        NetworkGraph.build([
            GraphNode("a", "conv", (), l),
            GraphNode("b", "conv", ("a", "a"),
                      ConvLayer("y", 8, 4, 4, kernel=3, padding=1))])
    with pytest.raises(ValueError, match="needs inputs"):
        GraphNode("j", "add", ())
    with pytest.raises(ValueError, match="op"):
        GraphNode("a", "matmul", (), l)


def test_graph_params_reject_reserved_head_name():
    l = ConvLayer("x", 8, 3, 4, kernel=3, padding=1)
    with pytest.raises(ValueError, match="head"):
        mlayers.cnn_params_from_graph([GraphNode("head", "conv", (), l)])


def test_pool_inference_structured_errors():
    """Dims only a strided or upsampling join can explain must raise a
    PoolInferenceError carrying the structured fields (satellite 4)."""
    a = ConvLayer("a", 16, 3, 4, kernel=3, padding=1)      # out 16
    up = ConvLayer("b", 32, 4, 4, kernel=3, padding=1)     # needs 32
    with pytest.raises(PoolInferenceError) as ei:
        pool_between(a, up)
    err = ei.value
    assert isinstance(err, ValueError)          # stays catchable as-was
    assert (err.producer, err.consumer) == ("a", "b")
    assert (err.out_size, err.in_size) == (16, 32)
    assert err.reason == "upsample"
    assert "upsample" in str(err)

    deep = ConvLayer("c", 3, 4, 4, kernel=3, padding=1)    # 16 -> 3
    with pytest.raises(PoolInferenceError) as ei:
        pool_between(a, deep)                   # stride 5 > MAX_STRIDE
    err = ei.value
    assert err.reason == "strided-join"
    assert err.stride > PoolInferenceError.MAX_STRIDE
    # every zoo boundary (VGG 2/2, AlexNet 3/2, ResNet/U-Net 2/2,
    # sub-2x 3/1) stays inferable under the caps
    for nets in ("vgg16", "alexnet", "mobilenet"):
        NetworkPlan.build(nets)
    for nets in ("resnet18", "unet"):
        NetworkGraph.build(nets)


def test_graph_segments_break_on_unrecoverable_pool():
    """A pool whose params the dims between two convs would re-infer
    differently (o=10 pooled 2x2/s3 re-infers as 4x4/s3) must bound the
    segment instead of being silently absorbed."""
    a = ConvLayer("a", 10, 3, 4, kernel=3, padding=1)      # out 10
    b = ConvLayer("b", 3, 4, 4, kernel=3, padding=1)       # in 3
    nodes = [GraphNode("a", "conv", (), a),
             GraphNode("p", "pool", ("a",), pool=3, pool_window=2),
             GraphNode("b", "conv", ("p",), b)]
    NetworkGraph.build(nodes)                  # plans fine as a DAG
    segs = graph_segments(nodes)
    assert [names for names, _ in segs] == [("a",), ("b",)]
    # a recoverable pool (2x2/s2) is absorbed into one segment (its
    # name rides along so the executor can mark the node covered)
    c = ConvLayer("c", 5, 4, 4, kernel=3, padding=1)
    nodes2 = [GraphNode("a", "conv", (), a),
              GraphNode("p", "pool", ("a",), pool=2, pool_window=2),
              GraphNode("c", "conv", ("p",), c)]
    segs2 = graph_segments(nodes2)
    assert [names for names, _ in segs2] == [("a", "p", "c")]
    assert [l.name for l in segs2[0][1]] == ["a", "c"]
    # and the fused walk over it still bit-matches the per-node walk
    p = init_params(mlayers.cnn_params_from_graph(nodes2),
                    jax.random.PRNGKey(4))
    x = _inputs(nodes2, seed=4)
    per_node = mlayers.cnn_apply_from_graph(p, nodes2, x, impl="pallas")
    fused = mlayers.cnn_apply_from_graph(p, nodes2, x, impl="pallas",
                                         fused=True)
    assert jnp.array_equal(per_node, fused)


def test_graph_segments_cover_every_conv_once():
    for net in ("resnet18", "unet"):
        nodes = graph_nodes(net)
        segs = graph_segments(nodes)
        covered = [nm for names, _ in segs for nm in names]
        convs = [nd.name for nd in nodes if nd.op == "conv"]
        assert sorted(covered) == sorted(convs)
        assert len(covered) == len(set(covered))


def test_tune_graph_sweep_and_consumption(tmp_path):
    """One tune_graph sweep caches every conv node's knobs (and the
    fused segment records); the executor then runs on cached plans."""
    path = str(tmp_path / "tune.json")
    nodes = graph_nodes(tiny_graph("unet"))
    out = autotune.tune_graph(nodes, n=1, fused=True, path=path)
    convs = [nd for nd in nodes if nd.op == "conv"]
    assert len(out["layers"]) == len(convs)
    assert out["fused"]                        # multi-conv segments exist
    gp = NetworkGraph.build(nodes, use_autotune_cache=True)
    assert len(gp.conv_steps) == len(convs)
