"""Roofline machinery: HLO collective parsing, term arithmetic."""

import pytest

from repro.core import roofline as rl

HLO = """
HloModule jit_train_step, is_scheduled=true

%fused_computation { ... }

ENTRY %main.1 (p0: bf16[16,4096,128]) -> bf16[16,4096,128] {
  %ar = bf16[16,4096,128]{2,1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups=[4,16]<=[64], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%z), replica_groups={{0,1},{2,3}}, dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %ars = bf16[4,4]{1,0} all-reduce-start(%v), replica_groups={{0,1,2,3,4,5,6,7}}
}
"""


def test_parse_collectives_kinds_and_bytes():
    stats = rl.parse_collectives(HLO, 64)
    # all-reduce: 16*4096*128*2 bytes, group 4 -> wire 2*S*3/4
    s_ar = 16 * 4096 * 128 * 2
    assert stats.by_kind["all-reduce"] == pytest.approx(
        2 * s_ar * 3 / 4 + 2 * (4 * 4 * 2) * 7 / 8)
    # all-gather: result 64*128*2 bytes, iota group size 16
    s_ag = 64 * 128 * 2
    assert stats.by_kind["all-gather"] == pytest.approx(s_ag * 15 / 16)
    # reduce-scatter: result 8*128*4 bytes, group 2 -> wire S_out*(g-1)
    assert stats.by_kind["reduce-scatter"] == pytest.approx(8 * 128 * 4 * 1)
    # collective-permute: point-to-point
    assert stats.by_kind["collective-permute"] == pytest.approx(32 * 32 * 2)


def test_parse_ignores_non_collectives():
    stats = rl.parse_collectives(
        "%d = f32[4,4] dot(%a, %b), lhs_contracting_dims={1}", 8)
    assert stats.total_bytes == 0


def test_roofline_terms_and_dominance():
    t = rl.RooflineTerms(cell="x", flops_per_dev=197e12,
                         hbm_bytes_per_dev=819e9 / 2,
                         coll_bytes_per_dev=50e9 / 4, coll_by_kind={},
                         model_flops_per_dev=98.5e12)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(0.5)
    assert t.t_collective == pytest.approx(0.25)
    assert t.dominant == "compute"
    assert t.step_time_s == pytest.approx(1.0)
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5)


def test_markdown_table():
    t = rl.RooflineTerms(cell="a/b/c", flops_per_dev=1e12,
                         hbm_bytes_per_dev=1e9, coll_bytes_per_dev=1e9,
                         coll_by_kind={}, model_flops_per_dev=5e11)
    md = rl.markdown_table([t])
    assert "a/b/c" in md and "|" in md
