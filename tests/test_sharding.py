"""Sharding-rule resolution properties (divisibility fallbacks, FSDP)."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed import steps
from repro.launch.mesh import compat_make_mesh
from repro.distributed.sharding import make_rules
from repro.models.base import Param, resolve_spec, tree_bytes_per_dev


def _mesh():
    return compat_make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules()
    # kv_heads=2 cannot shard 16 ways -> replicated
    spec = resolve_spec((4096, 2, 128), ("embed", "kv_heads", "head_dim"),
                        mesh, rules)
    assert spec == P(None, None, None)
    # heads=32 shards fine
    spec = resolve_spec((4096, 32, 128), ("embed", "heads", "head_dim"),
                        mesh, rules)
    assert spec == P(None, "model", None)


def test_resolve_no_axis_reuse():
    """Two dims cannot both claim the same mesh axis (experts wins, mlp
    falls back to replication)."""
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules()
    spec = resolve_spec((128, 2048, 768), ("experts", "embed", "mlp"),
                        mesh, rules)
    assert spec == P("model", None, None)
    with_fsdp = make_rules(fsdp=True)
    spec = resolve_spec((128, 2048, 768), ("experts", "embed", "mlp"),
                        mesh, with_fsdp)
    assert spec == P("model", "data", None)


def test_fsdp_pod_composition():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = make_rules(fsdp=True)
    spec = resolve_spec((16384, 53248), ("embed", "mlp"), mesh, rules)
    assert spec == P(("data", "pod"), "model")


def test_seq_override_takes_axis_from_kv():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(**{"seq": "model"})
    spec = resolve_spec((126, 128, 32768, 8, 128),
                        ("layers", "batch", "seq", "kv_heads", None),
                        mesh, rules)
    assert spec[2] == "model"          # seq claimed model
    assert spec[3] is None             # kv falls back


def test_batch_shardings_divisibility():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    rules = make_rules()
    tree = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32),
            "big": jax.ShapeDtypeStruct((16, 8), jnp.int32)}
    sh = steps.batch_shardings(tree, mesh, rules)
    assert sh["tokens"].spec == P("data")   # 1 % 1 == 0 on the tiny mesh
    assert sh["big"].spec == P("data")


@settings(max_examples=20, deadline=None)
@given(size=st.integers(1, 64), extent=st.sampled_from([2, 4, 8, 16]))
def test_property_resolution_always_divides(size, extent):
    mesh = FakeMesh({"data": extent, "model": 16})
    rules = make_rules(fsdp=True)
    spec = resolve_spec((size,), ("embed",), mesh, rules)
    if spec[0] is not None:
        assert size % extent == 0


def test_tree_bytes_per_dev():
    mesh = FakeMesh({"data": 4, "model": 8})
    rules = make_rules(fsdp=True)
    tree = {"w": Param((64, 64), ("embed", "mlp"))}   # shards 4 x 8 = 32
    assert tree_bytes_per_dev(tree, mesh, rules, 2) == 64 * 64 * 2 / 32
