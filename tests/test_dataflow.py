"""Cycle-simulator tests: Fig. 5 schedule semantics, memory-read counts,
equivalence with the convolution oracle (incl. hypothesis sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (TrimSliceSim, core_conv, reference_conv2d_valid,
                        ifmap_reads_per_channel)

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("mode", ["trim", "3dtrim"])
@pytest.mark.parametrize("h,w", [(8, 8), (8, 10), (14, 14), (9, 12), (6, 7)])
def test_slice_conv_matches_oracle(mode, h, w):
    ifmap = RNG.standard_normal((h, w))
    wts = RNG.standard_normal((3, 3))
    out, stats = TrimSliceSim(3, mode).run(ifmap, wts)
    assert np.allclose(out, reference_conv2d_valid(ifmap, wts))


@pytest.mark.parametrize("mode", ["trim", "3dtrim"])
@pytest.mark.parametrize("h,w", [(8, 8), (14, 14), (10, 16)])
def test_memory_reads_match_analytical_model(mode, h, w):
    ifmap = RNG.standard_normal((h, w))
    sim = TrimSliceSim(3, mode)
    _, stats = sim.run(ifmap, np.ones((3, 3)))
    assert stats.memory_reads == sim.expected_memory_reads(h, w)
    assert stats.memory_reads == ifmap_reads_per_channel(
        h, w, 3, 1, shadow=(mode == "3dtrim"))


def test_3dtrim_reads_equal_ideal():
    """Shadow registers nullify the overhead: every activation read once."""
    for (h, w) in [(8, 8), (14, 14), (12, 9)]:
        _, stats = TrimSliceSim(3, "3dtrim").run(
            RNG.standard_normal((h, w)), np.ones((3, 3)))
        assert stats.memory_reads == h * w


def test_fig5_schedule_semantics():
    """The 8x8 example of Fig. 5 with raster-numbered activations."""
    ifmap = np.arange(1, 65, dtype=float).reshape(8, 8)
    sim = TrimSliceSim(3, "3dtrim", record_trace=True)
    out, stats = sim.run(ifmap, np.ones((3, 3)))

    # After band 0, the shadow registers hold the end-of-row activations
    # 15, 16 (ifmap row 1) and 23, 24 (row 2) — exactly Fig. 5, cycles 6-8.
    band0_last = [s for s in sim.trace if s.band == 0][-1]
    assert [sorted(v.values()) for v in band0_last.shadow_regs] == \
        [[15.0, 16.0], [23.0, 24.0]]

    # Band 1 re-injects 9, 10, 11 into PE row 0 via the shift registers
    # (Fig. 5, cycle 7) ...
    band1 = [s for s in sim.trace if s.band == 1]
    for step in band1[:3]:
        assert step.sources[0] == (0, "shift")
        assert step.sources[1] == (1, "shift")
        assert step.sources[2] == (2, "memory")   # fresh row from memory
    # ... and the end-of-row values come back from the shadow registers
    # (Fig. 5, cycles 11-13).
    for step in band1[8 - 3 + 1:]:
        assert step.sources[0] == (0, "shadow")
        assert step.sources[1] == (1, "shadow")


def test_trim_mode_rereads_end_of_row():
    """TrIM re-reads (K-1)^2 activations per band advance (Fig. 1)."""
    ifmap = np.arange(64, dtype=float).reshape(8, 8)
    _, stats = TrimSliceSim(3, "trim").run(ifmap, np.ones((3, 3)))
    assert stats.memory_reads == 64 + 5 * 4     # 5 band advances * (K-1)^2


def test_core_irb_sharing():
    """P_O slices sharing one IRB fetch the ifmap once (3D-TrIM); private
    buffers multiply the reads (TrIM orientation)."""
    ifmap = RNG.standard_normal((8, 8))
    wstack = RNG.standard_normal((4, 3, 3))
    outs, shared = core_conv(ifmap, wstack, "3dtrim")
    _, private = core_conv(ifmap, wstack, "trim")
    assert shared == 64
    assert private == 4 * 84
    for s in range(4):
        assert np.allclose(outs[s], reference_conv2d_valid(ifmap, wstack[s]))


@settings(max_examples=25, deadline=None)
@given(h=st.integers(5, 20), w=st.integers(6, 20), seed=st.integers(0, 99))
def test_property_sim_oracle_and_reads(h, w, seed):
    """Property: for any ifmap size, both modes produce the oracle conv and
    their read counters match the closed-form model."""
    rng = np.random.default_rng(seed)
    ifmap = rng.standard_normal((h, w))
    wts = rng.standard_normal((3, 3))
    ref = reference_conv2d_valid(ifmap, wts)
    for mode in ("trim", "3dtrim"):
        sim = TrimSliceSim(3, mode)
        out, stats = sim.run(ifmap, wts)
        assert np.allclose(out, ref)
        assert stats.memory_reads == sim.expected_memory_reads(h, w)
    # the overhead is exactly (H-K)(K-1)^2
    trim_reads = ifmap_reads_per_channel(h, w, 3, 1, shadow=False)
    assert trim_reads - h * w == (h - 3) * 4
