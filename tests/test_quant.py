"""Int8 fixed-point inference path (DESIGN.md §11).

Three contracts, in increasing integration order:

* **Bit-exactness.**  The int8 Pallas kernel (interpret mode) must agree
  *bit for bit* with the ``ref.conv2d_quantized`` oracle on every
  geometry: the MXU taps accumulate exactly in int32 and the fused
  epilogue is an exact int32 bias add followed by one correctly-rounded
  f32 multiply — there is no legitimate source of divergence, so the
  test uses ``==``, not allclose.

* **Calibrated accuracy.**  The dequantized int8 output of a VGG-16
  block must sit inside the *analytical* quantization error bound
  derived from the calibration scales (interval arithmetic over the
  rounding half-ulps), not just some empirical tolerance.

* **Guarded demotion.**  The quantized tier chain ``q8 -> pallas ->
  ref`` fails soft through ``testing/faults.py`` like every other conv
  path.

Plus the dtype-plumbing regressions of this sweep: ``dtype_width``,
bf16 plans pricing 2-byte traffic, and the ``conv2d_q8:`` autotune
namespace.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune, guard
from repro.core.conv_plan import ConvPlan, resolve_dtype_bytes
from repro.core.roofline import dtype_width
from repro.kernels import ops, ref
from repro.kernels.trim_conv2d import trim_conv2d
from repro.models import layers as mlayers
from repro.models.base import init_params
from repro.testing import faults

RNG = np.random.default_rng(42)


def _f32(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, jnp.float32)


def _quantize_problem(x, w, bias=None, zero_point=3):
    """Calibrate + quantize one conv problem the way the oracle expects."""
    x_scale = float(jnp.max(jnp.abs(x))) / 127.0
    w_scale = ref.weight_scales_int8(w)
    x_q = ref.quantize_int8(x, x_scale, zero_point)
    w_q = ref.quantize_int8(w, w_scale[None, None, None, :])
    return dict(x_q=x_q, w_q=w_q, x_scale=x_scale, x_zero_point=zero_point,
                w_scale=w_scale, bias=bias)


def _kernel_vs_oracle(n, h, w_, cin, cout, k, stride, groups, padding,
                      dataflow, bias=True):
    """Run the int8 kernel and the oracle on one geometry; return both."""
    x = _f32((n, h, w_, cin))
    w = _f32((k, k, cin // groups, cout), 0.1)
    b = _f32((cout,)) if bias else None
    q = _quantize_problem(x, w, b)
    y_ref = ref.conv2d_quantized(q["x_q"], q["w_q"], x_scale=q["x_scale"],
                                 x_zero_point=q["x_zero_point"],
                                 w_scale=q["w_scale"], bias=b,
                                 stride=stride, padding=padding,
                                 feature_group_count=groups)
    scale, bias_q = ref.dequant_params(q["w_q"], q["w_scale"],
                                       q["x_scale"], q["x_zero_point"], b)
    x_k = q["x_q"]
    if padding == "same":
        ph = ref._same_pads(h, k, stride)
        pw = ref._same_pads(w_, k, stride)
        zp = jnp.asarray(q["x_zero_point"], jnp.int8)
        x_k = jax.lax.pad(x_k, zp, ((0, 0, 0), (*ph, 0), (*pw, 0),
                                    (0, 0, 0)))
    y_k = trim_conv2d(x_k, q["w_q"], bias_q, scale, stride=stride, pad=0,
                      groups=groups, dataflow=dataflow, interpret=True)
    return y_k, y_ref


# ---------------------------------------------------------------------------
# Bit-exactness: kernel == oracle, across the geometry grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataflow", ["carry", "halo"])
@pytest.mark.parametrize(
    "k,stride,groups,padding",
    [(1, 1, 1, "same"),           # pointwise
     (3, 1, 1, "same"),           # the VGG workhorse
     (3, 2, 1, "same"),           # strided, asymmetric 'same' pads
     (3, 1, 2, "same"),           # grouped
     (5, 1, 2, "same"),           # big taps + grouped
     (3, 2, 2, "valid"),          # strided grouped, no padding
     (1, 1, 1, "valid")])
def test_int8_kernel_bit_exact(k, stride, groups, padding, dataflow):
    y_k, y_ref = _kernel_vs_oracle(2, 13, 11, 8, 12, k, stride, groups,
                                   padding, dataflow)
    assert y_k.dtype == jnp.float32
    assert bool(jnp.all(y_k == y_ref)), \
        float(jnp.max(jnp.abs(y_k - y_ref)))


def test_int8_kernel_bit_exact_no_bias_nonzero_zp():
    """The zero-point correction alone (no real bias) is still exact —
    'same' borders are padded with zp, not 0, so every output position
    sees the position-independent integer correction."""
    for df in ("carry", "halo"):
        y_k, y_ref = _kernel_vs_oracle(1, 12, 12, 8, 16, 3, 1, 1, "same",
                                       df, bias=False)
        assert bool(jnp.all(y_k == y_ref))


def test_int8_route_requires_consistent_arguments():
    x8 = jnp.zeros((1, 8, 8, 8), jnp.int8)
    w8 = jnp.zeros((3, 3, 8, 8), jnp.int8)
    s = jnp.ones((1, 8), jnp.float32)
    with pytest.raises(ValueError, match="int8 route"):
        trim_conv2d(x8, w8, interpret=True)           # int x, no scale
    with pytest.raises(ValueError, match="int8 route"):
        trim_conv2d(x8.astype(jnp.float32), w8.astype(jnp.float32), None,
                    s, interpret=True)                # scale, float x
    with pytest.raises(ValueError, match="integer weights"):
        trim_conv2d(x8, w8.astype(jnp.float32), None, s, interpret=True)
    with pytest.raises(ValueError, match="requantized int32 bias"):
        trim_conv2d(x8, w8, jnp.zeros((8,), jnp.float32), s,
                    interpret=True)


# ---------------------------------------------------------------------------
# The ops dispatch: quantize_conv2d_weights / calibrate_conv2d
# ---------------------------------------------------------------------------

def test_ops_conv2d_quantized_matches_oracle_bit_exact():
    x = _f32((2, 14, 14, 8))
    w = _f32((3, 3, 8, 16), 0.1)
    b = _f32((16,))
    q = _quantize_problem(x, w, b, zero_point=2)
    pk = ops.quantize_conv2d_weights(w, b, x_scale=q["x_scale"],
                                     x_zero_point=2)
    got = ops.conv2d(x, pk, stride=1, padding="same", activation="relu")
    want = ref.conv2d_quantized(q["x_q"], q["w_q"], x_scale=q["x_scale"],
                                x_zero_point=2, w_scale=q["w_scale"],
                                bias=b, stride=1, padding="same",
                                activation="relu")
    assert bool(jnp.all(got == want))
    assert guard.events() == []


def test_quantized_packed_weights_pytree_round_trip():
    w = _f32((3, 3, 8, 16), 0.1)
    pk = ops.quantize_conv2d_weights(w, _f32((16,)), x_scale=0.01,
                                     x_zero_point=1)
    leaves, treedef = jax.tree_util.tree_flatten(pk)
    assert len(leaves) == 5          # w, bias, scale, zero_point, in_scale
    pk2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert bool(jnp.all(pk2.w == pk.w))
    assert bool(jnp.all(pk2.scale == pk.scale))
    assert int(pk2.zero_point) == int(pk.zero_point)
    # padded scale lanes hold 1.0 (NaN-free bias requantization)
    cpp = pk.w.shape[3] // pk.groups
    assert bool(jnp.all(pk.scale.reshape(pk.groups, cpp)[:, 16:] == 1.0))


def test_calibrate_conv2d_jits_and_tracks_f32_within_bound():
    """A VGG-16-block-shaped layer: calibrate on a sample batch, run the
    int8 path under jit, and require the dequantized output to sit
    inside the analytical quantization error bound

        |y_q8 - y_f32| <= (s_w/2) |x| * 1  +  (s_x/2) 1 * |w|
                          + N s_x s_w / 4  +  s_x s_w / 2

    (interval arithmetic over the rounding half-ulps of x, w and the
    requantized bias; every term computable with one more conv)."""
    # conv11 of VGG-16 at 1/16 channel scale: (14, 32, 32), K=3
    x = _f32((1, 14, 14, 32), 0.5)
    p = init_params(mlayers.conv2d_params(3, 32, 32),
                    jax.random.PRNGKey(7))
    y_f32 = mlayers.conv2d_apply(p, x, activation=None)

    pq = mlayers.calibrate_conv2d(p, x)
    assert set(pq) == {"packed"}
    pk = pq["packed"]
    assert pk.w.dtype == jnp.int8 and pk.scale is not None
    y_q8 = jax.jit(
        lambda pt, v: mlayers.conv2d_apply(pt, v, activation=None))(pq, x)
    assert y_q8.shape == y_f32.shape

    s_x = float(pk.input_scale)
    w_scale = ref.weight_scales_int8(p["w"])          # (Cout,)
    ones = jnp.ones_like(p["w"])
    taps = ref.conv2d(jnp.abs(x), ones, padding="same")[..., :1]
    sum_absw = jnp.sum(jnp.abs(p["w"]), axis=(0, 1, 2))
    n_taps = np.prod(p["w"].shape[:3])
    bound = (w_scale / 2) * taps + (s_x / 2) * sum_absw \
        + n_taps * s_x * w_scale / 4 + s_x * w_scale / 2
    err = jnp.abs(y_q8 - y_f32)
    assert bool(jnp.all(err <= bound + 1e-6)), \
        (float(jnp.max(err - bound)),)
    # and the bound is meaningful: quantization error is actually small
    assert float(jnp.max(err)) / (float(jnp.max(jnp.abs(y_f32))) + 1e-6) \
        < 0.05


def test_quantized_grouped_valid_via_ops():
    x = _f32((1, 13, 13, 8))
    w = _f32((3, 3, 4, 8), 0.1)
    pk = ops.quantize_conv2d_weights(
        w, None, x_scale=float(jnp.max(jnp.abs(x))) / 127.0,
        x_zero_point=0, groups=2)
    got = ops.conv2d(x, pk, stride=2, padding="valid")
    q = _quantize_problem(x, w, zero_point=0)
    want = ref.conv2d_quantized(q["x_q"], q["w_q"], x_scale=q["x_scale"],
                                x_zero_point=0, w_scale=q["w_scale"],
                                stride=2, padding="valid",
                                feature_group_count=2)
    assert bool(jnp.all(got == want))


# ---------------------------------------------------------------------------
# Guarded demotion: q8 -> pallas -> ref (DESIGN.md §9 / §11)
# ---------------------------------------------------------------------------

def _quantized_layer():
    x = _f32((1, 12, 12, 8))
    w = _f32((3, 3, 8, 12), 0.1)
    b = _f32((12,))
    pk = ops.quantize_conv2d_weights(
        w, b, x_scale=float(jnp.max(jnp.abs(x))) / 127.0, x_zero_point=2)
    q = _quantize_problem(x, w, b, zero_point=2)
    oracle = ref.conv2d_quantized(
        q["x_q"], q["w_q"], x_scale=q["x_scale"], x_zero_point=2,
        w_scale=q["w_scale"], bias=b, stride=1, padding="same")
    return x, pk, oracle


def test_q8_failure_demotes_to_f32_pallas():
    x, pk, oracle = _quantized_layer()
    with faults.lowering_failure("q8") as fault:
        got = ops.conv2d(x, pk, layer="conv_q")
    assert fault.calls == 1
    # the f32 tier convolves the *dequantized* operands: same
    # quantization error, only epilogue rounding differs from the oracle
    assert float(jnp.max(jnp.abs(got - oracle))) < 1e-3 * \
        float(jnp.max(jnp.abs(oracle)))
    (ev,) = guard.events()
    assert (ev["tier"], ev["to"], ev["layer"]) == ("q8", "pallas",
                                                   "conv_q")


def test_q8_double_failure_demotes_to_ref_oracle():
    x, pk, oracle = _quantized_layer()
    with faults.lowering_failure("q8"), faults.lowering_failure("pallas"):
        got = ops.conv2d(x, pk)
    # the final tier IS the oracle: bit-identical
    assert bool(jnp.all(got == oracle))
    tiers = [(e["tier"], e["to"]) for e in guard.events()]
    assert tiers == [("q8", "pallas"), ("pallas", "ref")]


# ---------------------------------------------------------------------------
# Dtype plumbing: dtype_width and dtype-derived plan traffic
# ---------------------------------------------------------------------------

def test_dtype_width_single_source_of_truth():
    assert dtype_width("float32") == dtype_width("f32") == 4
    assert dtype_width("bfloat16") == dtype_width("bf16") == 2
    assert dtype_width("int8") == dtype_width("s8") == 1
    assert dtype_width(jnp.int8) == 1
    assert dtype_width(jnp.dtype("float16")) == 2
    assert dtype_width(np.float64) == 8
    with pytest.raises(ValueError, match="unknown dtype"):
        dtype_width("float40")
    assert resolve_dtype_bytes(2) == 2                # ints pass through
    assert resolve_dtype_bytes("bfloat16") == 2


def test_bf16_plan_prices_two_byte_traffic():
    """The satellite-1 regression: a plan built from a dtype (not a
    hard-coded ``=4``) must bill 2-byte traffic for bf16 and 1-byte for
    int8 — exactly half / a quarter of the f32 plan, with the element
    counts (and therefore Ops/MAcc) unchanged."""
    kw = dict(stride=1, pad=1, tile_h=8, tile_cout=8)
    p32 = ConvPlan.build((1, 16, 16, 8), (3, 3, 8, 8), dtype_bytes=4,
                         **kw)
    p16 = ConvPlan.build((1, 16, 16, 8), (3, 3, 8, 8),
                         dtype_bytes="bfloat16", **kw)
    p8 = ConvPlan.build((1, 16, 16, 8), (3, 3, 8, 8),
                        dtype_bytes=jnp.int8, **kw)
    assert (p16.dtype_bytes, p8.dtype_bytes) == (2, 1)
    for mode in ("3dtrim", "trim"):
        b32 = p32.hbm_bytes(mode)
        b16 = p16.hbm_bytes(mode)
        b8 = p8.hbm_bytes(mode)
        for key in ("input", "weights", "total"):
            assert b16[key] * 2 == b32[key], (mode, key)
            assert b8[key] * 4 == b32[key], (mode, key)


def test_netplan_derives_dtype_bytes_from_dtype():
    from repro.core.netplan import NetworkPlan
    np32 = NetworkPlan.build("alexnet", n=1)
    np16 = NetworkPlan.build("alexnet", n=1, dtype="bfloat16")
    assert all(s.plan.dtype_bytes == 4 for s in np32.steps)
    assert all(s.plan.dtype_bytes == 2 for s in np16.steps)
    # element-count accounting (the Ops/MAcc goldens) is dtype-invariant
    a32 = np32.arch_compare()["ops_per_macc"]
    a16 = np16.arch_compare()["ops_per_macc"]
    assert a32 == a16
    # byte accounting is not
    assert np16.hbm_bytes()["total"] * 2 == np32.hbm_bytes()["total"]


def test_kernel_plans_key_on_input_dtype():
    """trim_conv2d builds its plan from x.dtype: the bf16 kernel call
    must price 2-byte VMEM residency, not a hard-coded 4."""
    from repro.kernels.trim_conv2d import make_plan
    p16 = make_plan((1, 16, 16, 8), (3, 3, 8, 8),
                    dtype_bytes=jnp.bfloat16)
    p32 = make_plan((1, 16, 16, 8), (3, 3, 8, 8), dtype_bytes=4)
    assert p16.dtype_bytes == 2
    assert p16.vmem_resident_bytes < p32.vmem_resident_bytes


# ---------------------------------------------------------------------------
# Autotune: the conv2d_q8 namespace
# ---------------------------------------------------------------------------

def test_q8_knobs_come_only_from_q8_namespace():
    """An f32 record for the identical geometry must never leak tuning
    knobs into the int8 route, and vice versa."""
    x_shape, w_shape = (1, 16, 16, 8), (3, 3, 8, 12)
    f32_key = autotune.make_key(x_shape, w_shape, stride=1, pad=0)
    q8_key = autotune.make_key(x_shape, w_shape, stride=1, pad=0,
                               dtype="int8", op="conv2d_q8")
    assert q8_key.startswith("conv2d_q8:")
    assert f32_key != q8_key
    autotune.store(f32_key, dict(tile_h=8, tile_cout=4, dataflow="carry"))
    assert autotune.knobs_for(x_shape, w_shape, dtype="int8",
                              op="conv2d_q8") is None
    autotune.store(q8_key, dict(tile_h=4, tile_cout=8, dataflow="halo"))
    got = autotune.knobs_for(x_shape, w_shape, dtype="int8",
                             op="conv2d_q8")
    assert (got["tile_h"], got["dataflow"]) == (4, "halo")
    # the plain conv2d consult still sees only its own record
    assert autotune.knobs_for(x_shape, w_shape)["tile_h"] == 8


def test_tune_q8_round_trip_and_forward_consult():
    """``tune(op="conv2d_q8", dtype="int8")`` persists under the q8
    namespace with 1-byte candidate pricing, and the quantized forward
    actually honors the record (observable via the packed tile_cout
    guard: a mismatched record is ignored)."""
    x = _f32((1, 16, 16, 8))
    w = _f32((3, 3, 8, 12), 0.1)
    rec = autotune.tune(x.shape, w.shape, stride=1, pad=0, dtype="int8",
                        op="conv2d_q8")
    key = autotune.make_key(x.shape, w.shape, stride=1, pad=0,
                            dtype="int8", op="conv2d_q8")
    assert autotune.lookup(key) == rec
    pk = ops.quantize_conv2d_weights(
        w, None, x_scale=float(jnp.max(jnp.abs(x))) / 127.0,
        x_zero_point=0, tile_cout=rec["tile_cout"])
    got = ops.conv2d(x, pk, padding="valid")
    q = _quantize_problem(x, w, zero_point=0)
    want = ref.conv2d_quantized(q["x_q"], q["w_q"], x_scale=q["x_scale"],
                                x_zero_point=0, w_scale=q["w_scale"],
                                padding="valid")
    assert bool(jnp.all(got == want))


def test_measured_q8_tune_runs_int8_kernel():
    """measure=True on an int8 problem wall-clocks the *int8* kernel
    (integer operands + unit scale row) without tripping the
    int8-route argument validation."""
    rec = autotune.tune((1, 12, 12, 8), (3, 3, 8, 8), stride=1, pad=0,
                        dtype="int8", op="conv2d_q8", measure=True,
                        measure_top_k=1)
    assert rec["source"] == "measured"


# ---------------------------------------------------------------------------
# Energy model (satellite 6's gate, unit-level)
# ---------------------------------------------------------------------------

def test_energy_model_int8_vs_f32():
    from repro.core import energy
    int8 = energy.energy_per_inference("vgg16", dtype_bytes=1,
                                       mac="mac_int8")
    f32 = energy.energy_per_inference("vgg16", dtype_bytes=4,
                                      mac="mac_fp32")
    # the acceptance gate: quantization buys > 2x modeled energy
    assert f32["total_uJ"] / int8["total_uJ"] > 2.0
    assert f32["tops_per_watt"] < int8["tops_per_watt"]
    # the OPs/pJ == TOPS/W identity holds against a by-hand recompute
    from repro.core import model as acc_model
    ops_total = 2 * sum(l.macs for l in acc_model.vgg16_layers())
    assert int8["tops_per_watt"] == pytest.approx(
        ops_total / (int8["total_uJ"] * 1e6))
    with pytest.raises(ValueError, match="unknown network"):
        energy.energy_per_inference("resnet50")
