"""Fault-tolerance tests: atomic checkpointing, resume, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.launch.mesh import compat_make_mesh
from repro.data import DataConfig, SyntheticStream, make_batch
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.models import ModelConfig
from repro.models.base import init_params
from repro.optim import AdamWConfig

RULES = make_rules()
CFG = ModelConfig(family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=64, attn_impl="ref",
                  remat=False)
OPT = AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=50)


def _train(state, step_fn, stream, n):
    for _ in range(n):
        batch = jax.tree.map(jnp.asarray, next(stream))
        state, m = step_fn(state, batch)
    return state, m


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = init_params(steps.train_state_decl(CFG, OPT),
                        jax.random.PRNGKey(0), jnp.float32)
    mgr.save(7, state, meta={"data_state": {"seed": 1, "step": 7}})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 7
    assert manifest["data_state"]["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_publish_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    # a stale .tmp dir (simulated crash) is ignored by restore
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 4


def test_crash_resume_training_is_exact(tmp_path):
    """Train 6 steps; 'crash' after 3; resume from the checkpoint and data
    state -> final params identical to the uninterrupted run."""
    dc = DataConfig(batch=4, seq=16, vocab=64, task="copy", seed=5)
    step_fn = jax.jit(steps.make_train_step(CFG, OPT, RULES))

    # uninterrupted
    s_full = init_params(steps.train_state_decl(CFG, OPT),
                         jax.random.PRNGKey(0), jnp.float32)
    s_full, _ = _train(s_full, step_fn, SyntheticStream(dc), 6)

    # interrupted at step 3
    mgr = CheckpointManager(str(tmp_path))
    s_a = init_params(steps.train_state_decl(CFG, OPT),
                      jax.random.PRNGKey(0), jnp.float32)
    stream = SyntheticStream(dc)
    s_a, _ = _train(s_a, step_fn, stream, 3)
    mgr.save(3, s_a, meta={"data_state": stream.state()})
    del s_a                                 # crash

    template = init_params(steps.train_state_decl(CFG, OPT),
                           jax.random.PRNGKey(99), jnp.float32)
    s_b, manifest = mgr.restore(template)
    stream_b = SyntheticStream.from_state(dc, manifest["data_state"])
    s_b = jax.tree.map(jnp.asarray, s_b)
    s_b, _ = _train(s_b, step_fn, stream_b, 3)

    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_elastic_restore_new_mesh(tmp_path):
    """A checkpoint written under one mesh restores onto a different mesh
    shape (elastic restart): arrays are placed with the new shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, meta={"mesh": [1, 1]})

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P(None, "model"))}
    restored, _ = mgr.restore(state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.is_equivalent_to(shardings["w"], 2)
