"""Fault-tolerance tests: atomic checkpointing, resume, elastic restore,
and sha256 integrity verification (DESIGN.md §9)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.launch.mesh import compat_make_mesh
from repro.data import DataConfig, SyntheticStream, make_batch
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.models import ModelConfig
from repro.models.base import init_params
from repro.optim import AdamWConfig

RULES = make_rules()
CFG = ModelConfig(family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=64, attn_impl="ref",
                  remat=False)
OPT = AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=50)


def _train(state, step_fn, stream, n):
    for _ in range(n):
        batch = jax.tree.map(jnp.asarray, next(stream))
        state, m = step_fn(state, batch)
    return state, m


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = init_params(steps.train_state_decl(CFG, OPT),
                        jax.random.PRNGKey(0), jnp.float32)
    mgr.save(7, state, meta={"data_state": {"seed": 1, "step": 7}})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 7
    assert manifest["data_state"]["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_publish_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    # a stale .tmp dir (simulated crash) is ignored by restore
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 4


def test_crash_resume_training_is_exact(tmp_path):
    """Train 6 steps; 'crash' after 3; resume from the checkpoint and data
    state -> final params identical to the uninterrupted run."""
    dc = DataConfig(batch=4, seq=16, vocab=64, task="copy", seed=5)
    step_fn = jax.jit(steps.make_train_step(CFG, OPT, RULES))

    # uninterrupted
    s_full = init_params(steps.train_state_decl(CFG, OPT),
                         jax.random.PRNGKey(0), jnp.float32)
    s_full, _ = _train(s_full, step_fn, SyntheticStream(dc), 6)

    # interrupted at step 3
    mgr = CheckpointManager(str(tmp_path))
    s_a = init_params(steps.train_state_decl(CFG, OPT),
                      jax.random.PRNGKey(0), jnp.float32)
    stream = SyntheticStream(dc)
    s_a, _ = _train(s_a, step_fn, stream, 3)
    mgr.save(3, s_a, meta={"data_state": stream.state()})
    del s_a                                 # crash

    template = init_params(steps.train_state_decl(CFG, OPT),
                           jax.random.PRNGKey(99), jnp.float32)
    s_b, manifest = mgr.restore(template)
    stream_b = SyntheticStream.from_state(dc, manifest["data_state"])
    s_b = jax.tree.map(jnp.asarray, s_b)
    s_b, _ = _train(s_b, step_fn, stream_b, 3)

    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


# ---------------------------------------------------------------------------
# Integrity: sha256 sidecar verification (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _step_file(tmp_path, step, name):
    return os.path.join(str(tmp_path), f"step_{step:08d}", name)


def test_save_writes_sha256_sidecar(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.arange(8.0)})
    with open(_step_file(tmp_path, 1, "sha256.json")) as f:
        digests = json.load(f)
    assert set(digests) == {"arrays.npz", "manifest.json"}
    assert all(len(d) == 64 for d in digests.values())
    # verified restore round-trips
    restored, _ = mgr.restore({"w": jnp.arange(8.0)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_bitflip_raises_checkpoint_corrupt_error(tmp_path):
    from repro.testing import faults
    mgr = CheckpointManager(str(tmp_path))
    template = {"w": jnp.arange(64.0)}
    mgr.save(1, template)
    faults.flip_byte(_step_file(tmp_path, 1, "arrays.npz"))
    with pytest.raises(CheckpointCorruptError, match="sha256 mismatch"):
        mgr.restore(template)
    # the escape hatch skips verification (salvage path): whether the
    # load then succeeds depends on where the flip landed, but it must
    # not be an integrity error
    try:
        mgr.restore(template, verify=False)
    except CheckpointCorruptError:                # pragma: no cover
        pytest.fail("verify=False must skip the integrity check")
    except Exception:
        pass                                      # npz CRC may still balk


def test_truncation_raises_checkpoint_corrupt_error(tmp_path):
    from repro.testing import faults
    mgr = CheckpointManager(str(tmp_path))
    template = {"w": jnp.arange(64.0), "b": jnp.ones((16, 16))}
    mgr.save(3, template)
    faults.truncate_file(_step_file(tmp_path, 3, "arrays.npz"), 0.5)
    with pytest.raises(CheckpointCorruptError, match="sha256 mismatch"):
        mgr.restore(template)


def test_manifest_tamper_is_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.arange(4.0)}, meta={"lr": 1e-3})
    mpath = _step_file(tmp_path, 1, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["lr"] = 99.0                         # hand edit
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointCorruptError, match="manifest.json"):
        mgr.restore({"w": jnp.arange(4.0)})
    # verify=False restores the tampered (but loadable) checkpoint
    _, got = mgr.restore({"w": jnp.arange(4.0)}, verify=False)
    assert got["lr"] == 99.0


def test_legacy_checkpoint_without_sidecar_warns_and_restores(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"w": jnp.arange(4.0)})
    os.remove(_step_file(tmp_path, 2, "sha256.json"))  # pre-sidecar era
    with pytest.warns(RuntimeWarning, match="unverified"):
        restored, _ = mgr.restore({"w": jnp.arange(4.0)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(4.0))


def test_crash_before_publish_keeps_previous_step_restorable(tmp_path):
    """A crash between the temp write and the atomic rename leaves the
    previous published step as the (verified) latest."""
    from repro.testing import faults
    from repro.testing.faults import InjectedCrash
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros(4)})
    with faults.crash_before_publish("checkpoint"):
        with pytest.raises(InjectedCrash):
            mgr.save(2, {"w": jnp.ones(4)})
    assert mgr.latest_step() == 1                 # step 2 never published
    restored, manifest = mgr.restore({"w": jnp.zeros(4)})  # verified
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.zeros(4))
    # the interrupted save retries cleanly once the fault is gone
    mgr.save(2, {"w": jnp.ones(4)})
    assert mgr.latest_step() == 2


def test_elastic_restore_new_mesh(tmp_path):
    """A checkpoint written under one mesh restores onto a different mesh
    shape (elastic restart): arrays are placed with the new shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, meta={"mesh": [1, 1]})

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P(None, "model"))}
    restored, _ = mgr.restore(state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.is_equivalent_to(shardings["w"], 2)
