"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward + one train step on CPU, shape + NaN asserts; plus
parameter-count checks against the published sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.models import api
from repro.models.base import init_params
from repro.optim import AdamWConfig

RULES = make_rules()
KEY = jax.random.PRNGKey(0)
ARCHS = registry.archs()


def _smoke_batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.frontend == "vision":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["src"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = registry.get(arch).SMOKE
    params = init_params(api.params(cfg), KEY, jnp.float32)
    batch = _smoke_batch(cfg)
    logits, aux = api.forward(params, batch, cfg, RULES)
    exp_s = 16 + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get(arch).SMOKE
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    step = steps.make_train_step(cfg, opt_cfg, RULES)
    decl = steps.train_state_decl(cfg, opt_cfg)
    state = init_params(decl, KEY, jnp.float32)
    batch = _smoke_batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(new_state["step"]) == 1
    # parameters actually moved
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch,low,high", [
    ("phi3.5-moe-42b-a6.6b", 40e9, 44e9),
    ("qwen3-moe-30b-a3b", 29e9, 32e9),
    ("falcon-mamba-7b", 6.8e9, 7.8e9),
    ("starcoder2-7b", 6.8e9, 7.8e9),
    ("starcoder2-3b", 2.9e9, 3.4e9),
    ("llama3-405b", 400e9, 412e9),
    ("qwen2.5-3b", 3.0e9, 3.6e9),
    ("llava-next-34b", 33e9, 36e9),
    ("seamless-m4t-large-v2", 1.3e9, 2.4e9),
    ("recurrentgemma-2b", 2.5e9, 3.2e9),
])
def test_param_counts_match_published(arch, low, high):
    n = registry.count_params(registry.get(arch).CONFIG)
    assert low <= n <= high, f"{arch}: {n/1e9:.2f}B"


def test_active_params_moe():
    n = registry.count_active_params(
        registry.get("phi3.5-moe-42b-a6.6b").CONFIG)
    assert 6e9 <= n <= 7.3e9
    n = registry.count_active_params(registry.get("qwen3-moe-30b-a3b").CONFIG)
    assert 2.8e9 <= n <= 3.8e9


def test_all_cells_well_formed():
    """Every (arch x shape) cell has input specs and model flops; the
    long_500k skips are exactly the pure full-attention archs."""
    skips = []
    for arch in ARCHS:
        mod = registry.get(arch)
        for shape, plan in mod.PLANS.items():
            if plan.skip:
                skips.append((arch, shape))
                continue
            specs = registry.input_specs(mod.CONFIG, plan)
            assert "tokens" in specs
            assert registry.model_flops(mod.CONFIG, plan) > 0
    assert all(s == "long_500k" for _, s in skips)
    skipped_archs = {a for a, _ in skips}
    assert "falcon-mamba-7b" not in skipped_archs
    assert "recurrentgemma-2b" not in skipped_archs
    assert len(skipped_archs) == 8
