"""Serving-engine tests (DESIGN.md §10): batching policy, padding
isolation, FIFO order, backpressure, deterministic replay, and the
differential gate — every served row bit-matches the single-request
tuned forward.  The chaos case demotes a replica mid-load and asserts
it keeps serving with the expected ``guard.events()`` surfaced in
``stats()``.

Tests that need real forwards use a deliberately small 3-layer topology
so the suite stays tier-1 fast; policy-only tests use fake replicas and
injected service times on the virtual timeline — no jax, no wall-clock.
"""

import numpy as np
import pytest

import jax

from repro.core import guard, serving
from repro.core.model import ConvLayer
from repro.core.serving import (BucketGrid, QueueFull, Replica,
                                ServingEngine, pow2_buckets, replay)
from repro.models import layers as mlayers
from repro.models.base import init_params
from repro.testing import faults
from repro.testing.load import (TraceRecorder, burst_arrivals,
                                poisson_arrivals, ramp_arrivals)

pytestmark = pytest.mark.serving

TOPO = [ConvLayer("t0", ifmap=12, in_channels=3, out_channels=8,
                  kernel=3, stride=1, padding=1),
        ConvLayer("t1", ifmap=12, in_channels=8, out_channels=8,
                  kernel=3, stride=2, padding=1),
        ConvLayer("t2", ifmap=6, in_channels=8, out_channels=16,
                  kernel=3, stride=1, padding=1)]
RNG = np.random.default_rng(8)


def _params():
    return init_params(
        mlayers.cnn_params_from_layers(TOPO, n_classes=10),
        jax.random.PRNGKey(0))


def _engine(**kw):
    kw.setdefault("buckets", (1, 2, 4))
    return ServingEngine.for_topology(TOPO, _params(), **kw)


def _echo_replica(name="echo"):
    """A fake replica whose output row encodes the input row — lets
    policy tests verify routing without any real forward."""
    return Replica(name=name, fn=lambda b: np.asarray(b).sum(
        axis=tuple(range(1, np.asarray(b).ndim))))


def _xs(n, shape=(12, 12, 3)):
    return RNG.standard_normal((n,) + shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Bucket selection: exact and deterministic
# ---------------------------------------------------------------------------

def test_bucket_for_is_exact():
    g = BucketGrid.build((1, 2, 4, 8))
    assert [g.bucket_for(n) for n in range(1, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 8]
    assert [g.pad_rows(n) for n in range(1, 9)] == \
        [0, 0, 1, 0, 3, 2, 1, 0]


def test_bucket_for_bounds():
    g = BucketGrid.build((2, 4))
    assert g.bucket_for(1) == 2       # smallest bucket still fits
    with pytest.raises(ValueError):
        g.bucket_for(0)
    with pytest.raises(ValueError):
        g.bucket_for(5)               # beyond max_bucket: caller splits
    with pytest.raises(ValueError):
        BucketGrid.build(())
    with pytest.raises(ValueError):
        BucketGrid.build((0, 2))


def test_grid_sorts_and_dedups():
    g = BucketGrid.build((8, 1, 4, 4, 2))
    assert g.buckets == (1, 2, 4, 8)
    assert g.max_bucket == 8


def test_pow2_buckets():
    assert pow2_buckets(8) == (1, 2, 4, 8)
    assert pow2_buckets(6) == (1, 2, 4, 6)
    assert pow2_buckets(1) == (1,)
    with pytest.raises(ValueError):
        pow2_buckets(0)


# ---------------------------------------------------------------------------
# Differential gate: served rows bit-match the unbatched forward
# ---------------------------------------------------------------------------

def test_served_rows_bit_match_single_request_forward():
    eng = _engine()
    eng.prewarm()
    xs = _xs(7)
    trace = [(t, i, xs[i])
             for i, t in enumerate(poisson_arrivals(500.0, 7, seed=3))]
    results, rejected = replay(eng, trace)
    assert not rejected and len(results) == 7
    # batches actually formed at more than one bucket size
    assert len(eng.stats()["bucket_batches"]) >= 1
    for i in range(7):
        assert np.array_equal(results[i], eng.forward_one(xs[i])), i


def test_padding_rows_never_leak():
    """Serving the same requests under two engines whose padding fill
    differs wildly must produce identical rows — proof the padded rows
    cannot influence any real row."""
    xs = _xs(3)     # 3 requests -> bucket 4: one padding row
    outs = {}
    for fill in (0.0, 1e9):
        eng = _engine(pad_fill=fill)
        eng.prewarm()
        trace = [(0.0, i, xs[i]) for i in range(3)]
        results, _ = replay(eng, trace)
        outs[fill] = results
    assert eng.stats()["bucket_batches"] == {4: 1}
    for i in range(3):
        assert np.array_equal(outs[0.0][i], outs[1e9][i]), i


# ---------------------------------------------------------------------------
# Queue policy: FIFO, backpressure, determinism (fake replicas)
# ---------------------------------------------------------------------------

def test_fifo_within_bucket():
    eng = ServingEngine([_echo_replica()], buckets=(1, 2, 4),
                        input_shape=(2,))
    for rid in range(10):
        eng.submit(rid, np.full(2, rid, np.float32), now=float(rid))
    order = []
    t = 10.0
    while eng.pending():
        out, dt = eng.step(now=t, service_model=lambda b: 1.0)
        order.extend(rid for rid, _ in out)
        t += dt
    assert order == list(range(10))     # strict arrival order
    recs = eng.recorder.completed()
    assert [r.rid for r in recs] == list(range(10))


def test_backpressure_bounds_queue_depth():
    eng = ServingEngine([_echo_replica()], buckets=(1, 2, 4),
                        max_queue=4)
    for rid in range(4):
        eng.submit(rid, np.zeros(2), now=0.0)
    with pytest.raises(QueueFull):
        eng.submit(99, np.zeros(2), now=0.0)
    assert eng.recorder.max_queue_depth == 4
    assert eng.pending() == 4

    # replay sheds (records) instead of raising: open-loop load
    eng2 = ServingEngine([_echo_replica()], buckets=(1, 2, 4),
                         max_queue=4)
    trace = [(0.0, i, np.zeros(2)) for i in range(12)]
    results, rejected = replay(eng2, trace,
                               service_model=lambda b: 1.0)
    assert len(results) + len(rejected) == 12
    assert eng2.recorder.max_queue_depth <= 4
    assert eng2.stats()["rejected"] == len(rejected)


def test_max_queue_must_fit_a_batch():
    with pytest.raises(ValueError):
        ServingEngine([_echo_replica()], buckets=(1, 8), max_queue=4)


def test_replay_is_deterministic():
    def run():
        eng = ServingEngine([_echo_replica("a"), _echo_replica("b")],
                            buckets=(1, 2, 4))
        trace = [(t, i, np.full(2, i, np.float32)) for i, t in
                 enumerate(ramp_arrivals(5.0, 50.0, 20, seed=7))]
        results, rejected = replay(eng, trace,
                                   service_model=lambda b: 0.05 * b)
        timeline = [(r.rid, r.t_enqueue, r.t_execute, r.t_complete,
                     r.bucket, r.replica)
                    for r in eng.recorder.completed()]
        return results, rejected, timeline

    r1, rej1, tl1 = run()
    r2, rej2, tl2 = run()
    assert tl1 == tl2 and rej1 == rej2
    assert sorted(r1) == sorted(r2)
    assert all(np.array_equal(r1[k], r2[k]) for k in r1)


def test_continuous_batching_fills_buckets_under_burst():
    eng = ServingEngine([_echo_replica()], buckets=(1, 2, 4))
    # 8 simultaneous arrivals: two full max-bucket batches, FIFO
    trace = [(0.0, i, np.zeros(2)) for i in range(8)]
    replay(eng, trace, service_model=lambda b: 1.0)
    assert eng.stats()["bucket_batches"] == {4: 2}
    for r in eng.recorder.completed():
        assert r.bucket == 4 and r.batch_real == 4


def test_round_robin_spreads_load_over_replicas():
    eng = ServingEngine([_echo_replica("a"), _echo_replica("b")],
                        buckets=(1,))
    trace = [(float(i), i, np.zeros(2)) for i in range(6)]
    replay(eng, trace, service_model=lambda b: 0.1)
    served = eng.stats()["replicas"]
    assert served["a"]["served"] == 3 and served["b"]["served"] == 3


def test_recorder_lifecycle_and_latency():
    rec = TraceRecorder()
    eng = ServingEngine([_echo_replica()], buckets=(1, 2),
                        recorder=rec)
    eng.submit(0, np.zeros(2), now=1.0)
    eng.submit(1, np.zeros(2), now=1.5)
    out, dt = eng.step(now=2.0, service_model=lambda b: 0.5)
    assert {rid for rid, _ in out} == {0, 1} and dt == 0.5
    r0 = rec.records[0]
    assert (r0.t_enqueue, r0.t_execute, r0.t_complete) == (1.0, 2.0, 2.5)
    assert r0.latency == 1.5 and r0.queue_wait == 1.0
    assert r0.bucket == 2 and r0.batch_real == 2
    s = rec.summary()
    assert s["count"] == 2 and s["buckets"][2]["count"] == 2


def test_arrival_generators_are_seed_deterministic():
    assert poisson_arrivals(10.0, 5, seed=4) == \
        poisson_arrivals(10.0, 5, seed=4)
    assert poisson_arrivals(10.0, 5, seed=4) != \
        poisson_arrivals(10.0, 5, seed=5)
    bursts = burst_arrivals(3, 4, 1.0)
    assert bursts == [0.0] * 4 + [1.0] * 4 + [2.0] * 4
    ramp = ramp_arrivals(5.0, 50.0, 10, seed=1)
    assert ramp == sorted(ramp) and len(ramp) == 10


# ---------------------------------------------------------------------------
# Prewarm: no cold paths after it
# ---------------------------------------------------------------------------

def test_prewarm_eliminates_cold_tunes():
    eng = _engine()
    eng.prewarm()
    xs = _xs(5)
    trace = [(0.0, i, xs[i]) for i in range(5)]
    replay(eng, trace)
    st = eng.stats()
    assert st["cold_tunes"] == 0
    assert st["prewarmed_buckets"] == [1, 2, 4]


def test_unprewarmed_bucket_counts_as_cold_tune():
    eng = _engine()
    xs = _xs(2)
    eng.submit(0, xs[0], now=0.0)
    eng.submit(1, xs[1], now=0.0)
    eng.step(now=0.0)
    assert eng.stats()["cold_tunes"] == 1    # bucket 2, tuned on the spot
    eng.submit(2, xs[0], now=1.0)
    eng.submit(3, xs[1], now=1.0)
    eng.step(now=1.0)
    assert eng.stats()["cold_tunes"] == 1    # warm on the second hit


# ---------------------------------------------------------------------------
# Chaos: a demoted replica keeps serving, visibly
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_replica_demoted_mid_load_keeps_serving():
    # eager replicas: the guarded tier chain dispatches per call, so a
    # fault injected mid-load demotes on the very next batch
    eng = _engine(jit=False)
    eng.prewarm()
    xs = _xs(6)
    t = 0.0

    def serve(rid):
        nonlocal t
        eng.submit(rid, xs[rid], now=t)
        out, _ = eng.step(now=t, service_model=lambda b: 0.1)
        t += 0.1
        return dict(out)[rid]

    clean = [serve(rid) for rid in range(3)]
    assert not guard.events()
    before = [eng.forward_one(xs[i]) for i in range(6)]

    with faults.lowering_failure("pallas"):
        degraded = [serve(rid) for rid in range(3, 6)]

    # the engine kept serving every request...
    st = eng.stats()
    assert st["served"] == 6 and st["pending"] == 0
    # ...the demotions are attributed to the replica that hit them...
    rep = st["replicas"]["replica0"]
    assert rep["degraded"] and rep["served"] == 6
    evs = rep["guard_events"]
    assert evs and all(e["tier"] == "pallas" and e["to"] == "ref"
                       for e in evs)
    assert [dict(e) for e in guard.events()] == evs
    # ...and the demoted tier still matches the healthy forward (ref
    # numerics == pallas numerics within the stack's exactness contract)
    for rid, row in zip(range(3), clean):
        assert np.array_equal(row, before[rid])
    for rid, row in zip(range(3, 6), degraded):
        np.testing.assert_allclose(row, before[rid], rtol=2e-5,
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# Engine construction guards
# ---------------------------------------------------------------------------

def test_engine_needs_a_replica():
    with pytest.raises(ValueError):
        ServingEngine([], buckets=(1,))


def test_duplicate_rid_rejected():
    eng = ServingEngine([_echo_replica()], buckets=(1,))
    eng.submit(0, np.zeros(2), now=0.0)
    with pytest.raises(ValueError):
        eng.submit(0, np.zeros(2), now=0.1)


def test_serving_module_exports():
    for name in serving.__all__:
        assert getattr(serving, name) is not None
