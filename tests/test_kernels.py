"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode),
including hypothesis property sweeps over shapes/dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.trim_conv2d import hbm_traffic_model

RNG = np.random.default_rng(7)


def _allclose(a, b, tol=2e-3):
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(b))) + 1e-6
    assert float(jnp.max(jnp.abs(a - b))) / scale < tol


# ---------------------------------------------------------------------------
# trim_conv2d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataflow", ["carry", "halo"])
@pytest.mark.parametrize("h,w,cin,cout,k,s,padding", [
    (8, 8, 4, 8, 3, 1, "same"),
    (14, 14, 16, 32, 3, 1, "same"),
    (17, 13, 3, 5, 3, 1, "valid"),
    (27, 27, 6, 8, 5, 1, "same"),
    (32, 32, 3, 4, 3, 2, "same"),
    (56, 56, 3, 4, 11, 4, "valid"),     # AlexNet conv1 (kernel tiling)
    (16, 16, 4, 4, 1, 1, "valid"),
    (12, 20, 5, 7, 7, 3, "valid"),
])
def test_conv2d_vs_oracle(h, w, cin, cout, k, s, padding, dataflow):
    x = jnp.asarray(RNG.standard_normal((2, h, w, cin)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((k, k, cin, cout)) * 0.2,
                     jnp.float32)
    got = ops.conv2d(x, wt, stride=s, padding=padding, impl="pallas",
                     dataflow=dataflow)
    want = ref.conv2d(x, wt, stride=s, padding=padding)
    assert got.shape == want.shape
    _allclose(got, want)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3),
                                       (jnp.bfloat16, 3e-2)])
def test_conv2d_dtypes(dtype, tol):
    x = jnp.asarray(RNG.standard_normal((1, 12, 12, 8)), dtype)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 8, 16)) * 0.2, dtype)
    _allclose(ops.conv2d(x, wt, impl="pallas"),
              ref.conv2d(x.astype(jnp.float32), wt.astype(jnp.float32)),
              tol)


@settings(max_examples=12, deadline=None)
@given(h=st.integers(6, 24), w=st.integers(6, 24), cin=st.integers(1, 8),
       cout=st.integers(1, 8), k=st.sampled_from([1, 3, 5]),
       s=st.sampled_from([1, 2]))
def test_conv2d_property(h, w, cin, cout, k, s):
    if h < k or w < k:
        return
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, h, w, cin)),
                    jnp.float32)
    wt = jnp.asarray(np.random.default_rng(1).standard_normal(
        (k, k, cin, cout)) * 0.3, jnp.float32)
    _allclose(ops.conv2d(x, wt, stride=s, padding="valid", impl="pallas"),
              ref.conv2d(x, wt, stride=s, padding="valid"))


def test_conv2d_tile_boundaries():
    """Strips + carry (or halo over-fetch) must agree with the oracle at
    every tile_h."""
    from repro.kernels.trim_conv2d import trim_conv2d
    x = jnp.asarray(RNG.standard_normal((1, 16, 10, 4)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 4, 8)) * 0.3, jnp.float32)
    want = ref.conv2d(x, wt, padding="valid")
    for th in (1, 2, 4, 8, 16):
        for df in ("carry", "halo"):
            _allclose(trim_conv2d(x, wt, tile_h=th, dataflow=df), want)


# ---------------------------------------------------------------------------
# packed weights (load-time pad/reshape) vs the per-call path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("groups,cout,tile_cout,activation", [
    (1, 12, None, None),
    (1, 10, 4, "relu"),      # ragged cout tile: padded channels sliced off
    (4, 8, None, "gelu"),    # grouped
    (8, 16, 2, None),        # depthwise-ish with tiny tiles
])
def test_packed_weights_match_unpacked(groups, cout, tile_cout, activation):
    cin = 8
    x = jnp.asarray(RNG.standard_normal((2, 12, 11, cin)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, cin // groups, cout)) * .3,
                    jnp.float32)
    b = jnp.asarray(RNG.standard_normal((cout,)), jnp.float32)
    want = ops.conv2d(x, w, bias=b, activation=activation,
                      feature_group_count=groups)
    pk = ops.pack_conv2d_weights(w, b, groups=groups, tile_cout=tile_cout)
    got = ops.conv2d(x, pk, activation=activation)
    _allclose(got, want, tol=1e-6)
    for df in ("carry", "halo"):
        _allclose(ops.conv2d(x, pk, activation=activation, dataflow=df),
                  want, tol=1e-6)


def test_packed_weights_is_jit_transparent_pytree():
    """Packed params must survive jit boundaries: arrays are leaves, the
    tile knobs static."""
    x = jnp.asarray(RNG.standard_normal((1, 10, 10, 4)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 4, 6)) * .3, jnp.float32)
    pk = ops.pack_conv2d_weights(w, tile_cout=2)

    @jax.jit
    def fwd(x, pk):
        return ops.conv2d(x, pk, padding="valid")

    _allclose(fwd(x, pk), ref.conv2d(x, w, padding="valid"))
    leaves = jax.tree_util.tree_leaves(pk)
    assert all(hasattr(l, "shape") for l in leaves)


def test_pack_rejects_kernel_tiled_k():
    w = jnp.zeros((11, 11, 3, 4), jnp.float32)
    with pytest.raises(ValueError):
        ops.pack_conv2d_weights(w)


def test_hbm_traffic_model_shadow_vs_halo():
    """The kernel's traffic model mirrors the paper: 'trim' mode re-fetches
    K-1 halo rows per strip; '3dtrim' (carry) has zero overhead."""
    a = hbm_traffic_model(1, 224, 224, 64, 64, 3, tile_h=8, mode="3dtrim")
    b = hbm_traffic_model(1, 224, 224, 64, 64, 3, tile_h=8, mode="trim")
    assert a["overhead_pct"] == 0.0
    assert b["overhead_pct"] > 0
    assert b["input"] > a["input"]


# ---------------------------------------------------------------------------
# trim_conv1d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,d,k", [(2, 16, 8, 4), (1, 100, 24, 4),
                                     (3, 7, 5, 2), (2, 33, 16, 3)])
def test_conv1d_vs_oracle(b, l, d, k):
    x = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((k, d)), jnp.float32)
    _allclose(ops.depthwise_conv1d(x, wt, impl="pallas"),
              ref.depthwise_conv1d(x, wt))


def test_conv1d_decode_step_equals_full():
    """The decode-time carry is the shadow-register state: stepping one
    token at a time reproduces the full convolution."""
    x = jnp.asarray(RNG.standard_normal((2, 10, 8)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32)
    full = ref.depthwise_conv1d(x, wt)
    state = jnp.zeros((2, 3, 8))
    for t in range(10):
        state, y = ops.depthwise_conv1d_step(state, x[:, t], wt)
        _allclose(y, full[:, t])


# ---------------------------------------------------------------------------
# attention (pallas flash + chunked jnp) vs dense oracle
# ---------------------------------------------------------------------------

CASES = [
    (2, 32, 32, 4, 2, 16, True, None, None),
    (1, 64, 64, 8, 8, 32, True, 30.0, None),
    (2, 17, 47, 4, 1, 16, True, None, None),
    (2, 32, 32, 4, 2, 16, False, None, None),
    (1, 64, 64, 4, 2, 16, True, None, 16),
    (2, 1, 40, 8, 2, 32, True, None, None),
]


@pytest.mark.parametrize("impl", ["pallas", "chunked", "chunked_unroll"])
@pytest.mark.parametrize("case", CASES)
def test_attention_vs_oracle(impl, case):
    b, lq, lk, hq, hkv, d, causal, cap, win = case
    q = jnp.asarray(RNG.standard_normal((b, lq, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, lk, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, lk, hkv, d)), jnp.float32)
    want = ref.attention(q, k, v, causal=causal, logits_soft_cap=cap,
                         window=win)
    got = ops.attention(q, k, v, causal=causal, soft_cap=cap, window=win,
                        impl=impl, chunk=16)
    _allclose(got, want)


def test_decode_attention_vs_oracle():
    b, lmax, hq, hkv, d = 2, 24, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, 1, hq, d)), jnp.float32)
    kc = jnp.asarray(RNG.standard_normal((b, lmax, hkv, d)), jnp.float32)
    vc = jnp.asarray(RNG.standard_normal((b, lmax, hkv, d)), jnp.float32)
    clen = 17
    want = ref.attention(q, kc[:, :clen], vc[:, :clen], causal=True)
    _allclose(ops.decode_attention(q, kc, vc, jnp.full((b,), clen)), want)


@settings(max_examples=10, deadline=None)
@given(lq=st.integers(1, 40), lk_extra=st.integers(0, 40),
       hkv=st.sampled_from([1, 2, 4]), group=st.sampled_from([1, 2, 3]),
       causal=st.booleans())
def test_attention_property(lq, lk_extra, hkv, group, causal):
    lk = lq + lk_extra
    b, d = 1, 8
    hq = hkv * group
    rng = np.random.default_rng(lq * 100 + lk)
    q = jnp.asarray(rng.standard_normal((b, lq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, lk, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, lk, hkv, d)), jnp.float32)
    want = ref.attention(q, k, v, causal=causal)
    _allclose(ops.attention(q, k, v, causal=causal, impl="chunked",
                            chunk=8), want)
