"""Analytical model tests: Fig. 1 curve, Fig. 6 bands, Table I, GeMM."""

import math

import pytest

from repro.core import model as m
from repro.core import energy


def test_fig1_overhead_shape():
    """Overhead decreases with ifmap size and is largest for small ifmaps
    (the paper's motivation: deep-CNN layers suffer most)."""
    curve = m.fig1_curve()
    sizes = sorted(curve)
    vals = [curve[s] for s in sizes]
    assert all(a > b for a, b in zip(vals, vals[1:]))
    assert curve[14] == pytest.approx(100 * (11 * 4) / 196)   # 22.45%
    assert curve[224] == pytest.approx(100 * (221 * 4) / (224 * 224))


def test_3dtrim_zero_overhead():
    for s in (14, 28, 224):
        assert m.ifmap_reads_per_channel(s, s, 3, 1, shadow=True) == s * s


def test_fig6_vgg16_band():
    """Improvement over TrIM for every VGG-16 layer is ~3x (paper band:
    2.82-3.37x; our counting assumptions land at 3.2-3.45x — see
    EXPERIMENTS.md for the assumption-by-assumption comparison)."""
    rows = m.fig6("vgg16")
    assert len(rows) == 13
    for r in rows:
        assert 2.8 <= r["improvement"] <= 3.6, r
        assert r["3d-trim"] > r["trim"]


def test_fig6_alexnet_band():
    rows = m.fig6("alexnet")
    assert len(rows) == 5
    for r in rows:
        assert r["improvement"] > 1.4, r


def test_slice_normalization():
    """3D-TrIM does the same work with 2.6x fewer slices (paper §III)."""
    assert m.TRIM.slices / m.TRIM_3D.slices == pytest.approx(2.625)
    assert m.TRIM_3D.pes == 576
    assert m.TRIM_3D.peak_tops == pytest.approx(1.152)   # 1.15 TOPS


def test_kernel_tiling():
    assert m.num_subkernels(3) == 1
    assert m.num_subkernels(5) == 4      # §III: 5x5 -> four 3x3 sub-kernels
    assert m.num_subkernels(11) == 16


def test_gemm_baseline_worse():
    """im2col redundancy: GeMM-based accesses exceed 3D-TrIM's on every
    VGG layer (the paper's motivation for Conv-based SAs)."""
    for layer in m.vgg16_layers():
        conv = m.layer_accesses(layer, m.TRIM_3D).total
        gemm = m.gemm_accesses(layer)
        assert gemm > conv


def test_table1_reproduction():
    """Normalized Table I values (DeepScaleTool factors recovered from the
    paper's own raw/normalized pairs)."""
    rows = {r["name"]: r for r in energy.table1()}
    tri = rows["3d-trim (this work)"]
    assert tri["norm_energy_eff_tops_per_w"] == pytest.approx(4.6, abs=0.15)
    assert tri["norm_area_eff_tops_per_mm2"] == pytest.approx(4.42, abs=0.1)
    tpu = rows["tpu-v4i [18]"]
    assert tpu["norm_tops"] == pytest.approx(117.55, rel=0.01)
    assert tpu["norm_power_w"] == pytest.approx(399.54, rel=0.01)
    eye = rows["eyeriss [12]"]
    assert eye["norm_tops"] == pytest.approx(0.11, abs=0.01)
    mp = rows["multi-precision SA [11]"]
    assert mp["norm_area_mm2"] == pytest.approx(76.12, rel=0.01)
    # the headline: 3D-TrIM tops both efficiency columns
    for r in rows.values():
        if r["name"] != "3d-trim (this work)":
            assert tri["norm_energy_eff_tops_per_w"] > \
                r["norm_energy_eff_tops_per_w"]
            assert tri["norm_area_eff_tops_per_mm2"] > \
                r["norm_area_eff_tops_per_mm2"]


def test_energy_model_memory_dominates():
    """Horowitz [3]: external access energy dominates compute by orders of
    magnitude — the architectural motivation."""
    rep = energy.energy_per_inference("vgg16", m.TRIM_3D)
    assert rep["memory_uJ"] / rep["total_uJ"] > 0.5
