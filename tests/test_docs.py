"""The docs can't rot: the README quickstart snippets execute verbatim
(the same check CI runs via ``tools/doclint.py``)."""

import os
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "tools"))

import doclint  # noqa: E402


@pytest.fixture()
def readme_blocks():
    path = os.path.join(ROOT, "README.md")
    with open(path) as f:
        blocks = doclint.extract(f.read())
    assert blocks, "README.md lost its ```python quickstart blocks"
    return blocks


def test_readme_mentions_the_contract_surface(readme_blocks):
    """The satellite checklist: the README must document the tier-1
    command, the autotune env vars and the benchmark entry points."""
    text = open(os.path.join(ROOT, "README.md")).read()
    for needle in ("python -m pytest -x -q", "REPRO_CONV_AUTOTUNE",
                   "REPRO_CONVTUNE_CACHE", "benchmarks/run.py",
                   "benchmarks/paper_eval.py", "tools/doclint.py",
                   "pack_conv2d_weights", "mesh"):
        assert needle in text, f"README.md no longer mentions {needle}"


def test_readme_snippets_execute(readme_blocks, tmp_path, monkeypatch):
    """Run every ```python block in order in one shared namespace —
    exactly what ``tools/doclint.py`` (and CI) does.  The snippet that
    demonstrates REPRO_CONVTUNE_CACHE re-points the env var itself; run
    from a temp cwd so its relative artifacts/ path stays hermetic."""
    monkeypatch.chdir(tmp_path)
    os.makedirs(tmp_path / "artifacts", exist_ok=True)
    assert doclint.run_blocks(readme_blocks) == len(readme_blocks)
