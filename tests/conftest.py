import os
import sys
import types

import pytest

# smoke tests and benches see the single real CPU device (the dry-run sets
# its own 512-device flag in its own process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Multi-device harness for the `multidevice`-marked differential tests
# (DESIGN.md §6): REPRO_MULTIDEVICE=1 forces 8 host CPU devices.  This
# must happen at conftest *import* time — XLA reads the flag at first
# jax initialization, long before any fixture runs.  The second tier-1
# CI job sets the env var; the default job leaves it unset and the
# marked tests skip (single device).
MULTIDEVICE_COUNT = 8
if os.environ.get("REPRO_MULTIDEVICE", "0") not in ("", "0"):
    from repro.launch.hostdevices import force_host_device_count
    force_host_device_count(MULTIDEVICE_COUNT)


@pytest.fixture(scope="session")
def multidevice_harness():
    """The forced multi-device CPU mesh backing the sharded differential
    tests; yields the device count (>= 2 or the test was skipped)."""
    import jax
    n = jax.device_count()
    assert n >= 2, "multidevice tests collected on a single-device run"
    yield n


def pytest_collection_modifyitems(config, items):
    if not any("multidevice" in item.keywords for item in items):
        return
    import jax
    if jax.device_count() >= 2:
        return
    skip = pytest.mark.skip(
        reason="needs the forced multi-device CPU harness "
               "(REPRO_MULTIDEVICE=1, 8 host devices)")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _isolated_convtune_cache(tmp_path, monkeypatch):
    """Point the conv autotune cache at a per-test temp file: tests must
    never read knobs from (or write records into) the developer's real
    ``~/.cache/repro/convtune.json``."""
    from repro.core import autotune
    monkeypatch.setenv(autotune.CACHE_ENV,
                       str(tmp_path / "convtune.json"))
    autotune.reset_memory_cache()
    yield
    autotune.reset_memory_cache()


@pytest.fixture(autouse=True)
def _guard_reset():
    """Fresh guard state (events + memoized demotions) per test: a
    demotion memoized by one test must never silently reroute another
    test's conv dispatch."""
    from repro.core import guard
    guard.reset()
    yield
    guard.reset()

try:                                    # pragma: no cover - env-dependent
    import hypothesis  # noqa: F401
except ImportError:
    # Minimal stand-in so the property tests still run (as deterministic
    # random sweeps) on a bare interpreter without the hypothesis package.
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _given(**strategies):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must not see the
            # wrapped signature, or it would treat the strategy parameters
            # as fixtures)
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(max_examples=10, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers, _st.sampled_from = _integers, _sampled_from
    _st.booleans, _st.floats = _booleans, _floats
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
