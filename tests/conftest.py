import os
import sys
import types

import pytest

# smoke tests and benches see the single real CPU device (the dry-run sets
# its own 512-device flag in its own process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _isolated_convtune_cache(tmp_path, monkeypatch):
    """Point the conv autotune cache at a per-test temp file: tests must
    never read knobs from (or write records into) the developer's real
    ``~/.cache/repro/convtune.json``."""
    from repro.core import autotune
    monkeypatch.setenv(autotune.CACHE_ENV,
                       str(tmp_path / "convtune.json"))
    autotune.reset_memory_cache()
    yield
    autotune.reset_memory_cache()

try:                                    # pragma: no cover - env-dependent
    import hypothesis  # noqa: F401
except ImportError:
    # Minimal stand-in so the property tests still run (as deterministic
    # random sweeps) on a bare interpreter without the hypothesis package.
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _given(**strategies):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must not see the
            # wrapped signature, or it would treat the strategy parameters
            # as fixtures)
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(max_examples=10, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers, _st.sampled_from = _integers, _sampled_from
    _st.booleans, _st.floats = _booleans, _floats
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
