import os
import sys

# smoke tests and benches see the single real CPU device (the dry-run sets
# its own 512-device flag in its own process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
