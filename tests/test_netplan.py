"""NetworkPlan subsystem tests (DESIGN.md §7): whole-network chaining of
the per-layer ConvPlans — exact reduction to the per-layer sums, golden
Ops/MAcc values for the paper networks, trim-vs-3dtrim ratio
monotonicity, residency semantics, the one-sweep network tuner, and the
end-to-end topology execution path."""

import math

import numpy as np
import pytest

from repro.core import (ConvPlan, NetworkPlan, autotune, network_layers,
                        scale_layers)
from repro.core.model import ConvLayer
from repro.core.netplan import infer_pools, pool_between
from repro.core.roofline import network_roofline

APPROX = dict(rel=1e-6)


# ---------------------------------------------------------------------------
# Reduction: the network is exactly the sum of its layers when nothing
# is kept resident (the acceptance invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", ["vgg16", "alexnet", "mobilenet"])
@pytest.mark.parametrize("mode", ["3dtrim", "trim"])
def test_reduces_to_per_layer_sum(net, mode):
    plan = NetworkPlan.build(net, residency="never", fold_pooling=False)
    agg = plan.hbm_bytes(mode)
    ref = dict(input=0, weights=0, output=0, total=0)
    for s in plan.steps:
        t = s.plan.hbm_bytes(mode)
        for k in ref:
            ref[k] += t[k]
    assert agg["input"] == ref["input"]
    assert agg["weights"] == ref["weights"]
    assert agg["output"] == ref["output"]
    assert agg["total"] == ref["total"]
    assert agg["halo"] == 0
    assert plan.macs == sum(s.plan.macs for s in plan.steps)


def test_sharded_network_reduces_at_one_shard():
    """spatial_shards=1 must match the unsharded plan exactly (halo=0);
    more shards add exactly the per-layer one-way halo bytes."""
    base = NetworkPlan.build("alexnet", residency="never",
                             fold_pooling=False)
    one = NetworkPlan.build("alexnet", residency="never",
                            fold_pooling=False, spatial_shards=1)
    assert one.hbm_bytes() == base.hbm_bytes()
    four = NetworkPlan.build("alexnet", residency="never",
                             fold_pooling=False, spatial_shards=4)
    t = four.hbm_bytes()
    assert t["halo"] == sum(s.plan.halo_bytes_oneway for s in four.steps)
    assert t["halo"] > 0
    # HBM terms are the global problem's — unchanged by sharding
    assert t["input"] == base.hbm_bytes()["input"]
    # Ops/MAcc never counts the wire bytes
    assert four.ops_per_macc("trim") == base.ops_per_macc("trim")


# ---------------------------------------------------------------------------
# Golden Ops/MAcc values — the first VGG-16 layers and the network
# ---------------------------------------------------------------------------

def test_vgg16_arch_golden_values():
    """The paper-accounting goldens (Fig. 6 / SV): per-layer Ops/MAcc of
    both configurations and the per-slice improvement for the first
    VGG-16 layers, plus the whole-network numbers."""
    arch = NetworkPlan.build("vgg16").arch_compare()
    rows = arch["layers"]
    for i in (0, 1):       # conv1 and conv2 share the geometry
        assert rows[i]["ops_per_macc"]["3d-trim"] == \
            pytest.approx(143.79366342939022, **APPROX)
        assert rows[i]["ops_per_macc"]["trim"] == \
            pytest.approx(113.07798488191933, **APPROX)
        assert rows[i]["improvement"] == \
            pytest.approx(3.3380358422225758, **APPROX)
    assert rows[2]["ops_per_macc"]["3d-trim"] == \
        pytest.approx(143.17818642993024, **APPROX)
    assert rows[2]["improvement"] == \
        pytest.approx(3.222106353043754, **APPROX)
    # whole network: the paper's claimed range (up to ~3.4x per layer)
    assert arch["ops_per_macc"]["3d-trim"] == \
        pytest.approx(134.70339520762815, **APPROX)
    assert arch["improvement"] == pytest.approx(3.301313156671815,
                                                **APPROX)
    assert 1.0 < arch["improvement"] < 3.6
    assert all(1.0 < r["improvement"] < 3.6 for r in rows)
    assert max(r["improvement"] for r in rows) == \
        pytest.approx(3.423274253731343, rel=1e-6)


def test_vgg16_plan_golden_values():
    """The execution-engine accounting goldens for the first layers."""
    cmp = NetworkPlan.build("vgg16").compare()
    rows = cmp["layers"]
    assert rows[0]["ops_per_macc_3dtrim"] == pytest.approx(564.48,
                                                           **APPROX)
    assert rows[0]["ops_per_macc_trim"] == \
        pytest.approx(561.9992999649983, **APPROX)
    assert rows[0]["improvement"] == pytest.approx(1.0044140625, **APPROX)
    assert cmp["improvement"] == pytest.approx(1.0008943523145661,
                                               **APPROX)


# ---------------------------------------------------------------------------
# trim-vs-3dtrim ratio monotonicity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", ["vgg16", "alexnet", "mobilenet"])
def test_ratio_at_least_one_everywhere(net):
    """3dtrim never loses: per-layer and network ratios are >= 1, and
    the network ratio is bracketed by the per-layer extremes."""
    cmp = NetworkPlan.build(net).compare()
    ratios = [r["improvement"] for r in cmp["layers"]]
    assert all(r >= 1.0 for r in ratios)
    assert min(ratios) <= cmp["improvement"] <= max(ratios)


def test_ratio_monotone_in_strip_count():
    """Shrinking tile_h adds strips; every extra strip adds K-1 trim
    halo rows, so the 3dtrim/trim Ops/MAcc ratio must grow monotonically
    with the strip count for a fixed layer."""
    layer = network_layers("vgg16")[0]
    x = (1, layer.ifmap, layer.ifmap, layer.in_channels)
    w = (3, 3, layer.in_channels, layer.out_channels)
    prev_ratio, prev_tiles = None, None
    for tile_h in (224, 56, 14, 4):
        p = ConvPlan.build(x, w, pad=layer.padding, tile_h=tile_h)
        ratio = (p.arithmetic_intensity("3dtrim")
                 / p.arithmetic_intensity("trim"))
        if prev_ratio is not None:
            assert p.g_tiles > prev_tiles
            assert ratio > prev_ratio
        prev_ratio, prev_tiles = ratio, p.g_tiles


# ---------------------------------------------------------------------------
# Residency rules
# ---------------------------------------------------------------------------

def test_residency_semantics():
    plan = NetworkPlan.build("vgg16")       # auto
    steps = plan.steps
    # boundary flags are consistent: resident_in mirrors the producer
    assert not steps[0].resident_in
    for a, b in zip(steps, steps[1:]):
        assert b.resident_in == a.resident_out
    # the network output always leaves the accelerator
    assert not steps[-1].resident_out
    # auto keeps the small deep activations, spills the big early ones:
    # conv1's ofmap (224*224*64*4B > budget) must spill
    assert not steps[0].resident_out
    assert any(s.resident_out for s in steps)
    # a resident boundary bills neither the output nor the next input
    for a, b in zip(steps, steps[1:]):
        if a.resident_out:
            assert a.hbm_bytes()["output"] == 0
            assert b.hbm_bytes("trim")["input"] == 0
    # residency can only reduce traffic
    never = NetworkPlan.build("vgg16", residency="never")
    always = NetworkPlan.build("vgg16", residency="always")
    assert plan.hbm_bytes()["total"] <= never.hbm_bytes()["total"]
    assert always.hbm_bytes()["total"] <= plan.hbm_bytes()["total"]
    # and therefore only increase Ops/MAcc
    assert plan.ops_per_macc("trim") >= never.ops_per_macc("trim")
    # OPs are invariant under residency
    assert plan.ops == never.ops == always.ops


def test_pool_inference():
    vgg = network_layers("vgg16")
    assert pool_between(vgg[1], vgg[2]) == (2, 2)      # VGG 2x2/s2
    alex = network_layers("alexnet")
    assert pool_between(alex[0], alex[1]) == (2, 3)    # AlexNet 3x3/s2
    assert infer_pools(vgg)[-1] == (1, 1)
    # pooled output feeds the next layer exactly
    plan = NetworkPlan.build("alexnet")
    for a, b in zip(plan.steps, plan.steps[1:]):
        assert a.out_size == b.layer.ifmap


def test_sub2x_boundary_is_a_stride1_pool():
    """A sub-2x spatial boundary (5 -> 3) resolves to a genuine
    stride-1 overlapping pool (3x3/s1) — planned and executed
    consistently, not silently skipped."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers
    from repro.models.base import init_params
    topo = [ConvLayer("c1", 7, 3, 4, kernel=3, padding=0),   # out 5
            ConvLayer("c2", 3, 4, 6, kernel=3, padding=1)]
    assert pool_between(topo[0], topo[1]) == (1, 3)
    plan = NetworkPlan.build(topo)
    assert plan.steps[0].out_size == 3 == plan.steps[1].layer.ifmap
    p = init_params(layers.cnn_params_from_layers(topo),
                    jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (1, 7, 7, 3)), jnp.float32)
    y_ref = layers.cnn_apply_from_layers(p, topo, x, impl="ref")
    y_pal = layers.cnn_apply_from_layers(p, topo, x, impl="pallas")
    assert y_ref.shape == (1, 3, 3, 6)
    np.testing.assert_allclose(y_pal, y_ref, atol=1e-4)


def test_scale_layers_grouped_channels_stay_valid():
    """Scaled grouped layers must keep groups | cin and groups | cout —
    including depthwise multipliers and non-depthwise groups."""
    topo = [ConvLayer("pw0", 16, 3, 24, kernel=1),
            ConvLayer("dw1", 16, 24, 48, kernel=3, padding=1,
                      groups=24),                       # multiplier 2
            ConvLayer("pw1", 16, 48, 64, kernel=1)]
    scaled = scale_layers(topo, 5)
    NetworkPlan.build(scaled)          # ConvPlan validates divisibility
    for l in scaled:
        assert l.in_channels % l.groups == 0
        assert l.out_channels % l.groups == 0
    dw = scaled[1]
    assert dw.groups == dw.in_channels          # still depthwise


def test_build_rejects_broken_topologies():
    with pytest.raises(ValueError, match="unknown network"):
        NetworkPlan.build("resnet50")
    bad = [ConvLayer("a", 16, 3, 8, kernel=3, padding=1),
           ConvLayer("b", 16, 4, 8, kernel=3, padding=1)]   # 8 != 4
    with pytest.raises(ValueError, match="channels"):
        NetworkPlan.build(bad)
    with pytest.raises(ValueError, match="residency"):
        NetworkPlan.build("vgg16", residency="sometimes")


# ---------------------------------------------------------------------------
# Roofline aggregation
# ---------------------------------------------------------------------------

def test_network_roofline_sums_steps():
    plan = NetworkPlan.build("alexnet", spatial_shards=2)
    terms = network_roofline("alexnet", plan)
    assert terms.flops_per_dev == sum(float(s.plan.flops)
                                      for s in plan.steps)
    assert terms.hbm_bytes_per_dev == \
        pytest.approx(sum(float(s.hbm_bytes()["total"])
                          for s in plan.steps))
    assert terms.coll_bytes_per_dev == \
        pytest.approx(float(plan.hbm_bytes()["halo"]))
    assert terms.step_time_s > 0


# ---------------------------------------------------------------------------
# tune_network: one sweep covers the topology
# ---------------------------------------------------------------------------

def test_tune_network_sweep_and_consumption(tmp_path):
    topo = [ConvLayer("c1", 12, 3, 4, kernel=3, padding=1),
            ConvLayer("c2", 12, 4, 4, kernel=3, padding=1),   # repeat ↓
            ConvLayer("c3", 12, 4, 4, kernel=3, padding=1),
            ConvLayer("big", 12, 4, 4, kernel=9, padding=4)]
    recs = autotune.tune_network(topo)
    assert set(recs) == {"c1", "c2", "c3", "big"}
    # K=9 > MAX_NATIVE_K runs the kernel-tiled path: no cache record
    assert "skipped" in recs["big"]
    assert recs["c1"]["dataflow"] in ("carry", "halo")
    # identical problems are tuned once and share the record verbatim
    assert recs["c2"]["key"] == recs["c3"]["key"]
    assert recs["c2"] is recs["c3"]
    assert recs["c1"]["key"] != recs["c2"]["key"]
    # the records land where ops.conv2d looks them up (kernel-seen shape)
    knobs = autotune.knobs_for((1, 14, 14, 3), (3, 3, 3, 4), stride=1,
                               pad=0)
    assert knobs is not None
    assert knobs["tile_h"] == recs["c1"]["tile_h"]
    # ... and where NetworkPlan(use_autotune_cache=True) looks too
    plan = NetworkPlan.build(topo[:3], use_autotune_cache=True)
    assert plan.steps[0].plan.dataflow == recs["c1"]["dataflow"]


def test_tune_network_sharded_namespace():
    topo = [ConvLayer("c1", 12, 3, 4, kernel=3, padding=1)]
    rec = autotune.tune_network(topo, spatial_shards=2)["c1"]
    assert rec["key"].startswith("conv2d_shard:2:b1x2:")
    # the sharded record must not leak into the single-device lookup
    assert autotune.knobs_for((1, 14, 14, 3), (3, 3, 3, 4), stride=1,
                              pad=0) is None
    assert autotune.sharded_knobs_for((1, 14, 14, 3), (3, 3, 3, 4),
                                      spatial_shards=2, stride=1,
                                      pad=0) is not None


# ---------------------------------------------------------------------------
# End-to-end topology execution (the engine the examples run)
# ---------------------------------------------------------------------------

def test_topology_execution_matches_ref():
    """Tune -> pack -> run a small chained topology (VGG-style and
    AlexNet-style pooling boundaries included) on the Pallas path and
    lock it against the pure-jnp reference through the same apply."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers
    from repro.models.base import init_params
    topo = [ConvLayer("c1", 16, 3, 8, kernel=3, padding=1),
            ConvLayer("c2", 16, 8, 8, kernel=3, padding=1),   # pool 2x2
            ConvLayer("c3", 8, 8, 12, kernel=3, padding=1)]
    autotune.tune_network(topo, n=2)
    p = init_params(layers.cnn_params_from_layers(topo, n_classes=5),
                    jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 16, 16, 3)), jnp.float32)
    y_ref = layers.cnn_apply_from_layers(p, topo, x, impl="ref")
    y_pal = layers.cnn_apply_from_layers(p, topo, x, impl="pallas")
    pk = layers.cnn_pack_params(p, topo, n=2)
    y_pck = layers.cnn_apply_from_layers(pk, topo, x)
    assert y_ref.shape == (2, 5)
    np.testing.assert_allclose(y_pal, y_ref, atol=1e-4)
    np.testing.assert_allclose(y_pck, y_ref, atol=1e-4)


def test_topology_execution_overlapping_pool():
    """An AlexNet-style boundary (stride-2 conv, overlapping 3x3/s2
    pool) through the kernel path."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers
    from repro.models.base import init_params
    topo = [ConvLayer("a1", 15, 3, 4, kernel=3, stride=2, padding=0),
            ConvLayer("a2", 3, 4, 6, kernel=3, padding=1)]
    assert infer_pools(topo)[0] == (2, 3)
    p = init_params(layers.cnn_params_from_layers(topo),
                    jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (1, 15, 15, 3)), jnp.float32)
    y_ref = layers.cnn_apply_from_layers(p, topo, x, impl="ref")
    y_pal = layers.cnn_apply_from_layers(p, topo, x, impl="pallas")
    assert y_ref.shape == (1, 3, 3, 6)
    np.testing.assert_allclose(y_pal, y_ref, atol=1e-4)


def test_non_same_equivalent_padding_fails_loudly():
    """A topology whose symmetric paper padding the execution path
    cannot reproduce (K=5 with pad=1: 'same' would pad 2) must raise —
    in the tuner, the pack path and the apply path — instead of
    silently executing a different network than NetworkPlan bills."""
    import jax
    import jax.numpy as jnp
    from repro.core.netplan import layer_kernel_problem
    from repro.models import layers
    from repro.models.base import init_params
    bad = ConvLayer("odd", 16, 3, 8, kernel=5, padding=1)
    with pytest.raises(ValueError, match="not 'same'-equivalent"):
        layer_kernel_problem(bad)
    with pytest.raises(ValueError, match="not 'same'-equivalent"):
        autotune.tune_network([bad])
    p = init_params(layers.cnn_params_from_layers([bad]),
                    jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="not 'same'-equivalent"):
        layers.cnn_pack_params(p, [bad])
    x = jnp.zeros((1, 16, 16, 3), jnp.float32)
    with pytest.raises(ValueError, match="not 'same'-equivalent"):
        layers.cnn_apply_from_layers(p, [bad], x)
    # NetworkPlan still *plans* it (analytical, exact padding), but the
    # cache lookup knows nothing was executable to tune
    NetworkPlan.build([bad], use_autotune_cache=True)
    # built-in topologies are all executable as planned
    for net in ("vgg16", "alexnet", "mobilenet"):
        for l in network_layers(net):
            layer_kernel_problem(l)


def test_tune_network_rejects_sharded_measure():
    topo = [ConvLayer("c1", 12, 3, 4, kernel=3, padding=1)]
    with pytest.raises(ValueError, match="measure"):
        autotune.tune_network(topo, spatial_shards=2, measure=True)


def test_tune_network_rejects_duplicate_names():
    l = ConvLayer("c1", 12, 4, 4, kernel=3, padding=1)
    with pytest.raises(ValueError, match="duplicate layer name"):
        autotune.tune_network([l, l])


def test_scale_layers_keeps_topology_chainable():
    for net in ("vgg16", "alexnet", "mobilenet"):
        topo = scale_layers(network_layers(net), 16)
        NetworkPlan.build(topo)           # chainability is validated here
        full = network_layers(net)
        assert [l.ifmap for l in topo] == [l.ifmap for l in full]
        assert topo[0].in_channels == full[0].in_channels
        assert all(t.out_channels <= f.out_channels
                   for t, f in zip(topo, full))
        # depthwise layers stay depthwise
        for t, f in zip(topo, full):
            if f.groups == f.in_channels and f.groups > 1:
                assert t.groups == t.in_channels


# ---------------------------------------------------------------------------
# paper_eval plumbing (the artifact CI uploads)
# ---------------------------------------------------------------------------

def test_paper_eval_rows_and_claim():
    import importlib
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    paper_eval = importlib.import_module("benchmarks.paper_eval")
    res = paper_eval.evaluate("alexnet", measured=True)
    rows, summary = res["rows"], res["summary"]
    kinds = {r["kind"] for r in rows}
    assert kinds == {"arch", "plan", "sim"}
    # every row carries the schema columns (DESIGN.md §7)
    assert all("mode" in r and "dataflow" in r for r in rows)
    assert all(r["exact"] for r in rows if r["kind"] == "sim")
    assert summary["arch"]["improvement"] > 1.0
    assert summary["plan"]["improvement"] >= 1.0
    assert summary["arch"]["max_layer_improvement"] == \
        pytest.approx(3.42, abs=0.02)
