"""Chaos suite: injected faults through the guarded execution stack
(DESIGN.md §9).

Every test asserts two things about a fallback edge: the demoted result
still matches the ``ref`` oracle (1e-5), and ``guard.events()`` records
exactly the expected demotions — once per problem, never per call.

Run in CI with ``REPRO_CONV_GUARD=1`` (the chaos job step); the numerics
tests set the env themselves so the suite is self-contained.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import guard
from repro.kernels import ops, ref
from repro.testing import faults
from repro.testing.faults import InjectedFault

pytestmark = pytest.mark.chaos

RNG = np.random.default_rng(11)


def _conv_inputs(n=1, h=12, w=12, cin=8, cout=12, k=3):
    x = jnp.asarray(RNG.standard_normal((n, h, w, cin)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((k, k, cin, cout)) * .3,
                     jnp.float32)
    return x, wt


def _allclose(a, b, tol=1e-5):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-6
    assert float(np.abs(a - b).max()) / scale < tol


# ---------------------------------------------------------------------------
# Single fallback edges
# ---------------------------------------------------------------------------

def test_pallas_failure_demotes_to_ref():
    x, w = _conv_inputs()
    want = ref.conv2d(x, w, bias=jnp.ones(12), activation="relu")
    with faults.lowering_failure("pallas") as fault:
        got = ops.conv2d(x, w, bias=jnp.ones(12), activation="relu",
                         layer="conv_t")
    _allclose(got, want)
    assert fault.calls == 1
    (ev,) = guard.events()
    assert (ev["tier"], ev["to"], ev["kind"]) == ("pallas", "ref", "error")
    assert ev["layer"] == "conv_t"
    assert "InjectedFault" in ev["error"]


def test_demotion_is_memoized_once_per_problem():
    x, w = _conv_inputs()
    want = ref.conv2d(x, w)
    with faults.lowering_failure("pallas") as fault:
        for _ in range(3):                  # same problem three times
            _allclose(ops.conv2d(x, w), want)
    # the broken tier was attempted exactly once; one event total
    assert fault.calls == 1
    assert len(guard.events()) == 1
    # even after the fault is gone, the memo keeps routing to ref
    # (a broken tier stays broken for the life of the process)
    _allclose(ops.conv2d(x, w), want)
    assert len(guard.events()) == 1
    # a *different* problem is its own key: re-attempted, new event
    x2, w2 = _conv_inputs(h=16, w=16)
    with faults.lowering_failure("pallas"):
        _allclose(ops.conv2d(x2, w2), ref.conv2d(x2, w2))
    assert len(guard.events()) == 2
    # reset() clears the memo: the (now healthy) tier runs again
    guard.reset()
    _allclose(ops.conv2d(x, w), want)
    assert guard.events() == []


def test_packed_weights_failure_demotes_to_ref():
    x, w = _conv_inputs()
    pk = ops.pack_conv2d_weights(w, jnp.ones(12))
    want = ref.conv2d(x, w, bias=jnp.ones(12), activation="relu")
    with faults.lowering_failure("pallas") as fault:
        got = ops.conv2d(x, pk, activation="relu")
    _allclose(got, want)
    assert fault.calls == 1
    (ev,) = guard.events()
    assert ev["key"].startswith("conv2d_packed:")
    assert (ev["tier"], ev["to"]) == ("pallas", "ref")


def test_sharded_failure_demotes_to_pallas():
    from repro.launch.mesh import make_conv_mesh
    mesh = make_conv_mesh(1, 1)
    x, w = _conv_inputs()
    want = ref.conv2d(x, w)
    with faults.lowering_failure("sharded") as fault:
        got = ops.conv2d(x, w, mesh=mesh)
    _allclose(got, want)
    assert fault.calls == 1
    (ev,) = guard.events()
    assert (ev["tier"], ev["to"], ev["kind"]) \
        == ("sharded", "pallas", "error")


def test_sharded_and_pallas_failures_demote_to_ref():
    from repro.launch.mesh import make_conv_mesh
    mesh = make_conv_mesh(1, 1)
    x, w = _conv_inputs()
    want = ref.conv2d(x, w)
    with faults.lowering_failure("sharded"), \
            faults.lowering_failure("pallas"):
        got = ops.conv2d(x, w, mesh=mesh)
    _allclose(got, want)
    tiers = [(e["tier"], e["to"]) for e in guard.events()]
    assert tiers == [("sharded", "pallas"), ("pallas", "ref")]


def test_depthwise_conv_failure_demotes_to_ref():
    x = jnp.asarray(RNG.standard_normal((1, 10, 10, 6)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 3, 1, 6)) * .3, jnp.float32)
    want = ref.conv2d(x, w, feature_group_count=6)
    with faults.lowering_failure("pallas"):
        got = ops.depthwise_conv2d(x, w, layer="dw")
    _allclose(got, want)
    (ev,) = guard.events()
    assert ev["layer"] == "dw" and ":g6:" in ev["key"]


def test_fused_group_failure_demotes_to_per_layer():
    """A fused-megakernel failure falls back to the per-layer path and
    stays bit-identical to the unfused forward."""
    from repro.core.model import ConvLayer
    from repro.models import layers as L
    from repro.models.base import init_params
    net = [ConvLayer("c0", 12, 3, 4, 3, 1, 1),
           ConvLayer("c1", 12, 4, 6, 3, 1, 1),      # pool 2/2 -> 6
           ConvLayer("c2", 6, 6, 8, 3, 1, 1)]
    p = init_params(L.cnn_params_from_layers(net),
                    jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.standard_normal((2, 12, 12, 3)), jnp.float32)
    want = L.cnn_apply_from_layers(p, net, x)       # per-layer pallas
    with faults.lowering_failure("fused") as fault:
        got = L.cnn_apply_from_layers(p, net, x, fused=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    evs = [e for e in guard.events() if e["tier"] == "fused"]
    assert evs and fault.calls == len(evs)   # one attempt per group
    for ev in evs:
        assert ev["to"] == "pallas" and ev["key"].startswith("fused:")
        assert ".." in ev["layer"]           # "convA..convB" group label


# ---------------------------------------------------------------------------
# Acceptance: full VGG-16 forward under compound injected failures
# ---------------------------------------------------------------------------

def test_vgg16_forward_survives_fused_and_pallas_failures():
    """ISSUE 7 acceptance: with BOTH the fused megakernels and the
    per-layer Pallas kernels broken, a full VGG-16 forward completes via
    demotion, matches the ref oracle at 1e-5, and every demotion appears
    exactly once in guard.events()."""
    from repro.core.fuse_plan import FusedGroupPlan
    from repro.core.netplan import network_layers
    from repro.models import layers as L
    from repro.models.base import init_params
    net = network_layers("vgg16")
    p = init_params(L.cnn_params_from_layers(net, n_classes=10),
                    jax.random.PRNGKey(1))
    x = jnp.asarray(RNG.standard_normal((1, 224, 224, 3)), jnp.float32)
    want = L.cnn_apply_from_layers(p, net, x, impl="ref")
    with faults.lowering_failure("fused"), faults.lowering_failure("pallas"):
        got = L.cnn_apply_from_layers(p, net, x, fused=True)
    _allclose(got, want, tol=1e-5)

    evs = guard.events()
    # every demotion appears exactly once: no duplicate (tier, key)
    pairs = [(e["tier"], e["key"]) for e in evs]
    assert len(pairs) == len(set(pairs))
    # fused demotions: one per fused (depth>=2) group of the plan
    plan = FusedGroupPlan.build(net, n=1)
    n_fused_groups = sum(1 for g in plan.groups if g.fused)
    assert sum(1 for e in evs if e["tier"] == "fused") == n_fused_groups
    # pallas demotions: one per distinct per-layer conv problem
    pallas_keys = {e["key"] for e in evs if e["tier"] == "pallas"}
    assert sum(1 for e in evs if e["tier"] == "pallas") == len(pallas_keys)
    assert all(e["to"] == "ref" for e in evs if e["tier"] == "pallas")
    # VGG-16 has 13 convs but repeated blocks share problems; every
    # distinct problem demoted at most once and at least one per stage
    assert 5 <= len(pallas_keys) <= 13


# ---------------------------------------------------------------------------
# Numerics guard (REPRO_CONV_GUARD=1)
# ---------------------------------------------------------------------------

def test_nan_poison_demotes_with_numerics_guard(monkeypatch):
    monkeypatch.setenv(guard.GUARD_ENV, "1")
    x, w = _conv_inputs()
    want = ref.conv2d(x, w)
    with faults.nan_poison("pallas") as fault:
        got = ops.conv2d(x, w, layer="poisoned_layer")
    assert fault.calls == 1
    _allclose(got, want)
    assert np.isfinite(np.asarray(got)).all()
    (ev,) = guard.events()
    assert (ev["tier"], ev["to"], ev["kind"]) \
        == ("pallas", "ref", "numerics")
    assert ev["layer"] == "poisoned_layer"
    assert "NaN" in ev["error"]


def test_nan_poison_passes_through_without_guard(monkeypatch):
    """Off by default: the numerics check costs a device sync per conv,
    so NaN propagates unless REPRO_CONV_GUARD=1 opted in."""
    monkeypatch.delenv(guard.GUARD_ENV, raising=False)
    x, w = _conv_inputs()
    with faults.nan_poison("pallas"):
        got = ops.conv2d(x, w)
    assert np.isnan(np.asarray(got)).any()
    assert guard.events() == []


def test_numerics_guard_inert_under_jit(monkeypatch):
    """Under a jit trace the tier output is a tracer — the finite check
    cannot run and must pass through, not crash on bool(tracer)."""
    monkeypatch.setenv(guard.GUARD_ENV, "1")
    x, w = _conv_inputs()
    want = ref.conv2d(x, w)
    got = jax.jit(lambda x, w: ops.conv2d(x, w))(x, w)
    _allclose(got, want)
    assert guard.events() == []


def test_lowering_failure_demotes_inside_jit_trace():
    """A tier that raises at trace time demotes within the jit trace —
    the compiled function is the fallback tier's."""
    x, w = _conv_inputs()
    want = ref.conv2d(x, w)
    with faults.lowering_failure("pallas") as fault:
        got = jax.jit(lambda x, w: ops.conv2d(x, w))(x, w)
    _allclose(got, want)
    assert fault.calls == 1
    (ev,) = guard.events()
    assert (ev["tier"], ev["to"]) == ("pallas", "ref")


# ---------------------------------------------------------------------------
# Strict mode + guard internals
# ---------------------------------------------------------------------------

def test_strict_mode_restores_crash_semantics(monkeypatch):
    monkeypatch.setenv(guard.STRICT_ENV, "1")
    x, w = _conv_inputs()
    with faults.lowering_failure("pallas"):
        with pytest.raises(InjectedFault):
            ops.conv2d(x, w)
    assert guard.events() == []


def test_final_tier_errors_propagate():
    """The last tier runs unguarded: a genuinely invalid problem still
    raises (from the simplest engine), never returns garbage."""
    def bad():
        raise ValueError("genuinely invalid problem")
    with pytest.raises(ValueError, match="genuinely invalid"):
        guard.run_chain("k", [("pallas", bad), ("ref", bad)])
    # the pallas attempt was recorded; the ref failure propagated
    (ev,) = guard.events()
    assert ev["tier"] == "pallas"


def test_event_ring_is_bounded():
    for i in range(guard.RING_SIZE + 44):
        def boom(i=i):
            raise RuntimeError(f"fault {i}")
        guard.run_chain(f"key{i}", [("pallas", boom), ("ref", lambda: 0)])
    evs = guard.events()
    assert len(evs) == guard.RING_SIZE           # ring, not a leak
    assert evs[-1]["error"].endswith(f"fault {guard.RING_SIZE + 43}")
    # the demotion memo is complete even where the ring wrapped
    assert len(guard.demotions()) == guard.RING_SIZE + 44


def test_problem_key_is_structural_and_backend_free():
    k1 = guard.problem_key("conv2d", (1, 8, 8, 4), (3, 3, 4, 8))
    k2 = guard.problem_key("conv2d", (1, 8, 8, 4), (3, 3, 4, 8))
    k3 = guard.problem_key("conv2d", (2, 8, 8, 4), (3, 3, 4, 8))
    assert k1 == k2 and k1 != k3
    assert "jax" not in k1  # no backend/device leakage in the key


# ---------------------------------------------------------------------------
# Cache / checkpoint fault edges (the corrupt-file injectors)
# ---------------------------------------------------------------------------

def test_autotune_crash_before_publish_preserves_cache(tmp_path):
    from repro.core import autotune
    from repro.testing.faults import InjectedCrash
    path = str(tmp_path / "convtune.json")
    autotune.store("conv2d:a", dict(tile_h=4, tile_cout=8,
                                    dataflow="carry"), path)
    with faults.crash_before_publish("autotune"):
        with pytest.raises(InjectedCrash):
            autotune.store("conv2d:b", dict(tile_h=2, tile_cout=4,
                                            dataflow="halo"), path)
    # the published cache is intact and readable; no stray temp files
    autotune.reset_memory_cache()
    assert autotune.lookup("conv2d:a", path)["tile_h"] == 4
    stray = [f for f in tmp_path.iterdir() if ".tmp" in f.name]
    assert stray == []
    # the interrupted record was never published
    assert autotune.lookup("conv2d:b", path) is None


def test_guard_module_is_jax_free():
    """benchmarks/run.py --shard imports repro.core modules before
    choosing a device config; the guard must not initialize jax."""
    import subprocess
    import sys
    code = ("import repro.core.guard, sys; "
            "assert 'jax' not in sys.modules, 'guard imported jax'; "
            "print('ok')")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={**__import__('os').environ,
                              "PYTHONPATH": "src"},
                         cwd=__import__('os').path.join(
                             __import__('os').path.dirname(__file__), ".."))
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr
