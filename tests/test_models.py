"""Model-family behaviour tests: forward/decode shapes, NaN-freeness, and
prefill-vs-decode logits consistency (the strongest serving correctness
invariant)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import make_rules
from repro.models import api, ModelConfig
from repro.models.base import init_params

RULES = make_rules()
KEY = jax.random.PRNGKey(0)

FAMILIES = {
    "dense": ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=2, d_ff=128, vocab=97, attn_impl="ref",
                         remat=False),
    "moe": ModelConfig(family="moe", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, moe_dff=96, n_experts=8, top_k=2,
                       vocab=97, attn_impl="ref", remat=False),
    "ssm": ModelConfig(family="ssm", n_layers=2, d_model=64, ssm_state=8,
                       dt_rank=8, scan_chunk=16, vocab=97, remat=False),
    "hybrid": ModelConfig(family="hybrid", n_layers=3, d_model=64, n_heads=4,
                          n_kv_heads=1, d_ff=128, vocab=97, window=8,
                          block_pattern=("rec", "rec", "att"), lru_width=64,
                          mlp="geglu", attn_impl="ref", remat=False),
    "encdec": ModelConfig(family="encdec", n_layers=4, enc_layers=2,
                          dec_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=97, norm="layernorm", mlp="gelu",
                          attn_impl="ref", n_frontend_tokens=12,
                          remat=False),
}


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (b, s)), jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.family == "encdec":
        batch["src"] = jnp.asarray(
            np.random.default_rng(1).standard_normal((b, s, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("family", list(FAMILIES))
def test_forward_shapes_no_nan(family):
    cfg = FAMILIES[family]
    params = init_params(api.params(cfg), KEY, jnp.float32)
    batch = _batch(cfg)
    logits, aux = api.forward(params, batch, cfg, RULES)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss = api.loss_fn(logits, batch["labels"], aux)
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_prefill_decode_consistency(family):
    """Token-by-token decode must reproduce the full-sequence forward —
    validates KV caches, ring buffers, conv carries and SSM states."""
    cfg = FAMILIES[family]
    params = init_params(api.params(cfg), KEY, jnp.float32)
    b, s = 2, 12
    toks = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab,
                                                         (b, s)), jnp.int32)
    full_logits, _ = api.forward(params, {"tokens": toks}, cfg, RULES)

    state = init_params(api.decode_state(cfg, b, s), KEY, jnp.float32)
    got = []
    for t in range(s):
        batch = {"tokens": toks[:, t:t + 1],
                 "cache_len": jnp.full((b,), t + 1, jnp.int32)}
        logits, state = api.decode(params, batch, state, cfg, RULES)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_vlm_vision_prefix():
    cfg = FAMILIES["dense"].replace(frontend="vision", n_frontend_tokens=6)
    params = init_params(api.params(cfg), KEY, jnp.float32)
    batch = _batch(cfg)
    batch["vision"] = jnp.ones((2, 6, cfg.d_model))
    logits, _ = api.forward(params, batch, cfg, RULES)
    assert logits.shape == (2, 16 + 6, cfg.vocab)
    loss = api.loss_fn(logits, batch["labels"])   # labels align to the tail
    assert jnp.isfinite(loss)


def test_moe_routing_is_sparse_and_loadbalanced():
    """Every token reaches exactly top_k experts (within capacity) and the
    aux loss is near 1 for a fresh router (uniform-ish routing)."""
    cfg = FAMILIES["moe"]
    params = init_params(api.params(cfg), KEY, jnp.float32)
    logits, aux = api.forward(params, _batch(cfg, 4, 32), cfg, RULES)
    assert 0.5 < float(aux) < 4.0


def test_scan_vs_unroll_equivalence():
    """The Δ-compile execution mode (unrolled layers + unrolled attention
    chunks) computes the same function as the production scan mode."""
    cfg = FAMILIES["dense"].replace(attn_impl="chunked", attn_chunk=8)
    params = init_params(api.params(cfg), KEY, jnp.float32)
    batch = _batch(cfg)
    a, _ = api.forward(params, batch, cfg, RULES)
    b_, _ = api.forward(params, batch,
                        cfg.replace(unroll_layers=True,
                                    attn_impl="chunked_unroll"), RULES)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4,
                               atol=2e-4)


def test_mamba_chunked_scan_chunk_invariance():
    """The chunked associative scan must not depend on the chunk size."""
    cfg = FAMILIES["ssm"]
    params = init_params(api.params(cfg), KEY, jnp.float32)
    batch = _batch(cfg)
    outs = []
    for chunk in (4, 8, 16):
        logits, _ = api.forward(params, batch,
                                cfg.replace(scan_chunk=chunk), RULES)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)
