"""Training-loop integration: loss decreases, microbatch equivalence,
optimizer behaviour, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, make_batch
from repro.distributed import steps
from repro.distributed.sharding import make_rules
from repro.models import ModelConfig
from repro.models.base import init_params
from repro.optim import AdamWConfig, adamw
from repro.optim.compress import ef_quantize, _quantize_int8

RULES = make_rules()
CFG = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=64, attn_impl="ref",
                  remat=False)


def _state_and_step(n_micro=1, **opt_kw):
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100, **opt_kw)
    step = jax.jit(steps.make_train_step(CFG, opt_cfg, RULES, n_micro))
    state = init_params(steps.train_state_decl(CFG, opt_cfg),
                        jax.random.PRNGKey(1), jnp.float32)
    return state, step


def test_loss_decreases_on_learnable_task():
    dc = DataConfig(batch=8, seq=32, vocab=64, task="copy", seed=3)
    state, step = _state_and_step()
    losses = []
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, make_batch(dc, i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    # streaming (never-repeated) batches: the copy half of the sequence is
    # the learnable signal; calibrated drop ~0.35 nats over 60 steps
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25


def test_microbatch_equivalence():
    """n_micro=4 must produce (numerically) the same update as n_micro=1."""
    dc = DataConfig(batch=8, seq=16, vocab=64, task="lm", seed=0)
    batch = jax.tree.map(jnp.asarray, make_batch(dc, 0))
    s1, step1 = _state_and_step(n_micro=1)
    s4, step4 = _state_and_step(n_micro=4)
    out1, m1 = step1(s1, batch)
    out4, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_grad_clipping():
    g = {"w": jnp.full((10,), 100.0)}
    p = {"w": jnp.zeros((10,))}
    mom = {"mu": {"w": jnp.zeros((10,))}, "nu": {"w": jnp.zeros((10,))}}
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, warmup_steps=0, decay_steps=1)
    _, _, metrics = adamw.apply_updates(p, g, mom, jnp.int32(0), cfg)
    assert float(metrics["grad_norm"]) > 100


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    assert float(adamw.lr_at(cfg, jnp.int32(0))) < 1e-3 * 0.2
    assert float(adamw.lr_at(cfg, jnp.int32(10))) == pytest.approx(1e-3)
    assert float(adamw.lr_at(cfg, jnp.int32(1000))) <= 1e-3 * 0.11


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, scale = _quantize_int8(x)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
    assert err.max() <= float(scale) * 0.51 + 1e-6


def test_error_feedback_quantization_converges():
    """EF compensation: the accumulated residual keeps the mean error near
    zero over repeated steps (unbiased long-run compression)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(256)
    total_q, total_g = jnp.zeros(256), jnp.zeros(256)
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(256), jnp.float32)
        q, residual = ef_quantize(g, residual, bits=4)
        total_q = total_q + q
        total_g = total_g + g
    drift = np.abs(np.asarray(total_q - total_g)).max()
    # bounded by one quantization step, NOT growing with iterations
    assert drift < 1.5


def test_data_pipeline_resumable():
    dc = DataConfig(batch=4, seq=16, vocab=64, task="copy", seed=9)
    from repro.data import SyntheticStream
    s1 = SyntheticStream(dc)
    batches = [next(s1) for _ in range(5)]
    state = s1.state()
    s2 = SyntheticStream.from_state(dc, {"seed": 9, "step": 3, "task": "copy"})
    np.testing.assert_array_equal(next(s2)["tokens"], batches[3]["tokens"])
    # exact replay from saved state
    s3 = SyntheticStream.from_state(dc, state)
    nxt = next(s3)
    s1_next = next(s1)
    np.testing.assert_array_equal(nxt["tokens"], s1_next["tokens"])
