"""Gradient tests for the differentiable conv path (DESIGN.md §5).

``jax.grad`` of the trim ``ops.conv2d`` is compared against the same
grad of the ``ref`` oracle across the stride/groups/dataflow/packed
grid, the backward kernels against the canonical ``ref.conv2d_*_grad``
vjp oracle, and one finite-difference spot check ties the whole chain
to first principles.  Tolerance policy (f32): 1e-5 on the max-abs
relative scale — both paths accumulate in fp32, so only summation order
differs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.trim_conv2d import (trim_conv2d_input_grad,
                                       trim_conv2d_weight_grad)
from repro.models import layers
from repro.models.base import init_params

RNG = np.random.default_rng(13)
TOL_F32 = 1e-5


def _close(a, b, tol=TOL_F32):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-9
    assert float(np.abs(a - b).max()) / scale < tol


# ---------------------------------------------------------------------------
# Backward kernels vs the canonical vjp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w,cin,cout,k,s,pad,g", [
    (8, 8, 4, 8, 3, 1, 0, 1),
    (12, 10, 4, 8, 3, 2, 1, 1),      # (h+2p-k) % s != 0 residual
    (11, 13, 6, 6, 5, 3, 2, 2),      # grouped, stride 3
    (10, 10, 8, 8, 3, 2, 1, 8),      # depthwise strided
    (9, 9, 4, 4, 1, 1, 0, 1),        # 1x1
    (14, 9, 5, 7, 4, 2, 1, 1),       # even K
])
def test_backward_kernels_vs_oracle(h, w, cin, cout, k, s, pad, g):
    x = jnp.asarray(RNG.standard_normal((2, h, w, cin)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((k, k, cin // g, cout)) * .3,
                     jnp.float32)
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    y = ref.conv2d(xp, wt, stride=s, padding="valid",
                   feature_group_count=g)
    gy = jnp.asarray(RNG.standard_normal(y.shape), jnp.float32)
    dx_ref, dw_ref = ref.conv2d_grads(xp, wt, gy, stride=s,
                                      padding="valid",
                                      feature_group_count=g)
    dx = trim_conv2d_input_grad(gy, wt, x_shape=xp.shape, stride=s,
                                pad=0, groups=g)
    dw = trim_conv2d_weight_grad(xp, gy, kernel_size=(k, k), stride=s,
                                 pad=0, groups=g)
    _close(dx, dx_ref)
    _close(dw, dw_ref)


@pytest.mark.parametrize("dataflow", ["carry", "halo"])
def test_input_grad_dataflow_and_tiles(dataflow):
    """The input-grad conv inherits the forward kernel's dataflow axis
    and tile knobs."""
    x = jnp.asarray(RNG.standard_normal((1, 16, 16, 4)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 4, 6)) * .3, jnp.float32)
    y = ref.conv2d(x, wt, stride=2, padding="valid")
    gy = jnp.asarray(RNG.standard_normal(y.shape), jnp.float32)
    dx_ref = ref.conv2d_input_grad(x, wt, gy, stride=2, padding="valid")
    dx = trim_conv2d_input_grad(gy, wt, x_shape=x.shape, stride=2, pad=0,
                                dataflow=dataflow, tile_h=4, tile_cout=2)
    _close(dx, dx_ref)


def test_weight_grad_tile_knobs():
    x = jnp.asarray(RNG.standard_normal((2, 14, 12, 4)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 4, 10)) * .3, jnp.float32)
    y = ref.conv2d(x, wt, stride=2, padding="valid")
    gy = jnp.asarray(RNG.standard_normal(y.shape), jnp.float32)
    dw_ref = ref.conv2d_weight_grad(x, wt, gy, stride=2, padding="valid")
    for tile_go, tile_cout in [(1, None), (3, 4), (None, 2)]:
        dw = trim_conv2d_weight_grad(x, gy, kernel_size=(3, 3), stride=2,
                                     pad=0, tile_go=tile_go,
                                     tile_cout=tile_cout)
        _close(dw, dw_ref)


# ---------------------------------------------------------------------------
# jax.grad(ops.conv2d) vs jax.grad(ref.conv2d) — the acceptance grid
# ---------------------------------------------------------------------------

GRID = [
    # h, w, cin, cout, k, s, padding, groups, activation, dataflow
    (10, 10, 4, 8, 3, 1, "same", 1, None, None),
    (10, 10, 4, 8, 3, 1, "same", 1, "relu", None),
    (12, 9, 4, 8, 3, 2, "same", 1, "gelu", None),
    (12, 12, 8, 8, 3, 2, "valid", 8, "silu", None),
    (14, 14, 6, 9, 3, 1, "same", 3, None, "halo"),
    (11, 11, 4, 4, 1, 1, "valid", 1, None, None),
]


@pytest.mark.parametrize("case", GRID)
def test_grad_vs_ref_grid(case):
    h, w, cin, cout, k, s, padding, g, act, df = case
    x = jnp.asarray(RNG.standard_normal((2, h, w, cin)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((k, k, cin // g, cout)) * .3,
                     jnp.float32)
    b = jnp.asarray(RNG.standard_normal((cout,)), jnp.float32)
    kw = dict(stride=s, padding=padding, feature_group_count=g,
              activation=act)

    def loss_trim(x, wt, b):
        extra = {"dataflow": df} if df else {}
        return (ops.conv2d(x, wt, bias=b, **kw, **extra) ** 2).sum()

    def loss_ref(x, wt, b):
        return (ref.conv2d(x, wt, bias=b, **kw) ** 2).sum()

    got = jax.grad(loss_trim, argnums=(0, 1, 2))(x, wt, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, wt, b)
    for a, r in zip(got, want):
        _close(a, r)


def test_grad_kernel_tiled_large_k():
    """K > MAX_NATIVE_K: the adder-tree path differentiates through each
    sub-kernel's custom_vjp."""
    x = jnp.asarray(RNG.standard_normal((1, 30, 30, 3)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((11, 11, 3, 4)) * .1, jnp.float32)

    def loss(impl):
        return lambda x, wt: (ops.conv2d(x, wt, stride=4,
                                         padding="valid",
                                         impl=impl) ** 2).sum()

    got = jax.grad(loss("pallas"), argnums=(0, 1))(x, wt)
    want = jax.grad(loss("ref"), argnums=(0, 1))(x, wt)
    for a, r in zip(got, want):
        _close(a, r, tol=1e-4)   # two extra accumulation layers


def test_grad_packed_weights_matches_unpacked():
    """Packed-weights vjp: cotangents arrive in the packed padded layout
    and match the unpacked path after unpadding."""
    x = jnp.asarray(RNG.standard_normal((1, 12, 12, 8)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 2, 12)) * .3, jnp.float32)
    b = jnp.asarray(RNG.standard_normal((12,)), jnp.float32)
    groups, cout = 4, 12
    pk = ops.pack_conv2d_weights(wt, b, groups=groups, tile_cout=2)

    def loss_pk(x, pk):
        return (ops.conv2d(x, pk, activation="relu") ** 2).sum()

    def loss_raw(x, wt, b):
        return (ops.conv2d(x, wt, bias=b, feature_group_count=groups,
                           activation="relu") ** 2).sum()

    dx, dpk = jax.grad(loss_pk, argnums=(0, 1))(x, pk)
    dxr, dwr, dbr = jax.grad(loss_raw, argnums=(0, 1, 2))(x, wt, b)
    _close(dx, dxr)
    assert dpk.w.shape == pk.w.shape and dpk.bias.shape == pk.bias.shape
    _close(ops._unpack_weights(dpk.w, groups, cout), dwr)
    cpp = pk.w.shape[3] // groups
    db = dpk.bias.reshape(groups, cpp)[:, :cout // groups].reshape(-1)
    _close(db, dbr)


def test_grad_depthwise_helper():
    x = jnp.asarray(RNG.standard_normal((1, 10, 10, 6)), jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 1, 6)) * .3, jnp.float32)

    def loss(impl):
        return lambda x, wt: (ops.depthwise_conv2d(x, wt, stride=2,
                                                   impl=impl) ** 2).sum()

    got = jax.grad(loss("pallas"), argnums=(0, 1))(x, wt)
    want = jax.grad(loss("ref"), argnums=(0, 1))(x, wt)
    for a, r in zip(got, want):
        _close(a, r)


def test_grad_bf16_tolerance_policy():
    """The DESIGN.md §5 dtype policy: bf16 gradients track the f32
    oracle to 3e-2 (the inter-layer bf16 cast dominates; both backward
    kernels still accumulate fp32)."""
    x32 = jnp.asarray(RNG.standard_normal((1, 12, 12, 6)), jnp.float32)
    w32 = jnp.asarray(RNG.standard_normal((3, 3, 6, 8)) * .3, jnp.float32)

    def loss(fn, dt):
        return lambda x, w: (fn(x.astype(dt), w.astype(dt),
                                stride=2, padding="same")
                             .astype(jnp.float32) ** 2).sum()

    got = jax.grad(loss(ops.conv2d, jnp.bfloat16), argnums=(0, 1))(
        x32, w32)
    want = jax.grad(loss(ref.conv2d, jnp.float32), argnums=(0, 1))(
        x32, w32)
    for a, r in zip(got, want):
        _close(a, r, tol=3e-2)


def test_weight_grad_rejects_mismatched_cotangent():
    x = jnp.asarray(RNG.standard_normal((1, 10, 10, 4)), jnp.float32)
    bad_gy = jnp.zeros((1, 5, 5, 8), jnp.float32)   # wrong for s=1 K=3
    with pytest.raises(ValueError, match="does not match"):
        trim_conv2d_weight_grad(x, bad_gy, kernel_size=(3, 3), stride=1,
                                pad=0)


def test_finite_difference_spot_check():
    """First-principles anchor: directional derivative via central
    differences on the scalar loss."""
    x = jnp.asarray(RNG.standard_normal((1, 8, 8, 3)), jnp.float64
                    if jax.config.jax_enable_x64 else jnp.float32)
    wt = jnp.asarray(RNG.standard_normal((3, 3, 3, 4)) * .3, jnp.float32)

    def loss(wt):
        return (ops.conv2d(x, wt, stride=2, padding="same") ** 2).sum()

    g = jax.grad(loss)(wt)
    v = jnp.asarray(RNG.standard_normal(wt.shape), jnp.float32)
    v = v / jnp.linalg.norm(v)
    eps = 1e-2
    fd = (loss(wt + eps * v) - loss(wt - eps * v)) / (2 * eps)
    analytic = jnp.vdot(g, v)
    assert abs(float(fd - analytic)) / (abs(float(analytic)) + 1e-9) \
        < 5e-3


# ---------------------------------------------------------------------------
# End-to-end: a tiny CNN training step on trim kernels learns
# ---------------------------------------------------------------------------

def test_cnn_train_step_decreases_loss():
    """The examples/train_cnn.py loop in miniature: grads flow through
    stacked strided/depthwise trim convs and reduce the loss."""
    from repro.optim import AdamWConfig, adamw
    rng = np.random.default_rng(0)
    templates = rng.standard_normal((4, 12, 12, 3))
    params = init_params(
        layers.simple_cnn_params(cin=3, channels=(6,), n_classes=4),
        jax.random.PRNGKey(0))
    cfg = AdamWConfig(lr=1e-2, warmup_steps=2, decay_steps=100,
                      weight_decay=0.0)
    moments = adamw.init_moments(params, cfg)

    def loss_fn(p, x, y):
        logits = layers.simple_cnn_apply(p, x)
        return -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                    y[:, None], axis=1).mean()

    @jax.jit
    def step(p, m, i, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, m, _ = adamw.apply_updates(p, grads, m, i, cfg)
        return p, m, loss

    losses = []
    for i in range(12):
        labels = rng.integers(0, 4, size=8)
        x = jnp.asarray(templates[labels]
                        + 0.3 * rng.standard_normal((8, 12, 12, 3)),
                        jnp.float32)
        params, moments, loss = step(params, moments, jnp.int32(i), x,
                                     jnp.asarray(labels, jnp.int32))
        losses.append(float(loss))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.05, losses
