"""Validation of the dry-run methodology itself.

1. Δ-extrapolation (cost(1) + (L-1)*(cost(2)-cost(1))) is validated against
   a fully-unrolled compile of a small arch — run in a subprocess so it can
   own its XLA device-count flag.
2. A miniature production-mesh lower+compile must show the expected
   collective kinds.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys, json
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.configs.shapes import ShapePlan
from repro.launch import dryrun
from repro.models import ModelConfig

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((4, 4), ("data", "model"))
cfg = ModelConfig(family="dense", n_layers=6, d_model=128, n_heads=8,
                  n_kv_heads=4, d_ff=256, vocab=512, attn_impl="chunked",
                  attn_chunk=64)
plan = ShapePlan("t", "train", batch=16, seq=128)

# Δ-extrapolated
extrap = dryrun.delta_extrapolate(cfg, plan, mesh)

# ground truth: fully unrolled 6 layers
truth = dryrun._delta_compile(cfg, plan, mesh)

print(json.dumps({
    "extrap_flops": extrap["flops"], "true_flops": truth["flops"],
    "extrap_bytes": extrap["bytes"], "true_bytes": truth["bytes"],
    "extrap_coll": sum(extrap["coll"].values()),
    "true_coll": sum(truth["coll"].values()),
}))
"""


@pytest.mark.slow
def test_delta_extrapolation_matches_full_unroll(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, os.path.abspath(src)],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # at this toy scale (d=128) the fixed embed/logits overhead does not
    # cancel perfectly across L=1/2/6 fusion choices: measured deviations
    # are ~7% flops / ~14% bytes / ~1% collectives; at production layer
    # sizes the per-layer terms dominate and the deviation shrinks.
    assert res["extrap_flops"] == pytest.approx(res["true_flops"], rel=0.12)
    assert res["extrap_bytes"] == pytest.approx(res["true_bytes"], rel=0.20)
    assert res["extrap_coll"] == pytest.approx(res["true_coll"], rel=0.10)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys, json
sys.path.insert(0, sys.argv[1])
import jax
from repro.core import roofline as rl
from repro.configs.shapes import ShapePlan
from repro.launch import dryrun
from repro.models import ModelConfig

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 2, 4), ("pod", "data", "model"))
cfg = ModelConfig(family="dense", n_layers=2, d_model=128, n_heads=8,
                  n_kv_heads=4, d_ff=256, vocab=512, attn_impl="chunked",
                  attn_chunk=64)
plan = ShapePlan("t", "train", batch=16, seq=128, fsdp=True)
jitted, args = dryrun.build_cell(cfg, plan, mesh)
with mesh:
    compiled = jitted.lower(*args).compile()
stats = rl.parse_collectives(compiled.as_text(), mesh.size)
mem = compiled.memory_analysis()
print(json.dumps({"kinds": sorted(stats.by_kind),
                  "temp": mem.temp_size_in_bytes}))
"""


@pytest.mark.slow
def test_multipod_mesh_compile_has_collectives():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT, os.path.abspath(src)],
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # TP psums and FSDP weight gathers must both be present
    assert "all-reduce" in res["kinds"]
    assert "all-gather" in res["kinds"]
    assert res["temp"] > 0
