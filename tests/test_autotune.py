"""Conv autotuner tests: cache round-trip determinism, model-guided and
measured search, the ops.conv2d consultation path, and the packed-params
layer wiring (DESIGN.md §4).

The autouse conftest fixture points the cache at a per-test temp file, so
everything here is hermetic.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.tiling import VMEM_BYTES
from repro.kernels import ops, ref
from repro.models import layers
from repro.models.base import init_params

RNG = np.random.default_rng(5)

X_SHAPE = (1, 16, 16, 8)
W_SHAPE = (3, 3, 8, 12)


def _allclose(a, b, tol=2e-3):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-6
    assert float(np.abs(a - b).max()) / scale < tol


# ---------------------------------------------------------------------------
# Cache round trip + determinism
# ---------------------------------------------------------------------------

def test_tune_round_trip_is_deterministic():
    rec1 = autotune.tune(X_SHAPE, W_SHAPE, stride=1, pad=0)
    rec2 = autotune.tune(X_SHAPE, W_SHAPE, stride=1, pad=0)
    assert rec1 == rec2                       # same inputs, same winner
    key = autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0)
    assert autotune.lookup(key) == rec1
    # survives dropping the in-process memo: read back from the JSON file
    autotune.reset_memory_cache()
    assert autotune.lookup(key) == rec1
    # and the on-disk schema is what DESIGN.md documents
    with open(autotune.cache_path()) as f:
        data = json.load(f)
    assert data["version"] == 1
    assert data["entries"][key]["tile_h"] == rec1["tile_h"]
    assert rec1["dataflow"] in autotune.DATAFLOWS
    assert rec1["source"] == "model"


def test_store_overwrites_and_persists_atomically():
    key = "conv2d:test"
    autotune.store(key, dict(tile_h=4, tile_cout=8, dataflow="carry"))
    autotune.store(key, dict(tile_h=8, tile_cout=8, dataflow="halo"))
    autotune.reset_memory_cache()
    assert autotune.lookup(key)["tile_h"] == 8
    assert not os.path.exists(autotune.cache_path() + ".tmp")


def test_lookup_missing_cache_returns_none():
    assert autotune.lookup("conv2d:absent") is None
    assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None


def test_knobs_for_validates_records_and_env_kill_switch(monkeypatch):
    key = autotune.make_key(X_SHAPE, W_SHAPE, stride=2, pad=0)
    # invalid: tile_h not a stride multiple -> rejected, not crashed
    autotune.store(key, dict(tile_h=3, tile_cout=8, dataflow="carry"))
    assert autotune.knobs_for(X_SHAPE, W_SHAPE, stride=2) is None
    autotune.store(key, dict(tile_h=4, tile_cout=8, dataflow="halo"))
    assert autotune.knobs_for(X_SHAPE, W_SHAPE, stride=2)["tile_h"] == 4
    monkeypatch.setenv(autotune.AUTOTUNE_ENV, "0")
    assert autotune.knobs_for(X_SHAPE, W_SHAPE, stride=2) is None


# ---------------------------------------------------------------------------
# Robustness (DESIGN.md §9): concurrent stores, quarantine, validation
# ---------------------------------------------------------------------------

_STRESS_WORKER = r"""
import sys
from repro.core import autotune
path, wid, n = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
for i in range(n):
    autotune.store(f"conv2d:w{wid}:e{i}",
                   dict(tile_h=4, tile_cout=8, dataflow="carry",
                        worker=wid, i=i), path)
print("done", wid)
"""


def test_concurrent_store_loses_no_entries(tmp_path):
    """ISSUE 7 acceptance: N>=4 processes hammering one cache path
    concurrently retain 100% of their entries — the .lock sidecar +
    read-merge-replace store closes the lost-update race."""
    import subprocess
    import sys
    n_proc, n_entries = 4, 30
    path = str(tmp_path / "convtune.json")
    env = dict(os.environ, PYTHONPATH="src")
    procs = [subprocess.Popen(
        [sys.executable, "-c", _STRESS_WORKER, path, str(w),
         str(n_entries)],
        env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for w in range(n_proc)]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err
    autotune.reset_memory_cache()
    with open(path) as f:
        entries = json.load(f)["entries"]
    want = {f"conv2d:w{w}:e{i}" for w in range(n_proc)
            for i in range(n_entries)}
    missing = want - set(entries)
    assert not missing, f"lost {len(missing)}/{len(want)}: " \
                        f"{sorted(missing)[:5]}..."
    # and each record survived byte-for-byte (merge never mangles)
    assert entries["conv2d:w0:e0"]["worker"] == 0


@pytest.mark.parametrize("mode", ["truncate", "garbage", "wrong_version",
                                  "empty"])
def test_corrupt_cache_is_quarantined_not_reset(tmp_path, mode):
    """An unreadable (or unknown-schema) cache is renamed to
    convtune.json.corrupt-<pid> with a warning — preserved for
    inspection, never silently discarded — and reads as empty."""
    from repro.testing import faults
    path = str(tmp_path / "convtune.json")
    autotune.store("conv2d:x", dict(tile_h=4, tile_cout=8,
                                    dataflow="carry"), path)
    faults.corrupt_cache(path, mode)
    autotune.reset_memory_cache()
    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert autotune.lookup("conv2d:x", path) is None
    quarantined = [f.name for f in tmp_path.iterdir()
                   if ".corrupt-" in f.name]
    assert len(quarantined) == 1
    assert not os.path.exists(path)
    # the cache restarts cleanly after quarantine
    autotune.reset_memory_cache()
    autotune.store("conv2d:y", dict(tile_h=2, tile_cout=4,
                                    dataflow="halo"), path)
    autotune.reset_memory_cache()
    assert autotune.lookup("conv2d:y", path)["tile_h"] == 2


def test_wrong_version_quarantine_names_the_version(tmp_path):
    """A future schema version is quarantined with the version in the
    warning (migrate-or-quarantine, never silent discard)."""
    path = str(tmp_path / "convtune.json")
    with open(path, "w") as f:
        json.dump({"version": 999, "entries": {"k": {}}}, f)
    with pytest.warns(RuntimeWarning, match="999"):
        assert autotune.lookup("k", path) is None
    # the quarantined file still holds the original document
    (q,) = [f for f in tmp_path.iterdir() if ".corrupt-" in f.name]
    with open(q) as f:
        assert json.load(f)["version"] == 999


def test_missing_cache_file_is_not_quarantine(tmp_path, recwarn):
    """A cache that never existed is an empty cache — no warning, no
    .corrupt file (quarantine is for corruption, not first run)."""
    path = str(tmp_path / "nonexistent.json")
    assert autotune.lookup("k", path) is None
    assert not [w for w in recwarn.list
                if "quarantined" in str(w.message)]
    assert not list(tmp_path.iterdir())


def test_malformed_record_warns_once_and_misses():
    """A truncated/hand-edited record is a miss + ONE warning, not a
    KeyError in the dispatch path and not a warning per conv call."""
    key = autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0)
    autotune.store(key, dict(tile_cout=8, dataflow="carry"))  # no tile_h
    with pytest.warns(RuntimeWarning, match="malformed"):
        assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None
    # warn-once: subsequent lookups are silent misses
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None
    # conv2d dispatch degrades to the default plan instead of crashing
    x = jnp.asarray(RNG.standard_normal((1, 14, 14, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal(W_SHAPE) * .3, jnp.float32)
    _allclose(ops.conv2d(x, w), ref.conv2d(x, w))


def test_geometry_insane_record_is_rejected():
    """Structurally valid knobs that cannot build a ConvPlan for the
    problem (e.g. tile_cout way past the per-group C_out after a shape
    edit) are a miss + warning, not a crash inside the kernel."""
    key = autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0)
    autotune.store(key, dict(tile_h=4, tile_cout=10 ** 6,
                             dataflow="carry"))
    with pytest.warns(RuntimeWarning, match="infeasible"):
        assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

def test_candidates_cover_both_dataflows_and_fit_vmem():
    plans = autotune.candidate_knobs(X_SHAPE, W_SHAPE)
    assert {p.dataflow for p in plans} == set(autotune.DATAFLOWS)
    assert all(p.vmem_resident_bytes <= VMEM_BYTES for p in plans)
    # the full-height strip (one grid step along H) is always a candidate
    assert any(p.g_tiles == 1 for p in plans)


def test_measured_tune_records_wall_clock():
    rec = autotune.tune((1, 8, 8, 4), (3, 3, 4, 4), measure=True,
                        measure_top_k=2, write=False)
    assert rec["source"] == "measured"
    assert rec["measured_us"] > 0


# ---------------------------------------------------------------------------
# ops.conv2d consults the cache
# ---------------------------------------------------------------------------

def test_conv2d_uses_cached_knobs(monkeypatch):
    x = jnp.asarray(RNG.standard_normal((1, 14, 14, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal(W_SHAPE) * .3, jnp.float32)
    # 'same' K=3 s=1 pre-pads to 16x16; that's the key conv2d looks up
    key = autotune.make_key((1, 16, 16, 8), W_SHAPE, stride=1, pad=0)
    autotune.store(key, dict(tile_h=6, tile_cout=4, dataflow="halo",
                             source="model"))

    seen = {}
    real = ops.trim_conv2d

    def spy(*args, **kw):
        seen.update(kw)
        return real(*args, **kw)

    monkeypatch.setattr(ops, "trim_conv2d", spy)
    got = ops.conv2d(x, w)
    assert (seen["tile_h"], seen["tile_cout"], seen["dataflow"]) \
        == (6, 4, "halo")
    _allclose(got, ref.conv2d(x, w))
    # explicit knobs win over the cache
    seen.clear()
    ops.conv2d(x, w, tile_h=8, dataflow="carry")
    assert (seen["tile_h"], seen["tile_cout"], seen["dataflow"]) \
        == (8, 4, "carry")
    # kill switch restores the plan defaults
    seen.clear()
    ops.conv2d(x, w, use_autotune_cache=False)
    assert (seen["tile_h"], seen["dataflow"]) == (None, "carry")


@pytest.mark.parametrize("lname", ["pw1", "dw2"])
def test_hillclimb_write_cache_feeds_conv2d(lname):
    """The sweep->cache->conv2d loop: benchmarks/hillclimb.py --conv
    --write-cache stores a record under the exact key ops.conv2d looks
    up — including the stride-2 'same' case where the kernel-seen
    pre-pad is asymmetric (dw2: 112 -> 113 rows, not the layer's
    symmetric 114)."""
    import importlib
    hillclimb = importlib.import_module("benchmarks.hillclimb")
    res = hillclimb.conv_hillclimb(f"mobilenet:{lname}",
                                   ("carry", "halo"), write_cache=True)
    assert res["best"] is not None
    rec = autotune.lookup(res["cache_key"])
    assert rec["tile_h"] == res["best"]["tile_h"]
    # the stored key is found through the exact lookup ops.conv2d does
    from repro.core import mobilenet_layers
    layer = [l for l in mobilenet_layers() if l.name == lname][0]
    w_shape = (layer.kernel, layer.kernel,
               layer.in_channels // layer.groups, layer.out_channels)
    x_shape, pad = ops.kernel_input_shape(
        (1, layer.ifmap, layer.ifmap, layer.in_channels), layer.kernel,
        layer.stride, "same" if layer.padding else "valid")
    got = autotune.knobs_for(x_shape, w_shape, stride=layer.stride,
                             pad=pad, groups=layer.groups)
    assert got == rec


# ---------------------------------------------------------------------------
# Packed layer params (models/layers.py wiring)
# ---------------------------------------------------------------------------

def test_conv2d_pack_params_matches_unpacked():
    import jax
    p = init_params(layers.conv2d_params(3, 8, 12),
                    jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.standard_normal((1, 12, 12, 8)), jnp.float32)
    want = layers.conv2d_apply(p, x, activation="relu")
    packed = layers.conv2d_pack_params(p, x_shape=x.shape)
    got = layers.conv2d_apply(packed, x, activation="relu")
    _allclose(got, want, tol=1e-6)


def test_depthwise_separable_pack_matches_unpacked():
    import jax
    p = init_params(layers.depthwise_separable_params(3, 8, 16),
                    jax.random.PRNGKey(1))
    x = jnp.asarray(RNG.standard_normal((1, 10, 10, 8)), jnp.float32)
    want = layers.depthwise_separable_apply(p, x, stride=2)
    packed = layers.depthwise_separable_pack_params(p, x_shape=x.shape,
                                                    stride=2)
    got = layers.depthwise_separable_apply(packed, x, stride=2)
    _allclose(got, want, tol=1e-6)


# ---------------------------------------------------------------------------
# Backward shapes (DESIGN.md §5): keys, tuning, and the bwd consultation
# ---------------------------------------------------------------------------

def test_backward_keys_never_collide_with_forward():
    """The weight-grad record is op-namespaced, and the input-grad conv's
    key is the transformed problem's own conv2d key — even a forward
    problem with the *identical* raw shape tuple gets a different key
    than the wgrad record, and writing one never shadows the other."""
    fwd_key = autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0)
    wgrad_key = autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0,
                                  op="conv2d_wgrad")
    assert fwd_key != wgrad_key
    assert fwd_key.startswith("conv2d:")
    assert wgrad_key.startswith("conv2d_wgrad:")
    autotune.store(fwd_key, dict(tile_h=8, tile_cout=4, dataflow="carry"))
    autotune.store(wgrad_key, dict(tile_go=2, tile_cout=3))
    assert autotune.lookup(fwd_key)["tile_h"] == 8
    assert autotune.lookup(wgrad_key)["tile_go"] == 2
    # the input-grad conv of this problem keys a *different* conv2d shape
    from repro.core.conv_plan import input_grad_geometry
    geo = input_grad_geometry(X_SHAPE, W_SHAPE, stride=1, pad=0)
    ig_key = autotune.make_key(geo["g_padded_shape"], geo["wt_shape"],
                               stride=1, pad=0)
    assert ig_key != fwd_key


def test_tune_backward_round_trip():
    """tune_backward persists both records into the hermetic per-test
    cache and they read back through the validated lookups."""
    recs = autotune.tune_backward(X_SHAPE, W_SHAPE, stride=1, pad=0)
    assert set(recs) == {"input_grad", "weight_grad"}
    assert recs["weight_grad"]["tile_go"] >= 1
    wrec = autotune.weight_grad_knobs_for(X_SHAPE, W_SHAPE, stride=1,
                                          pad=0)
    assert wrec == recs["weight_grad"]
    from repro.core.conv_plan import input_grad_geometry
    geo = input_grad_geometry(X_SHAPE, W_SHAPE, stride=1, pad=0)
    irec = autotune.knobs_for(geo["g_padded_shape"], geo["wt_shape"],
                              stride=1, pad=0)
    assert irec == recs["input_grad"]
    # survives dropping the in-process memo (on-disk round trip)
    autotune.reset_memory_cache()
    assert autotune.weight_grad_knobs_for(X_SHAPE, W_SHAPE) == wrec
    # malformed wgrad records are rejected, not trusted
    autotune.store(autotune.make_key(X_SHAPE, W_SHAPE,
                                     op="conv2d_wgrad"),
                   dict(tile_go="bad", tile_cout=1))
    assert autotune.weight_grad_knobs_for(X_SHAPE, W_SHAPE) is None


# ---------------------------------------------------------------------------
# Sharded keys (DESIGN.md §6): conv2d_shard:<ndev> namespacing
# ---------------------------------------------------------------------------

def test_sharded_keys_never_alias_single_device():
    """Sharded records are namespaced by the full shard grid: the same
    raw shape tuple under different (batch x spatial) splits — even
    splits with the same device count — and the single-device path are
    all distinct keys, and writing any one never shadows the others."""
    # batch 8 so every split below is geometry-feasible (the consult-site
    # validation rejects records whose shard grid cannot divide the
    # problem — see test_geometry_insane_record_is_rejected)
    xb = (8, 16, 16, 8)
    fwd_key = autotune.make_key(xb, W_SHAPE, stride=1, pad=0)
    splits = [(1, 1), (1, 4), (4, 1), (1, 8), (8, 1), (2, 4)]
    keys = {grid: autotune.make_key(xb, W_SHAPE, stride=1, pad=0,
                                    op=autotune.sharded_key_op(*grid))
            for grid in splits}
    assert len({fwd_key, *keys.values()}) == len(splits) + 1
    for (bs, ss), key in keys.items():
        assert key.startswith(f"conv2d_shard:{bs * ss}:")
    autotune.store(fwd_key, dict(tile_h=8, tile_cout=4, dataflow="carry"))
    for i, ((bs, ss), key) in enumerate(keys.items()):
        autotune.store(key, dict(tile_h=i + 1, tile_cout=2,
                                 dataflow="halo"))
    # each lookup sees only its own record — in particular the two
    # 8-device splits (8x1 data-parallel vs 1x8 spatial) never alias
    assert autotune.knobs_for(xb, W_SHAPE)["tile_h"] == 8
    for i, (bs, ss) in enumerate(splits):
        got = autotune.sharded_knobs_for(xb, W_SHAPE,
                                         batch_shards=bs,
                                         spatial_shards=ss)
        assert (got["tile_h"], got["dataflow"]) == (i + 1, "halo")
    assert autotune.sharded_knobs_for(xb, W_SHAPE,
                                      spatial_shards=2) is None
    # malformed sharded records are rejected, not trusted
    autotune.store(keys[(1, 4)], dict(tile_h="bad", tile_cout=2,
                                      dataflow="halo"))
    assert autotune.sharded_knobs_for(xb, W_SHAPE,
                                      spatial_shards=4) is None


def test_tune_sharded_round_trip():
    """tune_sharded persists under the shard-grid key and reads back
    through the validated lookup (surviving the in-process memo)."""
    rec = autotune.tune_sharded(X_SHAPE, W_SHAPE, spatial_shards=4)
    assert rec["dataflow"] in autotune.DATAFLOWS
    assert rec["tile_h"] >= 1 and rec["tile_cout"] >= 1
    got = autotune.sharded_knobs_for(X_SHAPE, W_SHAPE, spatial_shards=4)
    assert got == rec
    autotune.reset_memory_cache()
    assert autotune.sharded_knobs_for(X_SHAPE, W_SHAPE,
                                      spatial_shards=4) == rec
    # a different mesh size — or a different split of the same size —
    # is a different problem
    assert autotune.sharded_knobs_for(X_SHAPE, W_SHAPE,
                                      spatial_shards=8) is None
    assert autotune.sharded_knobs_for(X_SHAPE, W_SHAPE,
                                      batch_shards=4) is None
    rec2 = autotune.tune_sharded(X_SHAPE, W_SHAPE, batch_shards=1,
                                 spatial_shards=1)
    assert autotune.sharded_knobs_for(X_SHAPE, W_SHAPE) == rec2
    # ... and never pollutes the single-device lookup
    assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None


def test_conv2d_sharded_consults_namespaced_cache(monkeypatch):
    """ops.conv2d(..., mesh=) fills unset knobs from the
    conv2d_shard:<ndev> record of the global kernel-seen shape — and
    ignores the single-device record for the same shape."""
    import jax
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    x = jnp.asarray(RNG.standard_normal((1, 14, 14, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal(W_SHAPE) * .3, jnp.float32)
    # 'same' K=3 s=1 pre-pads to 16x16; the 1x1 grid on this tiny mesh
    autotune.store(autotune.make_key((1, 16, 16, 8), W_SHAPE, stride=1,
                                     pad=0,
                                     op=autotune.sharded_key_op(1, 1)),
                   dict(tile_h=6, tile_cout=4, dataflow="halo",
                        source="model"))
    autotune.store(autotune.make_key((1, 16, 16, 8), W_SHAPE, stride=1,
                                     pad=0),
                   dict(tile_h=2, tile_cout=12, dataflow="carry",
                        source="model"))

    seen = {}
    real = ops.trim_conv2d

    def spy(*args, **kw):
        seen.update(kw)
        return real(*args, **kw)

    monkeypatch.setattr(ops, "trim_conv2d", spy)
    got = ops.conv2d(x, w, mesh=mesh)
    assert (seen["tile_h"], seen["tile_cout"], seen["dataflow"]) \
        == (6, 4, "halo")
    _allclose(got, ref.conv2d(x, w))


def test_weight_grad_candidates_fit_vmem():
    plans = autotune.candidate_weight_grad_knobs(X_SHAPE, W_SHAPE)
    assert plans
    assert all(p.vmem_resident_bytes <= VMEM_BYTES for p in plans)
    # the full-height cotangent strip (one sweep step per image) is
    # always a candidate
    assert any(p.go_tiles == 1 for p in plans)


def test_backward_pass_uses_cached_knobs(monkeypatch):
    """The conv backward consults both caches: the weight-grad kernel
    under its conv2d_wgrad key, the input-grad conv under the conv2d
    key of its transformed shapes."""
    import jax
    from repro.kernels import trim_conv2d as tc
    x = jnp.asarray(RNG.standard_normal(X_SHAPE), jnp.float32)
    w = jnp.asarray(RNG.standard_normal(W_SHAPE) * .3, jnp.float32)
    autotune.store(autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0,
                                     op="conv2d_wgrad"),
                   dict(tile_go=3, tile_cout=6))
    from repro.core.conv_plan import input_grad_geometry
    geo = input_grad_geometry(X_SHAPE, W_SHAPE, stride=1, pad=0)
    autotune.store(autotune.make_key(geo["g_padded_shape"],
                                     geo["wt_shape"], stride=1, pad=0),
                   dict(tile_h=5, tile_cout=4, dataflow="halo",
                        source="model"))

    seen = {}
    real_ig, real_wg = ops.trim_conv2d_input_grad, \
        ops.trim_conv2d_weight_grad

    def spy_ig(*a, **kw):
        seen["ig"] = kw
        return real_ig(*a, **kw)

    def spy_wg(*a, **kw):
        seen["wg"] = kw
        return real_wg(*a, **kw)

    monkeypatch.setattr(ops, "trim_conv2d_input_grad", spy_ig)
    monkeypatch.setattr(ops, "trim_conv2d_weight_grad", spy_wg)
    gx, gw = jax.grad(
        lambda x, w: (ops.conv2d(x, w, padding="valid") ** 2).sum(),
        argnums=(0, 1))(x, w)
    assert (seen["ig"]["tile_h"], seen["ig"]["tile_cout"],
            seen["ig"]["dataflow"]) == (5, 4, "halo")
    assert (seen["wg"]["tile_go"], seen["wg"]["tile_cout"]) == (3, 6)
    dx_ref, dw_ref = ref.conv2d_grads(
        x, w, 2 * ref.conv2d(x, w, padding="valid"), stride=1,
        padding="valid")
    _allclose(gx, dx_ref, tol=1e-5)
    _allclose(gw, dw_ref, tol=1e-5)


def test_packed_params_pick_up_cached_plan():
    """Pack-time cache consultation: a tuned record fixes the packed
    tile_cout and rides along as tile_h/dataflow hints."""
    key = autotune.make_key((1, 14, 14, 8), (3, 3, 8, 12),
                            stride=1, pad=0)
    autotune.store(key, dict(tile_h=4, tile_cout=6, dataflow="halo",
                             source="model"))
    w = jnp.asarray(RNG.standard_normal(W_SHAPE) * .3, jnp.float32)
    pk = ops.pack_conv2d_weights(w, x_shape=(1, 12, 12, 8))
    assert (pk.tile_cout, pk.tile_h, pk.dataflow) == (6, 4, "halo")
    x = jnp.asarray(RNG.standard_normal((1, 12, 12, 8)), jnp.float32)
    _allclose(ops.conv2d(x, pk), ref.conv2d(x, w))


# ---------------------------------------------------------------------------
# Fused-group keys (DESIGN.md §8): conv2d_fused:d<depth> namespacing
# ---------------------------------------------------------------------------

def test_fused_keys_never_alias_other_namespaces():
    """A fused-group record lives under conv2d_fused:d<depth>:... — it
    can never collide with the per-layer conv2d:/conv2d_wgrad:/
    conv2d_shard: keys of its own stages, and groups that share a
    leading stage stay distinct (depth + signature chain in the key)."""
    from repro.core.fuse_plan import build_group
    from repro.core.netplan import network_layers
    layers = network_layers("alexnet")[1:]        # conv2..conv5 (K<=5)
    g2 = build_group(layers[:2], 0)
    g4 = build_group(layers, 0)
    k2 = autotune.fused_key(g2.signature)
    k4 = autotune.fused_key(g4.signature)
    assert k2.startswith("conv2d_fused:d2:")
    assert k4.startswith("conv2d_fused:d4:")
    per_layer = {autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0, op=op)
                 for op in ("conv2d", "conv2d_wgrad",
                            autotune.sharded_key_op(1, 4))}
    assert len({k2, k4, *per_layer}) == 2 + len(per_layer)
    # batch and dtype are part of the problem
    assert autotune.fused_key(g2.signature, n=4) != k2
    assert autotune.fused_key(g2.signature, dtype="bfloat16") != k2
    # writing a fused record never shadows the others
    autotune.store(k2, dict(strip_rows=3, depth=2))
    autotune.store(k4, dict(strip_rows=7, depth=4))
    assert autotune.fused_knobs_for(g2.signature)["strip_rows"] == 3
    assert autotune.fused_knobs_for(g4.signature)["strip_rows"] == 7
    assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None
    # malformed fused records are rejected, not trusted
    autotune.store(k2, dict(strip_rows="bad"))
    assert autotune.fused_knobs_for(g2.signature) is None
    autotune.store(k2, dict(strip_rows=0))
    assert autotune.fused_knobs_for(g2.signature) is None


def test_tune_fused_round_trip():
    """tune_fused persists a VMEM-feasible strip height under the fused
    key; FusedGroupPlan.build(use_autotune_cache=True) then runs on the
    cached group knob (surviving the in-process memo)."""
    from repro.core.fuse_plan import FUSED_VMEM_BUDGET, FusedGroupPlan, \
        build_group
    from repro.core.netplan import infer_pools, network_layers
    layers = network_layers("alexnet")
    pools = list(infer_pools(layers))
    sub = layers[1:]                              # the fusable chain
    rec = autotune.tune_fused(sub, pools=pools[1:])
    assert rec["strip_rows"] >= 1 and rec["depth"] == len(sub)
    assert rec["source"] == "model"
    g = build_group(sub, 0, strip_rows=rec["strip_rows"], pools=pools[1:])
    assert g.vmem_resident_bytes <= FUSED_VMEM_BUDGET
    got = autotune.fused_knobs_for(g.signature)
    assert got == rec
    autotune.reset_memory_cache()
    assert autotune.fused_knobs_for(g.signature) == rec
    # the plan-level consumer: cached strip heights drive the partition
    plan = FusedGroupPlan.build("alexnet", use_autotune_cache=True)
    fused = [gg for gg in plan.groups if gg.fused]
    assert fused and fused[0].strip_rows == rec["strip_rows"]
    # REPRO_CONV_AUTOTUNE=0 disables the lookup
    os.environ[autotune.AUTOTUNE_ENV] = "0"
    try:
        assert autotune.fused_knobs_for(g.signature) is None
    finally:
        del os.environ[autotune.AUTOTUNE_ENV]


def test_tune_fused_network_sweep():
    """One record per depth>=2 group of the partition, each under its
    own conv2d_fused key."""
    recs = autotune.tune_fused_network("vgg16")
    assert recs, "vgg16 partition produced no fused groups"
    from repro.core.fuse_plan import FusedGroupPlan
    plan = FusedGroupPlan.build("vgg16")
    assert len(recs) == sum(1 for g in plan.groups if g.fused)
    keys = {r["key"] for r in recs.values()}
    assert len(keys) == len(recs)
    for r in recs.values():
        assert r["key"].startswith("conv2d_fused:")
        assert autotune.lookup(r["key"])["strip_rows"] == r["strip_rows"]


# ---------------------------------------------------------------------------
# Quantized keys (DESIGN.md §11): conv2d_q8 namespacing + dtype in the key
# ---------------------------------------------------------------------------

def test_q8_keys_never_alias_other_namespaces():
    """An int8 record lives under conv2d_q8:...:int8:... — the same raw
    shape tuple can never collide with the conv2d:/conv2d_wgrad:/
    conv2d_shard:/conv2d_fused: records of its own geometry, and dtype
    is part of *every* namespace's key (an f32 and an int8 tune of the
    identical problem are distinct records in the same namespace)."""
    q8_key = autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0,
                               dtype="int8", op="conv2d_q8")
    assert q8_key.startswith("conv2d_q8:")
    assert ":int8:" in q8_key
    others = {autotune.make_key(X_SHAPE, W_SHAPE, stride=1, pad=0, op=op)
              for op in ("conv2d", "conv2d_wgrad",
                         autotune.sharded_key_op(1, 4))}
    assert len({q8_key, *others}) == 1 + len(others)
    # dtype distinguishes records inside a namespace, not just across
    for op in ("conv2d", "conv2d_q8", "conv2d_wgrad"):
        assert autotune.make_key(X_SHAPE, W_SHAPE, op=op, dtype="int8") \
            != autotune.make_key(X_SHAPE, W_SHAPE, op=op, dtype="float32")
    # writing the q8 record never shadows the plain conv2d consult
    autotune.store(q8_key, dict(tile_h=4, tile_cout=6, dataflow="halo"))
    assert autotune.knobs_for(X_SHAPE, W_SHAPE) is None
    assert autotune.knobs_for(X_SHAPE, W_SHAPE, dtype="int8",
                              op="conv2d_q8")["tile_cout"] == 6
    # ... and the f32 record never leaks into the q8 consult
    autotune.store(autotune.make_key(X_SHAPE, W_SHAPE),
                   dict(tile_h=8, tile_cout=12, dataflow="carry"))
    got = autotune.knobs_for(X_SHAPE, W_SHAPE, dtype="int8",
                             op="conv2d_q8")
    assert (got["tile_h"], got["dataflow"]) == (4, "halo")
    # malformed q8 records are rejected, not trusted
    autotune.store(q8_key, dict(tile_h="bad", tile_cout=6,
                                dataflow="halo"))
    assert autotune.knobs_for(X_SHAPE, W_SHAPE, dtype="int8",
                              op="conv2d_q8") is None


# ---------------------------------------------------------------------------
# Serving prewarm (DESIGN.md §10): no cold tunes after prewarm_buckets
# ---------------------------------------------------------------------------

def _serving_topo(scale=8):
    from repro.core import network_layers, scale_layers
    return scale_layers(network_layers("alexnet"), scale)


def test_prewarm_buckets_covers_every_grid_shape(monkeypatch):
    """After ``prewarm_buckets``, every (layer, bucket) problem of the
    grid resolves through ``knobs_for`` without a single call into the
    tuner — the serving definition of "zero cold tunes"."""
    from repro.core.netplan import layer_kernel_problem
    from repro.kernels.ops import MAX_NATIVE_K
    topo = _serving_topo()
    buckets = (1, 2, 4)
    recs = autotune.prewarm_buckets(topo, buckets)
    assert sorted(recs) == [1, 2, 4]

    def cold(*a, **kw):                    # any tune call is a cold tune
        raise AssertionError(f"cold tune after prewarm: {a} {kw}")

    monkeypatch.setattr(autotune, "tune", cold)
    for b in buckets:
        for layer in topo:
            if layer.kernel > MAX_NATIVE_K:
                assert "skipped" in recs[b]["layers"][layer.name]
                continue
            x_shape, pad, w_shape, _ = layer_kernel_problem(layer, n=b)
            knobs = autotune.knobs_for(x_shape, w_shape,
                                       stride=layer.stride, pad=pad,
                                       groups=layer.groups)
            assert knobs is not None, (layer.name, b)
            assert knobs == {k: v for k, v in
                             recs[b]["layers"][layer.name].items()
                             if k in knobs}


def test_prewarm_buckets_fused_seeds_group_records():
    """``fused=True`` additionally sweeps the conv2d_fused group records
    per bucket, so the megakernel path is warm too."""
    topo = _serving_topo()
    recs = autotune.prewarm_buckets(topo, (1, 2), fused=True)
    for b in (1, 2):
        fused = recs[b]["fused"]
        assert fused, f"no fused groups recorded at bucket {b}"
        for r in fused.values():
            assert r["key"].startswith("conv2d_fused:")
            assert f":n{b}:" in r["key"] or b == 1
            assert autotune.lookup(r["key"]) is not None


def test_prewarm_buckets_dedups_and_validates():
    topo = _serving_topo()
    with pytest.raises(ValueError):
        autotune.prewarm_buckets(topo, (0, 2))
    recs = autotune.prewarm_buckets(topo, (2, 1, 2, 1))
    assert sorted(recs) == [1, 2]


_PREWARM_WORKER = r"""
import sys
from repro.core import autotune, network_layers, scale_layers
path = sys.argv[1]
topo = scale_layers(network_layers("alexnet"), 8)
autotune.prewarm_buckets(topo, (1, 2), path=path)
print("done")
"""


def test_concurrent_prewarm_merges_cleanly(tmp_path):
    """ISSUE 8: 4 serving replicas prewarming the same cache path at
    once (the multi-replica startup race) lose nothing — every record a
    solo prewarm would write is present after the concurrent ones merge
    through the flock+merge store."""
    import subprocess
    import sys
    path = str(tmp_path / "convtune.json")
    env = dict(os.environ, PYTHONPATH="src")
    procs = [subprocess.Popen(
        [sys.executable, "-c", _PREWARM_WORKER, path],
        env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for _ in range(4)]
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err

    # the expected key set: what a single prewarm would persist
    topo = _serving_topo()
    want = set()
    for per in autotune.prewarm_buckets(topo, (1, 2),
                                        write=False).values():
        want |= {r["key"] for r in per["layers"].values()
                 if "key" in r}
    with open(path) as f:
        entries = json.load(f)["entries"]
    missing = want - set(entries)
    assert not missing, f"lost {len(missing)}/{len(want)}: " \
                        f"{sorted(missing)[:5]}"
