"""Differential-testing layer: sharded conv == single-device conv.

Under the forced multi-device CPU harness (conftest:
``REPRO_MULTIDEVICE=1`` -> ``--xla_force_host_platform_device_count=8``)
every test here asserts that the ``shard_map`` halo-exchange path of
``ops.conv2d(..., mesh=)`` — forward AND both gradients — is allclose to
the single-device kernel and to the ``ref.conv2d_grads`` oracle across a
(mesh shape x H/W x K x stride x groups x dataflow) grid, including
output heights not divisible by the device count and the over-sharded
regime where a slab is shorter than the K-1 halo.

Tolerance policy (DESIGN.md §6): f32 <= 1e-5 max-abs relative.  The
sharded path runs the *same* per-strip fp32 accumulation as the
single-device kernel; only the cross-boundary summation order of dw/db
(the psum) differs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_shard import ShardedConvPlan
from repro.kernels import ops, ref

pytestmark = pytest.mark.multidevice

RNG = np.random.default_rng(23)

# (data_shards, spatial_shards) — products must fit the 8-device harness
MESHES = [(1, 2), (2, 2), (1, 4), (4, 1), (2, 4), (1, 8)]

# (h, w, k, stride, groups, padding) — h_out often not divisible by the
# spatial shard count; the k=5 row over 8 shards exercises slab < K-1
GEOMETRIES = [
    (13, 10, 3, 1, 1, "same"),
    (16, 9, 3, 2, 1, "same"),
    (12, 12, 4, 2, 1, "valid"),
    (11, 10, 5, 1, 2, "valid"),
    (10, 8, 2, 1, 1, "same"),
    (9, 9, 1, 1, 1, "valid"),
]


def _allclose(a, b, tol=1e-5):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-9
    assert float(np.abs(a - b).max()) / scale < tol


def _mesh(data: int, model: int):
    if data * model > jax.device_count():
        pytest.skip(f"mesh needs {data * model} devices, have "
                    f"{jax.device_count()}")
    devs = np.array(jax.devices()[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _case(h, w, k, groups, *, n=4, seed=0):
    rng = np.random.default_rng(seed)
    cin, cout = 4 * groups, 6 * groups
    x = jnp.asarray(rng.standard_normal((n, h, w, cin)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((k, k, cin // groups, cout)) * .3,
                     jnp.float32)
    return x, wt


# ---------------------------------------------------------------------------
# Forward: sharded == single-device == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("data,model", MESHES)
@pytest.mark.parametrize("dataflow", ["carry", "halo"])
def test_sharded_forward_matches_single_device(data, model, dataflow,
                                               multidevice_harness):
    mesh = _mesh(data, model)
    for i, (h, w, k, s, g, padding) in enumerate(GEOMETRIES):
        x, wt = _case(h, w, k, g, seed=i)
        got = ops.conv2d(x, wt, stride=s, padding=padding,
                         feature_group_count=g, dataflow=dataflow,
                         mesh=mesh, use_autotune_cache=False)
        single = ops.conv2d(x, wt, stride=s, padding=padding,
                            feature_group_count=g, dataflow=dataflow,
                            use_autotune_cache=False)
        want = ref.conv2d(x, wt, stride=s, padding=padding,
                          feature_group_count=g)
        _allclose(got, single)
        _allclose(got, want)


@pytest.mark.parametrize("data,model", [(1, 2), (2, 4), (1, 8)])
def test_sharded_fused_epilogue(data, model, multidevice_harness):
    """Bias + activation fuse into the per-shard kernel epilogue."""
    mesh = _mesh(data, model)
    x, wt = _case(14, 11, 3, 1, seed=7)
    b = jnp.asarray(RNG.standard_normal((6,)), jnp.float32)
    for act in (None, "relu", "gelu"):
        got = ops.conv2d(x, wt, bias=b, activation=act, mesh=mesh,
                         use_autotune_cache=False)
        _allclose(got, ref.conv2d(x, wt, bias=b, activation=act))


def test_sharded_depthwise(multidevice_harness):
    mesh = _mesh(1, 4)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 13, 9, 8)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((3, 3, 1, 8)) * .3, jnp.float32)
    got = ops.depthwise_conv2d(x, wd, mesh=mesh)
    _allclose(got, ref.conv2d(x, wd, feature_group_count=8))


# ---------------------------------------------------------------------------
# Gradients: the vjp transposes the halo shuffle, psums dw/db
# ---------------------------------------------------------------------------

GRAD_GRID = [
    # (h, w, k, stride, groups, padding, dataflow)
    (13, 10, 3, 1, 1, "same", "carry"),
    (16, 9, 3, 2, 1, "same", "halo"),
    (12, 12, 4, 2, 1, "valid", "carry"),
    (11, 10, 5, 1, 2, "valid", "halo"),
]


@pytest.mark.parametrize("data,model", [(1, 2), (2, 2), (2, 4), (1, 8)])
def test_sharded_gradients_match_ref(data, model, multidevice_harness):
    mesh = _mesh(data, model)
    for i, (h, w, k, s, g, padding, df) in enumerate(GRAD_GRID):
        x, wt = _case(h, w, k, g, seed=40 + i)
        rng = np.random.default_rng(60 + i)
        y = ref.conv2d(x, wt, stride=s, padding=padding,
                       feature_group_count=g)
        gy = jnp.asarray(rng.standard_normal(y.shape), jnp.float32)

        def loss(x, w):
            out = ops.conv2d(x, w, stride=s, padding=padding,
                             feature_group_count=g, dataflow=df,
                             mesh=mesh, use_autotune_cache=False)
            return (out * gy).sum()

        dx, dw = jax.grad(loss, argnums=(0, 1))(x, wt)
        dx_ref, dw_ref = ref.conv2d_grads(x, wt, gy, stride=s,
                                          padding=padding,
                                          feature_group_count=g)
        _allclose(dx, dx_ref)
        _allclose(dw, dw_ref)


def test_sharded_vjp_matches_single_device_vjp(multidevice_harness):
    """Direct vjp-vs-vjp lock: same cotangent in, same cotangents out
    (x, w AND bias) as the single-device custom_vjp path."""
    mesh = _mesh(2, 4)
    x, wt = _case(15, 12, 3, 1, seed=80)
    b = jnp.asarray(RNG.standard_normal((6,)), jnp.float32)

    def f(mesh_arg):
        def g(x, w, b):
            return ops.conv2d(x, w, stride=2, padding="same", bias=b,
                              activation="relu", mesh=mesh_arg,
                              use_autotune_cache=False)
        return g

    y_sh, vjp_sh = jax.vjp(f(mesh), x, wt, b)
    y_1d, vjp_1d = jax.vjp(f(None), x, wt, b)
    _allclose(y_sh, y_1d)
    gy = jnp.asarray(np.random.default_rng(81).standard_normal(y_1d.shape),
                     jnp.float32)
    for got, want in zip(vjp_sh(gy), vjp_1d(gy)):
        _allclose(got, want)


def test_sharded_train_step_decreases_loss(multidevice_harness):
    """A data+spatial-parallel CNN train step on the sharded convs
    learns on the same synthetic task as examples/train_cnn.py."""
    from repro.models import layers
    from repro.models.base import init_params
    from repro.optim import AdamWConfig, adamw

    mesh = _mesh(2, 2)
    rng = np.random.default_rng(5)
    templates = rng.standard_normal((4, 12, 12, 3))
    labels = rng.integers(0, 4, size=8)
    x = jnp.asarray(templates[labels]
                    + 0.3 * rng.standard_normal((8, 12, 12, 3)),
                    jnp.float32)
    y = jnp.asarray(labels, jnp.int32)
    params = init_params(
        layers.simple_cnn_params(cin=3, channels=(8,), n_classes=4,
                                 depthwise_stage=False),
        jax.random.PRNGKey(0))
    cfg = AdamWConfig(lr=2e-2, warmup_steps=1, decay_steps=50)
    moments = adamw.init_moments(params, cfg)

    def loss_fn(p):
        logits = layers.simple_cnn_apply(p, x, mesh=mesh)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, m, i):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, m, _ = adamw.apply_updates(p, grads, m, i, cfg)
        return p, m, loss

    losses = []
    for i in range(8):
        params, moments, loss = step(params, moments, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    # and the sharded forward agrees with the single-device one on the
    # trained params
    _allclose(layers.simple_cnn_apply(params, x, mesh=mesh),
              layers.simple_cnn_apply(params, x), tol=1e-4)


# ---------------------------------------------------------------------------
# Plan consistency on the harness mesh
# ---------------------------------------------------------------------------

def test_conv_rules_overrides(multidevice_harness):
    """make_conv_rules overrides reach resolve_conv_mesh: strips=None
    disables spatial parallelism (batch-only sharding on a mesh that
    has a 'model' axis) and the result still matches the oracle."""
    from repro.distributed.sharding import make_conv_rules

    mesh = _mesh(2, 4)
    rules = make_conv_rules(strips=None)
    plan = ShardedConvPlan.from_mesh((4, 12, 12, 4), (3, 3, 4, 6), mesh,
                                     rules=rules)
    assert (plan.batch_shards, plan.spatial_shards) == (2, 1)
    assert plan.spatial_axis is None
    assert plan.halo_bytes == 0
    x, wt = _case(12, 12, 3, 1, seed=90)
    got = ops.conv2d(x, wt, mesh=mesh, rules=rules,
                     use_autotune_cache=False)
    _allclose(got, ref.conv2d(x, wt))


def test_sharded_plan_resolves_from_mesh(multidevice_harness):
    """from_mesh reads the conv rules: batch -> 'data', strips ->
    'model'; the executed path and the analytics see the same grid."""
    mesh = _mesh(2, 4)
    plan = ShardedConvPlan.from_mesh((4, 16, 16, 8), (3, 3, 8, 16), mesh)
    assert (plan.batch_shards, plan.spatial_shards) == (2, 4)
    assert (plan.batch_axis, plan.spatial_axis) == ("data", "model")
    assert plan.n_devices == 8
    t = plan.sharded_traffic()
    assert t["halo"] == plan.halo_bytes > 0
    assert t["total"] == t["hbm_total"] + t["halo"]
