"""Property-based test layer over the conv subsystem.

Hypothesis strategies sample (H, W, K, stride, pad, groups, dataflow)
geometries and assert the ConvPlan invariants the hand-picked edge list
used to spot-check one by one:

  * the Pallas grid covers the output exactly (no output row/channel
    unassigned, none computed twice);
  * "trim" (halo) accounting never moves fewer input bytes than
    "3dtrim" (carry) accounting;
  * padded layouts round-trip (padded rows == strips * tile_h ==
    h + pad + pad_bottom; the halo window is the strip plus K-1 rows);
  * backward geometry round-trips: the input-grad conv lands exactly
    back on the input shape, the weight-grad plan's windows cover every
    tap of every cotangent row;

and that the kernels agree with the oracle (forward AND both gradients)
on the sampled geometries.  Runs under real hypothesis when installed,
else under the conftest fallback as a deterministic random sweep.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.conv_plan import (ConvPlan, WeightGradPlan,
                                  input_grad_geometry)
from repro.core.conv_shard import ShardedConvPlan
from repro.kernels import ops, ref
from repro.kernels.trim_conv2d import (trim_conv2d, trim_conv2d_input_grad,
                                       trim_conv2d_weight_grad)


def _close(a, b, tol=2e-3):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape, (a.shape, b.shape)
    scale = float(np.abs(b).max()) + 1e-9
    assert float(np.abs(a - b).max()) / scale < tol


def _geometry(h, w, k, stride, pad_frac, groups, cin_pg, cout_pg):
    """Build a valid sampled geometry or None (too-small inputs)."""
    pad = int(pad_frac * (k - 1) + 0.5)        # 0 <= pad <= k-1
    if h + 2 * pad < k or w + 2 * pad < k:
        return None
    cin = cin_pg * groups
    cout = cout_pg * groups
    return dict(h=h, w=w, k=k, stride=stride, pad=pad, groups=groups,
                cin=cin, cout=cout)


# ---------------------------------------------------------------------------
# ConvPlan invariants
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(h=st.integers(4, 40), w=st.integers(4, 40),
       k=st.sampled_from([1, 2, 3, 4, 5, 7]),
       stride=st.sampled_from([1, 2, 3]),
       pad_frac=st.floats(min_value=0.0, max_value=1.0),
       groups=st.sampled_from([1, 2, 4]),
       cin_pg=st.integers(1, 6), cout_pg=st.integers(1, 6),
       tile_h_mult=st.integers(1, 6),
       dataflow=st.sampled_from(["carry", "halo"]))
def test_conv_plan_invariants(h, w, k, stride, pad_frac, groups, cin_pg,
                              cout_pg, tile_h_mult, dataflow):
    geo = _geometry(h, w, k, stride, pad_frac, groups, cin_pg, cout_pg)
    if geo is None:
        return
    try:
        plan = ConvPlan.build(
            (1, geo["h"], geo["w"], geo["cin"]),
            (k, k, cin_pg, geo["cout"]), stride=stride, pad=geo["pad"],
            groups=groups, tile_h=tile_h_mult * stride,
            dataflow=dataflow)
    except ValueError:
        return                                  # empty output etc.

    # grid covers the output exactly
    n, g, strips, co = plan.grid
    assert (n, g) == (1, groups)
    assert strips == plan.g_tiles and co == plan.co_tiles
    assert strips * plan.th_out >= plan.h_out + plan.delta
    assert (strips - 1) * plan.th_out < plan.h_out + plan.delta
    assert co * plan.tile_cout >= plan.cout_per_group
    assert (co - 1) * plan.tile_cout < plan.cout_per_group

    # padded shapes round-trip
    assert plan.rows_padded == strips * plan.tile_h
    assert plan.rows_padded == plan.h + plan.pad + plan.pad_bottom
    assert plan.padded_input_shape == (1, plan.rows_padded, plan.wp,
                                       plan.cin)
    assert plan.halo_in_block[1] == plan.tile_h + k - 1
    assert plan.halo_padded_input_shape[1] == plan.rows_padded + k - 1
    assert plan.padded_output_shape[1] >= plan.delta + plan.h_out
    # the strip window always reaches the taps of its last output row
    assert plan.wp >= (plan.w_out - 1) * stride + k

    # halo accounting never moves fewer bytes than carry accounting
    trim = plan.hbm_bytes("trim")
    shadow = plan.hbm_bytes("3dtrim")
    assert trim["input"] >= shadow["input"]
    assert trim["total"] >= shadow["total"]
    assert shadow["overhead_pct"] == 0.0
    assert plan.halo_rows("trim") == (plan.g_tiles - 1) * (k - 1)
    # the plan's own dataflow accounting maps carry->3dtrim, halo->trim
    assert plan.hbm_bytes() == (shadow if dataflow == "carry" else trim)
    assert plan.arithmetic_intensity() > 0
    assert plan.flops == 2 * plan.macs


@settings(max_examples=30, deadline=None)
@given(h=st.integers(4, 32), w=st.integers(4, 32),
       k=st.sampled_from([1, 2, 3, 5]), stride=st.sampled_from([1, 2, 3]),
       pad_frac=st.floats(min_value=0.0, max_value=1.0),
       groups=st.sampled_from([1, 2, 3]),
       cin_pg=st.integers(1, 5), cout_pg=st.integers(1, 5),
       tile_go=st.integers(1, 8))
def test_backward_plan_invariants(h, w, k, stride, pad_frac, groups,
                                  cin_pg, cout_pg, tile_go):
    geo = _geometry(h, w, k, stride, pad_frac, groups, cin_pg, cout_pg)
    if geo is None:
        return
    x_shape = (2, geo["h"], geo["w"], geo["cin"])
    w_shape = (k, k, cin_pg, geo["cout"])
    s, pad = stride, geo["pad"]

    # input-grad geometry round-trips onto the input shape
    igeo = input_grad_geometry(x_shape, w_shape, stride=s, pad=pad,
                               groups=groups)
    gh = igeo["g_padded_shape"][1]
    gw = igeo["g_padded_shape"][2]
    assert gh - k + 1 == geo["h"] and gw - k + 1 == geo["w"]
    ig_plan = ConvPlan.build_input_grad(x_shape, w_shape, stride=s,
                                        pad=pad, groups=groups)
    assert ig_plan.stride == 1 and ig_plan.pad == 0
    assert ig_plan.h_out == geo["h"] and ig_plan.w_out == geo["w"]
    assert (ig_plan.cin, ig_plan.cout) == (geo["cout"], geo["cin"])

    # weight-grad windows cover every tap of every cotangent row
    wg = ConvPlan.build_weight_grad(x_shape, w_shape, stride=s, pad=pad,
                                    groups=groups, tile_go=tile_go)
    assert isinstance(wg, WeightGradPlan)
    assert wg.go_tiles * wg.tile_go >= wg.h_out
    assert (wg.go_tiles - 1) * wg.tile_go < wg.h_out
    assert wg.window_rows == (wg.tile_go - 1) * s + k
    # last strip's window ends exactly at the padded ifmap bottom
    assert (wg.go_tiles - 1) * wg.tile_go * s + wg.window_rows \
        == wg.x_rows_padded
    assert wg.wp >= (wg.w_out - 1) * s + k
    # the weight grad mirrors the forward MAC count exactly
    fwd = ConvPlan.build(x_shape, w_shape, stride=s, pad=pad,
                         groups=groups)
    assert wg.macs == fwd.macs
    assert wg.hbm_bytes()["total"] > 0
    assert wg.vmem_resident_bytes > 0


# ---------------------------------------------------------------------------
# ShardedConvPlan invariants (DESIGN.md §6)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(h=st.integers(4, 40), w=st.integers(4, 40),
       k=st.sampled_from([1, 2, 3, 4, 5, 7]),
       stride=st.sampled_from([1, 2, 3]),
       pad_frac=st.floats(min_value=0.0, max_value=1.0),
       groups=st.sampled_from([1, 2]),
       cin_pg=st.integers(1, 5), cout_pg=st.integers(1, 5),
       n_per_shard=st.integers(1, 3),
       batch_shards=st.sampled_from([1, 2, 4]),
       spatial_shards=st.sampled_from([1, 2, 3, 4, 8]),
       dataflow=st.sampled_from(["carry", "halo"]))
def test_sharded_plan_invariants(h, w, k, stride, pad_frac, groups,
                                 cin_pg, cout_pg, n_per_shard,
                                 batch_shards, spatial_shards, dataflow):
    geo = _geometry(h, w, k, stride, pad_frac, groups, cin_pg, cout_pg)
    if geo is None:
        return
    n = n_per_shard * batch_shards
    try:
        plan = ShardedConvPlan.build(
            (n, geo["h"], geo["w"], geo["cin"]),
            (k, k, cin_pg, geo["cout"]), stride=stride, pad=geo["pad"],
            groups=groups, dataflow=dataflow,
            batch_shards=batch_shards, spatial_shards=spatial_shards)
    except ValueError:
        return                                  # empty output etc.

    # per-shard strips tile the global output exactly: contiguous,
    # disjoint, and every output row owned by exactly one shard
    strips = plan.shard_strips()
    assert len(strips) == spatial_shards
    assert sum(rows for _, rows in strips) == plan.h_out
    cursor = 0
    for start, rows in strips:
        assert 0 <= rows <= plan.h_out_local
        if rows:
            assert start == cursor
            cursor += rows
    assert cursor == plan.h_out

    # halo bytes: each interior seam moves K-1 rows down (forward
    # ppermute) and K-1 rows back up (the vjp transpose shuffle), for
    # every image — 2 (K-1)-row boundaries per seam at every stride
    db = plan.dtype_bytes
    assert plan.halo_bytes == (2 * (k - 1) * plan.wp * plan.cin
                               * db * (spatial_shards - 1) * n)
    assert plan.halo_bytes == 2 * plan.halo_bytes_oneway
    if spatial_shards == 1 or k == 1:
        assert plan.halo_bytes == 0
    assert plan.halo_bytes_per_device * plan.n_devices == plan.halo_bytes

    # shards=1 reduces exactly to ConvPlan traffic
    t = plan.sharded_traffic()
    base = ConvPlan.build(
        (n, geo["h"], geo["w"], geo["cin"]), (k, k, cin_pg, geo["cout"]),
        stride=stride, pad=geo["pad"], groups=groups, dataflow=dataflow)
    if spatial_shards == 1 and batch_shards == 1:
        bt = base.hbm_bytes()
        assert t["halo"] == 0
        assert t["total"] == t["hbm_total"] == bt["total"]
        assert (t["input"], t["weights"], t["output"]) == \
            (bt["input"], bt["weights"], bt["output"])
    else:
        assert t["total"] == t["hbm_total"] + t["halo"]

    # the per-device kernel invocation is a consistent ordinary ConvPlan
    local = plan.local_plan()
    assert isinstance(local, ConvPlan)
    # slab alignment: the local kernel emits exactly the owned rows
    assert local.h_out == plan.local_out_rows == plan.h_out_local
    assert local.w_out == plan.w_out
    assert (local.n, local.cin, local.cout) == (plan.n_local, plan.cin,
                                                plan.cout)
    # local window: slab + K-1 tail
    assert plan.local_in_rows == plan.slab_rows + (k - 1)
    assert plan.local_flops == 2 * plan.local_macs


@settings(max_examples=10, deadline=None)
@given(batch_shards=st.sampled_from([3, 5, 7]))
def test_sharded_plan_rejects_indivisible_batch(batch_shards):
    try:
        ShardedConvPlan.build((4, 12, 12, 4), (3, 3, 4, 8),
                              batch_shards=batch_shards)
    except ValueError:
        return
    assert 4 % batch_shards == 0


# ---------------------------------------------------------------------------
# Kernels vs oracle on sampled geometries
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(h=st.integers(5, 20), w=st.integers(5, 20),
       k=st.sampled_from([1, 2, 3, 4, 5]),
       stride=st.sampled_from([1, 2, 3]),
       pad_frac=st.floats(min_value=0.0, max_value=1.0),
       groups=st.sampled_from([1, 2, 4]),
       cin_pg=st.integers(1, 4), cout_pg=st.integers(1, 4),
       dataflow=st.sampled_from(["carry", "halo"]),
       seed=st.integers(0, 2 ** 16))
def test_conv2d_matches_ref_on_sampled_geometries(
        h, w, k, stride, pad_frac, groups, cin_pg, cout_pg, dataflow,
        seed):
    geo = _geometry(h, w, k, stride, pad_frac, groups, cin_pg, cout_pg)
    if geo is None:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, geo["h"], geo["w"],
                                         geo["cin"])), jnp.float32)
    wt = jnp.asarray(
        rng.standard_normal((k, k, cin_pg, geo["cout"])) * .3,
        jnp.float32)
    pad = geo["pad"]
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    got = trim_conv2d(xp, wt, stride=stride, pad=0, groups=groups,
                      dataflow=dataflow)
    want = ref.conv2d(xp, wt, stride=stride, padding="valid",
                      feature_group_count=groups)
    assert got.shape == want.shape
    _close(got, want)


@settings(max_examples=12, deadline=None)
@given(h=st.integers(5, 16), w=st.integers(5, 16),
       k=st.sampled_from([1, 2, 3, 4]), stride=st.sampled_from([1, 2]),
       pad_frac=st.floats(min_value=0.0, max_value=1.0),
       groups=st.sampled_from([1, 2]),
       cin_pg=st.integers(1, 4), cout_pg=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
def test_gradients_match_ref_on_sampled_geometries(
        h, w, k, stride, pad_frac, groups, cin_pg, cout_pg, seed):
    geo = _geometry(h, w, k, stride, pad_frac, groups, cin_pg, cout_pg)
    if geo is None:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, geo["h"], geo["w"],
                                         geo["cin"])), jnp.float32)
    wt = jnp.asarray(
        rng.standard_normal((k, k, cin_pg, geo["cout"])) * .3,
        jnp.float32)
    pad = geo["pad"]
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    y = ref.conv2d(xp, wt, stride=stride, padding="valid",
                   feature_group_count=groups)
    gy = jnp.asarray(rng.standard_normal(y.shape), jnp.float32)
    dx_ref, dw_ref = ref.conv2d_grads(xp, wt, gy, stride=stride,
                                      padding="valid",
                                      feature_group_count=groups)
    dx = trim_conv2d_input_grad(gy, wt, x_shape=xp.shape, stride=stride,
                                pad=0, groups=groups)
    dw = trim_conv2d_weight_grad(xp, gy, kernel_size=(k, k),
                                 stride=stride, pad=0, groups=groups)
    _close(dx, dx_ref, tol=1e-5)
    _close(dw, dw_ref, tol=1e-5)


@settings(max_examples=15, deadline=None)
@given(h=st.integers(6, 20), w=st.integers(6, 20), cin=st.integers(1, 6),
       cout=st.integers(1, 6), k=st.sampled_from([1, 3, 5]),
       stride=st.sampled_from([1, 2]),
       padding=st.sampled_from(["same", "valid"]),
       seed=st.integers(0, 2 ** 16))
def test_ops_conv2d_matches_ref_on_sampled_geometries(
        h, w, cin, cout, k, stride, padding, seed):
    """The public ops.conv2d entry (autotune default path included)."""
    if padding == "valid" and (h < k or w < k):
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, h, w, cin)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * .3,
                     jnp.float32)
    _close(ops.conv2d(x, wt, stride=stride, padding=padding),
           ref.conv2d(x, wt, stride=stride, padding=padding))


# ---------------------------------------------------------------------------
# FusedGroupPlan invariants (DESIGN.md §8)
# ---------------------------------------------------------------------------

@settings(max_examples=18, deadline=None)
@given(net=st.sampled_from(["vgg16", "alexnet", "mobilenet"]),
       n=st.integers(1, 3),
       dataflow=st.sampled_from(["carry", "halo"]),
       residency=st.sampled_from(["auto", "always", "never"]),
       max_depth=st.sampled_from([None, 1, 2, 4]))
def test_fused_partition_invariants(net, n, dataflow, residency,
                                    max_depth):
    """The group partition tiles the network exactly; executed bytes
    never exceed the spill-everything baseline; depth-1 partitions
    reduce *exactly* to per-layer execution."""
    from repro.core.fuse_plan import FusedGroupPlan
    from repro.core.netplan import network_layers
    layers_list = network_layers(net)
    plan = FusedGroupPlan.build(net, n=n, dataflow=dataflow,
                                residency=residency, max_depth=max_depth)

    # exact tiling: contiguous, ordered, covering every layer once
    assert plan.groups[0].start == 0
    for g, nxt in zip(plan.groups, plan.groups[1:]):
        assert nxt.start == g.start + g.depth
    assert sum(g.depth for g in plan.groups) == len(layers_list)
    if max_depth is not None:
        assert all(g.depth <= max_depth for g in plan.groups)

    # fused execution may only remove HBM traffic, never add it
    executed = plan.executed_hbm_bytes()
    assert executed["total"] <= plan.never_hbm_bytes()
    assert executed["total"] == (executed["input"] + executed["weights"]
                                 + executed["output"] + executed["pool"])
    assert plan.executed_ratio() >= 1.0

    # a depth-1 partition is per-layer execution, byte for byte
    p1 = FusedGroupPlan.build(net, n=n, dataflow=dataflow,
                              residency=residency, max_depth=1)
    assert p1.executed_hbm_bytes()["total"] == p1.never_hbm_bytes()
    assert p1.executed_ratio() == 1.0


@settings(max_examples=12, deadline=None)
@given(net=st.sampled_from(["vgg16", "alexnet"]), n=st.integers(1, 2),
       strip_rows=st.sampled_from([None, 1, 2, 7]))
def test_fused_group_geometry_chains(net, n, strip_rows):
    """Per-group strip geometry: stage i's pooled rows are exactly stage
    i+1's input rows (the resident chain), and the last stage's strips
    tile its pooled output."""
    from repro.core.fuse_plan import FusedGroupPlan
    plan = FusedGroupPlan.build(net, n=n, strip_rows=strip_rows)
    for g in plan.groups:
        for a, b in zip(g.stages, g.stages[1:]):
            assert (a.pool_start, a.pool_step, a.pool_rows) == \
                (b.in_start, b.in_step, b.in_rows)
            assert (a.h_pool, a.w_pool, a.cout) == \
                (b.h_in, b.w_in, b.cin)
        lt = g.last
        assert lt.pool_rows == g.strip_rows
        assert g.n_strips * g.strip_rows >= lt.h_pool
        assert (g.n_strips - 1) * g.strip_rows < lt.h_pool


# ---------------------------------------------------------------------------
# Serving-engine invariants (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _random_grid(rng, max_bucket):
    """A random bucket grid that always contains max_bucket (so every
    trace fits) plus a random subset of smaller sizes."""
    from repro.core.serving import BucketGrid
    smaller = [b for b in range(1, max_bucket)
               if rng.integers(2)]
    return BucketGrid.build(tuple(smaller) + (max_bucket,))


def _policy_engine(grid, n_replicas, max_queue=10_000):
    from repro.core.serving import Replica, ServingEngine
    reps = [Replica(name=f"r{i}", fn=lambda b: np.asarray(b)[:, 0])
            for i in range(n_replicas)]
    return ServingEngine(reps, grid, max_queue=max_queue)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), max_bucket=st.integers(1, 64))
def test_bucket_for_is_minimal_and_in_grid(seed, max_bucket):
    rng = np.random.default_rng(seed)
    grid = _random_grid(rng, max_bucket)
    for n in range(1, max_bucket + 1):
        b = grid.bucket_for(n)
        assert b in grid.buckets and b >= n
        smaller = [g for g in grid.buckets if n <= g < b]
        assert not smaller, (n, b, grid.buckets)
        assert grid.pad_rows(n) == b - n


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 60),
       max_bucket=st.integers(1, 8), n_replicas=st.integers(1, 3),
       rate=st.floats(0.5, 50.0), service=st.floats(0.001, 0.5))
def test_every_request_served_exactly_once(seed, n, max_bucket,
                                           n_replicas, rate, service):
    """Conservation under unbounded queueing: completions == arrivals,
    each request exactly once, and the recorder agrees."""
    from repro.core.serving import replay
    from repro.testing.load import poisson_arrivals
    rng = np.random.default_rng(seed)
    eng = _policy_engine(_random_grid(rng, max_bucket), n_replicas)
    xs = rng.standard_normal((n, 3)).astype(np.float32)
    trace = [(t, i, xs[i]) for i, t in
             enumerate(poisson_arrivals(rate, n, seed=seed))]
    results, rejected = replay(eng, trace,
                               service_model=lambda b: service)
    assert not rejected
    assert sorted(results) == list(range(n))      # exactly once, all
    recs = eng.recorder.completed()
    assert len(recs) == n
    assert sorted(r.rid for r in recs) == list(range(n))
    for r in recs:                                 # sane lifecycles
        assert r.t_enqueue <= r.t_execute <= r.t_complete
        assert 1 <= r.batch_real <= r.bucket


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 60),
       max_bucket=st.integers(1, 8), max_queue_extra=st.integers(0, 8),
       service=st.floats(0.01, 0.5))
def test_bounded_queue_conserves_requests(seed, n, max_bucket,
                                          max_queue_extra, service):
    """With backpressure, served + shed still equals arrivals (nothing
    lost, nothing duplicated) and the queue bound holds."""
    from repro.core.serving import replay
    rng = np.random.default_rng(seed)
    grid = _random_grid(rng, max_bucket)
    max_queue = grid.max_bucket + max_queue_extra
    eng = _policy_engine(grid, 1, max_queue=max_queue)
    # all-at-once burst: the hardest case for the bound
    trace = [(0.0, i, np.zeros(3, np.float32)) for i in range(n)]
    results, rejected = replay(eng, trace,
                               service_model=lambda b: service)
    assert sorted(list(results) + rejected) == list(range(n))
    assert set(results).isdisjoint(rejected)
    assert eng.recorder.max_queue_depth <= max_queue


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 40),
       max_bucket=st.integers(1, 8), service=st.floats(0.001, 0.5))
def test_latency_monotone_in_queue_position_under_fifo(seed, n,
                                                       max_bucket,
                                                       service):
    """For simultaneous arrivals on one replica, FIFO makes completion
    time — hence latency — nondecreasing in queue position."""
    from repro.core.serving import replay
    rng = np.random.default_rng(seed)
    eng = _policy_engine(_random_grid(rng, max_bucket), 1)
    trace = [(0.0, i, np.zeros(3, np.float32)) for i in range(n)]
    replay(eng, trace, service_model=lambda b: service)
    recs = sorted(eng.recorder.records.values(), key=lambda r: r.rid)
    lats = [r.latency for r in recs]
    assert all(a <= b for a, b in zip(lats, lats[1:])), lats
    # FIFO also means batch order follows rid order
    execs = [r.t_execute for r in recs]
    assert all(a <= b for a, b in zip(execs, execs[1:]))


# ---------------------------------------------------------------------------
# NetworkGraph invariants (DESIGN.md §12)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(net=st.sampled_from(["vgg16", "alexnet", "mobilenet"]),
       n=st.integers(1, 3),
       dataflow=st.sampled_from(["carry", "halo"]),
       residency=st.sampled_from(["auto", "always", "never"]))
def test_graph_linear_reduction_is_exact(net, n, dataflow, residency):
    """A linear chain planned as a DAG IS the NetworkPlan: same
    per-boundary residency decisions, same HBM byte terms and same
    paper-metric accesses in both accounting modes, byte for byte."""
    from repro.core.netplan import NetworkGraph, NetworkPlan
    plan = NetworkPlan.build(net, n=n, dataflow=dataflow,
                             residency=residency)
    graph = NetworkGraph.build(net, n=n, dataflow=dataflow,
                               residency=residency)
    assert len(graph.steps) == len(plan.steps)
    for gs, ps in zip(graph.steps, plan.steps):
        assert gs.name == ps.name
        assert gs.resident_in == ps.resident_in
        assert gs.resident_out == ps.resident_out
        assert gs.pool == ps.pool
    for mode in ("3dtrim", "trim"):
        assert graph.hbm_bytes(mode) == plan.hbm_bytes(mode)
        assert graph.accesses(mode) == plan.accesses(mode)
        assert graph.ops_per_macc(mode) == plan.ops_per_macc(mode)
    assert graph.macs == plan.macs


@settings(max_examples=15, deadline=None)
@given(net=st.sampled_from(["resnet18", "unet"]), n=st.integers(1, 3),
       budget=st.sampled_from([0, 1 << 18, 1 << 21, 8 << 20, 1 << 28]))
def test_graph_intervals_respect_budget(net, n, budget):
    """Under "auto" the resident liveness intervals never overlap
    beyond the budget at any topological boundary, and shrinking the
    budget can only move bytes from resident to re-fetched."""
    from repro.core.netplan import NetworkGraph
    gp = NetworkGraph.build(net, n=n, residency_budget=budget)
    occ = gp.boundary_occupancy()
    assert all(o <= budget for o in occ)
    assert len(occ) == gp.n_nodes - 1
    unlimited = NetworkGraph.build(net, n=n, residency_budget=1 << 60)
    assert gp.spilled_edge_bytes >= unlimited.spilled_edge_bytes
    for mode in ("3dtrim", "trim"):
        assert gp.hbm_bytes(mode)["total"] >= \
            unlimited.hbm_bytes(mode)["total"]


@settings(max_examples=10, deadline=None)
@given(net=st.sampled_from(["resnet18", "unet"]), n=st.integers(1, 2),
       mode=st.sampled_from(["3dtrim", "trim"]))
def test_graph_never_is_per_node_sum(net, n, mode):
    """policy="never" spills everything: the network total is exactly
    the sum of per-conv ConvPlan bytes plus every join's activation
    traffic (all in-edges re-read + output written)."""
    from repro.core.netplan import LayerStep, NetworkGraph
    gp = NetworkGraph.build(net, n=n, residency="never",
                            fold_pooling=False)
    in_edges: dict[str, list] = {}
    for e in gp.edges:
        in_edges.setdefault(e.consumer, []).append(e)
    expected = 0
    for s in gp.steps:
        if isinstance(s, LayerStep):
            expected += s.plan.hbm_bytes(mode)["total"]
        else:
            expected += sum(e.bytes for e in in_edges.get(s.name, []))
            expected += s.out_bytes
    assert gp.hbm_bytes(mode)["total"] == expected
