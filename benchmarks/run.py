"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * fig1_*        — TrIM ifmap access overhead per ifmap size (derived=%).
  * fig6_*        — per-layer OPs/Access/Slice improvement (derived=x).
  * table1_*      — normalized efficiency metrics (derived=TOPS/W|TOPS/mm2).
  * sim_*         — cycle-simulator throughput (us/call = one 14x14 slice
                    pass), derived = measured OPs/external-access.
  * kernel_*      — Pallas kernel wall time in interpret mode vs the jnp
                    oracle (CPU validation timing, not TPU perf); the
                    conv rows cover BOTH dataflow modes (carry and halo)
                    so a regression in either path is visible, plus the
                    tuned-tiles + packed-weights config vs the seed
                    default (derived = speedup).
  * fused_*       — fused residency-group megakernels vs the per-layer
                    engine (``--fused``): wall-clock on the reduced
                    executed config + full-scale executed HBM bytes and
                    the fused/per-layer traffic ratio (DESIGN.md §8).
  * train_*       — one jitted CNN training step on trim kernels
                    (fwd + custom_vjp bwd + AdamW) vs the pure-XLA step,
                    and the modeled fwd+bwd roofline of a conv layer
                    (``--train`` emits only these — the training perf
                    artifact CI uploads).
  * roofline_*    — summary of the dry-run artifact (derived = projected
                    roofline fraction), if artifacts/dryrun_matrix.json
                    exists.

  * plan_*        — ConvPlan analytical traffic / arithmetic intensity for
                    representative VGG-16 and MobileNet (depthwise) layers
                    (derived = flop/byte | modeled bound).

Run: PYTHONPATH=src python -m benchmarks.run [--smoke] [--json OUT.json]
``--smoke`` runs a fast CI subset (analytical models + one tiny kernel).
``--json OUT.json`` additionally writes the rows as machine-readable JSON
(name/us/derived + optional structured columns such as dataflow/mode on
conv and shard rows, + git rev) — the perf-trajectory artifact CI
uploads; the row schema is documented in DESIGN.md §7.  Rows whose
measurement triggered a guarded-dispatch demotion (DESIGN.md §9) carry a
``guard`` column listing the tier falls, and the payload carries the full
``guard_events`` ring — a bench number produced by a fallback tier is
never mistaken for the healthy path.  The
whole-network paper evaluation (per-layer and network Ops/MAcc, trim vs
3dtrim) is its own entry point, ``benchmarks/paper_eval.py``.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time

import numpy as np

# jax-free at module level by design (guard's docstring): importing it
# here cannot break the --shard pre-jax XLA_FLAGS dance below
from repro.core import guard


def _time(fn, warmup=1, iters=3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_fig1(emit):
    from repro.core import fig1_curve
    t0 = time.perf_counter()
    curve = fig1_curve(sizes=(14, 28, 56, 112, 224))
    us = (time.perf_counter() - t0) * 1e6
    for size, pct in curve.items():
        emit(f"fig1_overhead_I{size}", us / len(curve), f"{pct:.2f}%")


def bench_fig6(emit):
    from repro.core import fig6
    for net in ("vgg16", "alexnet"):
        t0 = time.perf_counter()
        rows = fig6(net)
        us = (time.perf_counter() - t0) * 1e6 / len(rows)
        for r in rows:
            emit(f"fig6_{net}_{r['layer']}", us,
                 f"{r['improvement']:.2f}x")


def bench_table1(emit):
    from repro.core.energy import table1
    t0 = time.perf_counter()
    rows = table1()
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    for r in rows:
        emit(f"table1_{r['name'].split()[0]}", us,
             f"{r['norm_energy_eff_tops_per_w']:.2f}TOPS/W|"
             f"{r['norm_area_eff_tops_per_mm2']:.2f}TOPS/mm2")


def bench_simulator(emit):
    from repro.core import TrimSliceSim
    rng = np.random.default_rng(0)
    ifmap = rng.standard_normal((14, 14))
    w = rng.standard_normal((3, 3))
    for mode in ("trim", "3dtrim"):
        sim = TrimSliceSim(3, mode)
        us = _time(lambda: sim.run(ifmap, w))
        _, stats = sim.run(ifmap, w)
        emit(f"sim_slice14_{mode}", us,
             f"{stats.ops_per_memory_access:.2f}ops/access")


def bench_conv_plan(emit):
    """ConvPlan analytical traffic — the same plan objects the kernel
    executes; keeps the benchmark, roofline and kernel in agreement."""
    from repro.core import mobilenet_layers, vgg16_layers
    from repro.core.roofline import conv_plan_roofline
    for layer in [vgg16_layers()[1], vgg16_layers()[12],
                  mobilenet_layers()[0], mobilenet_layers()[1]]:
        t0 = time.perf_counter()
        plan = layer.plan()
        terms = conv_plan_roofline(layer.name, plan)
        us = (time.perf_counter() - t0) * 1e6
        label = layer.label().replace(",", "x")   # keep CSV comma-free
        emit(f"plan_{layer.name}_{label}", us,
             f"{plan.arithmetic_intensity():.1f}flop/B|{terms.dominant}")


def bench_kernels(emit, smoke: bool = False):
    import jax.numpy as jnp
    from repro.core import autotune
    from repro.kernels import ops, ref
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 28, 28, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 16, 32)) * .2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((32,)), jnp.float32)
    us_r = _time(lambda: ops.conv2d(x, w, impl="ref").block_until_ready())
    # both dataflow modes vs the same oracle, so a regression in either
    # path shows up as its own ratio
    us_df = {}
    for df in ("carry", "halo"):
        us_df[df] = _time(lambda: ops.conv2d(
            x, w, impl="pallas", dataflow=df,
            use_autotune_cache=False).block_until_ready())
        emit(f"kernel_conv2d_{df}_interp", us_df[df],
             f"oracle={us_r:.0f}us|ratio={us_df[df] / us_r:.2f}",
             dataflow=df, mode="3dtrim" if df == "carry" else "trim")
    us_k = us_df["carry"]   # seed default dataflow

    us_f = _time(lambda: ops.conv2d(
        x, w, bias=b, activation="relu", impl="pallas",
        use_autotune_cache=False).block_until_ready())
    emit("kernel_conv2d_fused_epilogue", us_f, f"unfused={us_k:.0f}us")

    # the conv execution engine closed loop: measured-tuned tiles +
    # pre-packed weights vs the seed default config, same math
    rec = autotune.tune((1, 30, 30, 16), tuple(w.shape), stride=1, pad=0,
                        measure=True, write=False)
    pk = ops.pack_conv2d_weights(w, b, tile_cout=rec["tile_cout"],
                                 tile_h=rec["tile_h"],
                                 dataflow=rec["dataflow"])
    us_t = _time(lambda: ops.conv2d(
        x, pk, activation="relu",
        use_autotune_cache=False).block_until_ready())
    emit("kernel_conv2d_tuned_packed", us_t,
         f"default={us_f:.0f}us|speedup={us_f / max(us_t, 1e-9):.2f}x|"
         f"tile_h={rec['tile_h']}|tile_cout={rec['tile_cout']}|"
         f"dataflow={rec['dataflow']}")

    wd = jnp.asarray(rng.standard_normal((3, 3, 1, 16)) * .2, jnp.float32)
    us_d = _time(lambda: ops.depthwise_conv2d(
        x, wd, impl="pallas").block_until_ready())
    us_dr = _time(lambda: ops.depthwise_conv2d(
        x, wd, impl="ref").block_until_ready())
    emit("kernel_depthwise2d_pallas_interp", us_d, f"oracle={us_dr:.0f}us")
    if smoke:
        return

    xx = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    ww = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    us_k = _time(lambda: ops.depthwise_conv1d(
        xx, ww, impl="pallas").block_until_ready())
    us_r = _time(lambda: ops.depthwise_conv1d(
        xx, ww, impl="ref").block_until_ready())
    emit("kernel_conv1d_pallas_interp", us_k, f"oracle={us_r:.0f}us")

    q = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    us_k = _time(lambda: ops.attention(
        q, kv, kv, impl="pallas").block_until_ready())
    us_c = _time(lambda: ops.attention(
        q, kv, kv, impl="chunked", chunk=64).block_until_ready())
    emit("kernel_flashattn_pallas_interp", us_k, f"chunked={us_c:.0f}us")


def bench_train_step(emit):
    """One jitted CNN training step (fwd + custom_vjp bwd + AdamW) run
    entirely on trim kernels, against the pure-XLA (`impl="ref"`) step —
    plus the modeled fwd+bwd roofline of one conv layer from the same
    plan objects the backward kernels execute."""
    import jax
    import jax.numpy as jnp
    from repro.core.conv_plan import ConvPlan
    from repro.core.roofline import conv_plan_roofline, sum_terms
    from repro.models import layers
    from repro.models.base import init_params
    from repro.optim import AdamWConfig, adamw

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 16, 16, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, size=4), jnp.int32)
    params = init_params(
        layers.simple_cnn_params(cin=3, channels=(8,), n_classes=10,
                                 depthwise_stage=True),
        jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    moments = adamw.init_moments(params, opt_cfg)

    def make_step(impl):
        def loss_fn(p):
            logits = layers.simple_cnn_apply(p, x, impl=impl)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

        @jax.jit
        def step(p, m):
            loss, grads = jax.value_and_grad(loss_fn)(p)
            p, m, _ = adamw.apply_updates(p, grads, m, jnp.int32(0),
                                          opt_cfg)
            return p, m, loss
        return step

    step_k = make_step("pallas")
    step_r = make_step("ref")
    us_k = _time(lambda: jax.block_until_ready(step_k(params, moments)))
    us_r = _time(lambda: jax.block_until_ready(step_r(params, moments)))
    emit("train_step_cnn_trim", us_k,
         f"xla_ref={us_r:.0f}us|ratio={us_k / max(us_r, 1e-9):.2f}")

    # modeled fwd+bwd roofline of the first conv layer, from the same
    # ConvPlan/WeightGradPlan objects the kernels execute
    shapes = ((4, 18, 18, 3), (3, 3, 3, 8))
    fwd = ConvPlan.build(*shapes)
    ig = ConvPlan.build_input_grad(*shapes)
    wg = ConvPlan.build_weight_grad(*shapes)
    total = sum_terms("conv0_train", [
        conv_plan_roofline("fwd", fwd), conv_plan_roofline("igrad", ig),
        conv_plan_roofline("wgrad", wg)])
    emit("train_plan_conv0_fwd_bwd", total.step_time_s * 1e6,
         f"bwd/fwd_bytes="
         f"{(ig.hbm_bytes()['total'] + wg.hbm_bytes()['total']) / max(fwd.hbm_bytes()['total'], 1):.2f}|"
         f"{total.dominant}")


def bench_sharded(emit):
    """Sharded conv (DESIGN.md §6) on 1/2/4/8-device meshes: modeled
    ShardedConvPlan traffic (HBM terms + the cross-device halo-exchange
    bytes as a first-class roofline term) against the measured step time
    of the shard_map halo-exchange path on forced host CPU devices.  At
    shards=1 the plan terms reduce exactly to the single-device ConvPlan
    numbers (asserted here, emitted as shard_plan_reduction_d1)."""
    import jax
    import jax.numpy as jnp
    from repro.core.conv_plan import ConvPlan
    from repro.core.conv_shard import ShardedConvPlan
    from repro.core.roofline import sharded_conv_roofline
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    n, h, w_img, cin, cout, k = 8, 32, 32, 8, 16, 3
    x = jnp.asarray(rng.standard_normal((n, h, w_img, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * .2,
                    jnp.float32)
    # the kernel-seen shape ('same' K=3 s=1 pre-pads by 1 per side) —
    # the shape the plans and autotune keys are built over
    kshape, _ = ops.kernel_input_shape(x.shape, k, 1, "same")
    base = ConvPlan.build(kshape, w.shape)

    n_avail = jax.device_count()
    for ndev in (1, 2, 4, 8):
        plan = ShardedConvPlan.build(kshape, w.shape, spatial_shards=ndev)
        t = plan.sharded_traffic()
        terms = sharded_conv_roofline(f"shard_d{ndev}", plan)
        # every shard row carries its dataflow + traffic-accounting mode
        # as structured JSON columns (DESIGN.md §7 row schema)
        tags = dict(dataflow=plan.dataflow, mode=plan.traffic_mode)
        if ndev == 1:
            bt = base.hbm_bytes()
            exact = (t["halo"] == 0 and t["total"] == bt["total"]
                     and t["input"] == bt["input"])
            assert exact, (t, bt)
            emit("shard_plan_reduction_d1", 0.0,
                 f"halo=0B|matches_convplan={exact}", **tags)
        if ndev > n_avail:
            emit(f"shard_conv2d_d{ndev}", 0.0,
                 f"halo={t['halo']}B|skipped(devices={n_avail})", **tags)
            continue
        from repro.launch.mesh import make_conv_mesh
        mesh = make_conv_mesh(1, ndev)

        def call():
            ops.conv2d(x, w, mesh=mesh,
                       use_autotune_cache=False).block_until_ready()

        us = _time(call)
        # halo = the modeled fwd+vjp round trip; the measured time is
        # forward-only, whose wire cost is halo_fwd (one direction)
        emit(f"shard_conv2d_d{ndev}", us,
             f"halo={t['halo']}B|halo_fwd={plan.halo_bytes_oneway}B|"
             f"hbm={t['hbm_total']}B|"
             f"halo_per_dev={plan.halo_bytes_per_device:.0f}B|"
             f"t_coll={terms.t_collective * 1e6:.2f}us|"
             f"dom={terms.dominant}", **tags)


def bench_fused(emit, *, scale: int = 16, batch: int = 1):
    """Fused residency-group megakernels (DESIGN.md §8) vs the per-layer
    engine: wall-clock per network on the ``scale``-reduced executed
    configuration, with the full-scale executed HBM-byte estimate (the
    bytes the fused schedule actually moves vs one pallas_call + pool op
    per layer) riding along as structured JSON columns."""
    import jax
    import jax.numpy as jnp
    from repro.core import FusedGroupPlan, network_layers, scale_layers
    from repro.models import layers
    from repro.models.base import init_params

    rng = np.random.default_rng(11)
    for net in ("vgg16", "alexnet"):
        full = network_layers(net)
        topo = scale_layers(full, scale)
        params = init_params(layers.cnn_params_from_layers(topo),
                             jax.random.PRNGKey(0))
        x = jnp.asarray(rng.standard_normal(
            (batch, topo[0].ifmap, topo[0].ifmap, topo[0].in_channels)),
            jnp.float32)
        fplan = FusedGroupPlan.build(topo, n=batch)
        fs_full = FusedGroupPlan.build(net, n=batch).summary()

        per_layer = jax.jit(
            lambda p, v, t=topo: layers.cnn_apply_from_layers(p, t, v))
        fused = jax.jit(
            lambda p, v, t=topo, fp=fplan: layers.cnn_apply_from_layers(
                p, t, v, fuse_plan=fp))
        us_p = _time(lambda: per_layer(params, x).block_until_ready())
        us_f = _time(lambda: fused(params, x).block_until_ready())
        match = bool(jnp.array_equal(per_layer(params, x),
                                     fused(params, x)))
        tags = dict(network=net, mode="fused", exec_scale=scale,
                    executed_bytes=fs_full["executed_bytes"],
                    per_layer_bytes=fs_full["per_layer_bytes"],
                    executed_ratio=fs_full["executed_ratio"],
                    groups=fs_full["groups"],
                    max_depth=fs_full["max_depth"], bit_match=match)
        emit(f"fused_{net}_x{scale}", us_f,
             f"per_layer={us_p:.0f}us|"
             f"speedup={us_p / max(us_f, 1e-9):.2f}x|"
             f"executed_hbm={fs_full['executed_bytes'] / 1e6:.1f}MB|"
             f"per_layer_hbm={fs_full['per_layer_bytes'] / 1e6:.1f}MB|"
             f"ratio={fs_full['executed_ratio']:.2f}x|bit_match={match}",
             **tags)


def bench_roofline(emit):
    path = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "dryrun_matrix.json")
    cands = sorted(glob.glob(path)) or sorted(glob.glob(
        os.path.join(os.path.dirname(path), "dryrun_*.json")))
    if not cands:
        emit("roofline_artifact", 0.0, "missing(run launch.dryrun)")
        return
    rows = json.load(open(cands[-1]))
    ok = [r for r in rows if r.get("status") == "ok" and "roofline" in r]
    for r in ok:
        rf = r["roofline"]
        emit(f"roofline_{r['cell'].replace('/', '_')}",
             r.get("compile_s", 0) * 1e6,
             f"frac={rf['roofline_fraction']:.3f}|dom={rf['dominant']}")


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: analytical models + tiny kernels")
    ap.add_argument("--train", action="store_true",
                    help="only the training-step benches (the training "
                         "perf artifact CI uploads)")
    ap.add_argument("--shard", action="store_true",
                    help="only the sharded-conv benches: modeled halo "
                         "bytes vs measured step time on 1/2/4/8-device "
                         "meshes (forces 8 host CPU devices)")
    ap.add_argument("--fused", action="store_true",
                    help="only the fused-megakernel benches: fused vs "
                         "per-layer wall-clock + full-scale executed "
                         "HBM-byte estimate per network (DESIGN.md §8)")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write rows as JSON (+ git rev) for the "
                         "perf-trajectory artifact")
    args = ap.parse_args()
    if args.shard:
        # must precede the first jax import in this process (bench
        # functions import jax lazily for exactly this reason)
        assert "jax" not in sys.modules, \
            "--shard needs to set XLA_FLAGS before jax initializes"
        from repro.launch.hostdevices import force_host_device_count
        force_host_device_count(8)
    print("name,us_per_call,derived")
    rows = []
    last_guard_seq = [-1]

    def _new_guard_events():
        new = [e for e in guard.events() if e["seq"] > last_guard_seq[0]]
        if new:
            last_guard_seq[0] = new[-1]["seq"]
        return new

    def emit(name, us, derived, **extra):
        """One bench row.  CSV stays (name, us, derived); ``extra``
        key/values (e.g. dataflow=, mode=) ride along as structured
        columns in the --json artifact (schema: DESIGN.md §7).  Any
        guard demotions recorded since the previous row land on this
        row as a ``guard`` column, so a bench number silently produced
        by a fallback tier is distinguishable from the healthy path."""
        new = _new_guard_events()
        if new:
            extra.setdefault("guard", [
                {k: e[k] for k in ("tier", "to", "kind", "layer")}
                for e in new])
            print(f"# guard: {name} demoted "
                  + ";".join(f"{e['tier']}->{e['to']}" for e in new))
        print(f"{name},{us:.1f},{derived}")
        rows.append(dict(name=name, us=round(us, 1), derived=derived,
                         **extra))

    if args.shard:
        bench_sharded(emit)
    elif args.fused:
        bench_fused(emit)
    elif args.train:
        bench_train_step(emit)
    elif args.smoke:
        bench_fig1(emit)
        bench_fig6(emit)
        bench_conv_plan(emit)
        bench_kernels(emit, smoke=True)
    else:
        bench_fig1(emit)
        bench_fig6(emit)
        bench_conv_plan(emit)
        bench_table1(emit)
        bench_simulator(emit)
        bench_kernels(emit)
        bench_train_step(emit)
        bench_roofline(emit)
    if args.json:
        payload = dict(rev=_git_rev(), smoke=args.smoke,
                       mode=("shard" if args.shard
                             else "fused" if args.fused
                             else "train" if args.train
                             else "smoke" if args.smoke else "full"),
                       timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
                       guard_events=guard.events(),
                       rows=rows)
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
