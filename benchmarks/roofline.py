"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run artifact (artifacts/dryrun_matrix.json), plus the analytical conv
roofline read straight from ``ConvPlan`` (no artifact needed).

  PYTHONPATH=src python -m benchmarks.roofline [--artifact path]
                                               [--section conv|...]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def load(path=None):
    base = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    if path:
        return json.load(open(path)), path
    per_arch = sorted(glob.glob(os.path.join(base, "matrix_*.json")))
    if per_arch:
        rows = []
        for p in per_arch:
            rows.extend(json.load(open(p)))
        return rows, f"{len(per_arch)} matrix_*.json files"
    cands = sorted(glob.glob(os.path.join(base, "dryrun_matrix.json"))) \
        or sorted(glob.glob(os.path.join(base, "dryrun_*.json")))
    return json.load(open(cands[-1])), cands[-1]


def dryrun_table(rows) -> str:
    out = ["| cell | status | compile (s) | peak GiB/dev | args GiB | "
           "collective kinds |", "|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['cell']} | **skip** | — | — | — | "
                       f"{r['reason'][:60]}… |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['cell']} | **ERROR** | — | — | — | "
                       f"{r['error'][:60]} |")
            continue
        kinds = ", ".join(f"{k}:{v/2**30:.2f}GiB"
                          for k, v in sorted(r["costs"]["coll"].items()))
        out.append(
            f"| {r['cell']} | ok | {r['compile_s']} "
            f"| {r['memory']['peak_gib']:.2f} "
            f"| {r['memory']['argument_gib']:.2f} | {kinds} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| cell | T_comp (ms) | T_mem (ms) | T_coll (ms) | dominant | "
           "useful/HLO | roofline frac |", "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['cell']} | {rf['t_compute_s']*1e3:.2f} "
            f"| {rf['t_memory_s']*1e3:.2f} | {rf['t_collective_s']*1e3:.2f} "
            f"| {rf['dominant']} | {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} |")
    return "\n".join(out)


def summary(rows) -> str:
    ok = [r for r in rows if r["status"] == "ok"]
    skips = [r for r in rows if r["status"] == "skip"]
    errs = [r for r in rows if r["status"] == "error"]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = \
            doms.get(r["roofline"]["dominant"], 0) + 1
    lines = [f"- cells: {len(ok)} ok / {len(skips)} documented skips / "
             f"{len(errs)} errors",
             f"- dominant terms: {doms}"]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    if worst:
        lines.append(f"- worst roofline fraction: {worst[0]['cell']} "
                     f"({worst[0]['roofline']['roofline_fraction']:.4f})")
        best = worst[-1]
        lines.append(f"- best roofline fraction: {best['cell']} "
                     f"({best['roofline']['roofline_fraction']:.3f})")
    coll = sorted(ok, key=lambda r: -r["roofline"]["t_collective_s"])
    if coll:
        lines.append(f"- most collective-bound: {coll[0]['cell']} "
                     f"(T_coll {coll[0]['roofline']['t_collective_s']*1e3:.1f} ms)")
    return "\n".join(lines)


def conv_table() -> str:
    """Per-layer conv roofline from the shared ``ConvPlan`` objects — the
    exact plans the Pallas kernel executes (kernel and table cannot
    disagree).  Covers VGG-16 plus MobileNet depthwise stages."""
    from repro.core import mobilenet_layers, vgg16_layers
    from repro.core.roofline import conv_plan_roofline
    out = ["| layer | grid | tile_h | AI 3dtrim (fl/B) | AI trim | "
           "T_comp (us) | T_mem (us) | bound | halo ovh |",
           "|---|---|---|---|---|---|---|---|---|"]
    for layer in vgg16_layers() + mobilenet_layers():
        plan = layer.plan()
        t = conv_plan_roofline(layer.name, plan)
        ovh = plan.hbm_bytes("trim")["overhead_pct"]
        out.append(
            f"| {layer.name} {layer.label()} | {plan.grid} | {plan.tile_h} "
            f"| {plan.arithmetic_intensity('3dtrim'):.1f} "
            f"| {plan.arithmetic_intensity('trim'):.1f} "
            f"| {t.t_compute*1e6:.1f} | {t.t_memory*1e6:.1f} "
            f"| {t.dominant} | {ovh:.1f}% |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None)
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "summary", "conv"])
    args = ap.parse_args()
    if args.section in ("all", "conv"):
        print("### Conv roofline (ConvPlan analytical)\n" + conv_table()
              + "\n")
        if args.section == "conv":
            return
    rows, path = load(args.artifact)
    print(f"<!-- generated from {os.path.basename(path)} -->\n")
    if args.section in ("all", "summary"):
        print("### Summary\n" + summary(rows) + "\n")
    if args.section in ("all", "dryrun"):
        print("### Dry-run matrix\n" + dryrun_table(rows) + "\n")
    if args.section in ("all", "roofline"):
        print("### Roofline terms\n" + roofline_table(rows))


if __name__ == "__main__":
    main()
