"""Paper-fidelity Ops/MAcc evaluation — the 3D-TrIM headline claim.

Renders the paper's network-level comparison (arXiv:2502.18983 §V,
per-layer accounting per the TrIM analytical-modelling companion,
arXiv:2408.01254) for whole CNN topologies, from the same
:class:`~repro.core.netplan.NetworkPlan` objects the execution engine
plans with:

* **arch** rows — the architectural access model (Fig. 6 / §V): Ops per
  memory access of the 3D-TrIM ASIC configuration (8x8, shadow
  registers, 64 slices) vs the TrIM configuration (7x24, 168 slices),
  per layer and whole-network, with the per-slice improvement ratio the
  paper reports (up to ~3.4x on the favorable layers; the whole-network
  ratio lands ~3.2-3.3x).

* **plan** rows — the TPU execution engine's strip-level image of the
  same tradeoff: whole-network HBM traffic and Ops/MAcc of every
  layer's ``ConvPlan`` under ``mode="3dtrim"`` (shadow-register carry,
  zero halo) vs ``mode="trim"`` (K-1 halo rows re-fetched per strip),
  with the NetworkPlan's inter-layer residency decisions applied, plus
  the summed network roofline.  ``--shards`` plans every layer as a
  ``ShardedConvPlan`` and reports the cross-device halo wire bytes.

* **sim** rows (``--measured``) — cycle-level validation: the
  :class:`~repro.core.dataflow.TrimSliceSim` functional simulator runs
  one slice per unique stride-1 layer geometry in both modes and its
  *counted* external reads are compared against the analytical
  prediction (they must agree exactly).

* **edge** rows (``--net resnet18 | unet``) — DAG topologies plan
  through :class:`~repro.core.netplan.NetworkGraph`: one row per graph
  edge with the residency pass's per-edge decision (resident / spilled /
  refetch), the tensor bytes and the liveness interval it occupies.
  Skip-connection traffic lands in the joins' rows of the plan kind;
  linear nets additionally assert the NetworkGraph linear reduction
  (chain-as-DAG == NetworkPlan, byte for byte).

Run:

  PYTHONPATH=src python benchmarks/paper_eval.py --net vgg16 --net alexnet
  PYTHONPATH=src python benchmarks/paper_eval.py --net resnet18 --measured
  PYTHONPATH=src python benchmarks/paper_eval.py --measured --json OUT.json

``--json`` writes the artifact CI uploads next to the ``benchmarks/run.py``
bench JSONs; every row carries explicit ``kind`` / ``mode`` / ``dataflow``
columns (schema documented in DESIGN.md §7).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                    # python benchmarks/paper_eval.py
    from run import _git_rev
except ImportError:                     # imported as benchmarks.paper_eval
    from benchmarks.run import _git_rev

#: DAG topologies evaluated through :class:`~repro.core.NetworkGraph`
#: (per-edge residency) instead of the linear :class:`NetworkPlan`.
GRAPH_NETS = ("resnet18", "unet")


def arch_rows(netplan) -> tuple[list[dict], dict]:
    """The Fig. 6 / §V architectural comparison as flat JSON rows."""
    cmp = netplan.arch_compare()
    rows = []
    for r in cmp["layers"]:
        rows.append(dict(
            kind="arch", network=netplan.name, layer=r["layer"],
            label=r["label"], mode="both", dataflow="n/a", ops=r["ops"],
            accesses_3dtrim=r["accesses"]["3d-trim"],
            accesses_trim=r["accesses"]["trim"],
            ops_per_macc_3dtrim=r["ops_per_macc"]["3d-trim"],
            ops_per_macc_trim=r["ops_per_macc"]["trim"],
            improvement=r["improvement"]))
    return rows, cmp


def plan_rows(netplan) -> tuple[list[dict], dict]:
    """The execution engine's ConvPlan-level comparison as JSON rows."""
    cmp = netplan.compare()
    rows = []
    for mode in ("3dtrim", "trim"):
        for r in netplan.as_rows(mode):
            rows.append(dict(kind="plan", network=netplan.name, **r))
    return rows, cmp


def sim_rows(netplan, cap: int = 14) -> list[dict]:
    """Cycle-measured Ops/MAcc per unique stride-1 layer geometry: one
    TrimSliceSim slice pass per mode, counted reads vs the analytical
    model (the `measured` column of the paper evaluation)."""
    import numpy as np
    from repro.core.conv_plan import slice_reads_per_channel
    from repro.core.dataflow import TrimSliceSim
    rng = np.random.default_rng(0)
    rows, seen = [], set()
    for s in getattr(netplan, "conv_steps", netplan.steps):
        l = s.layer
        size = min(l.ifmap, cap)
        geo = (size, l.kernel, l.stride)
        if l.stride != 1 or geo in seen:
            continue            # the simulator models stride-1 slices
        seen.add(geo)
        ifmap = rng.standard_normal((size, size))
        w = rng.standard_normal((l.kernel, l.kernel))
        for mode in ("3dtrim", "trim"):
            sim = TrimSliceSim(l.kernel, mode)
            _, stats = sim.run(ifmap, w)
            predicted = slice_reads_per_channel(
                size, size, l.kernel, 1, shadow=(mode == "3dtrim"))
            rows.append(dict(
                kind="sim", network=netplan.name, layer=s.name,
                label=f"(I{size},K{l.kernel})", mode=mode,
                dataflow="carry" if mode == "3dtrim" else "halo",
                measured_reads=stats.memory_reads,
                predicted_reads=predicted,
                measured_ops_per_macc=stats.ops_per_memory_access,
                exact=stats.memory_reads == predicted))
            assert stats.memory_reads == predicted, \
                (s.name, mode, stats.memory_reads, predicted)
    return rows


def executed_eval(net: str, *, batch: int = 1,
                  exec_scale: int = 16) -> dict:
    """The *executed* trim-vs-3dtrim traffic comparison (DESIGN.md §8):
    what the engine actually moves through HBM when residency groups run
    as fused megakernels vs one ``pallas_call`` (+ pool op) per layer.

    Byte accounting is full-scale, from the same :class:`FusedGroupPlan`
    the fused executor runs; wall-clock and the bit-match check run the
    ``exec_scale``-reduced configuration (CPU interpret mode cannot run
    full-scale VGG-16 in bench time) through both engines.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import (FusedGroupPlan, NetworkPlan, network_layers,
                            scale_layers)
    from repro.models import layers as mlayers
    from repro.models.base import init_params

    fs = FusedGroupPlan.build(net, n=batch).summary()
    # the modeled counterpart: NetworkPlan's residency saving — total
    # planned HBM with every boundary spilled vs the auto decision
    never = NetworkPlan.build(net, n=batch,
                              residency="never").hbm_bytes()["total"]
    auto = NetworkPlan.build(net, n=batch,
                             residency="auto").hbm_bytes()["total"]
    modeled_ratio = never / auto

    topo = scale_layers(network_layers(net), exec_scale)
    params = init_params(mlayers.cnn_params_from_layers(topo),
                         jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, topo[0].ifmap, topo[0].ifmap, topo[0].in_channels)),
        jnp.float32)
    fplan = FusedGroupPlan.build(topo, n=batch)

    per_layer = jax.jit(
        lambda p, v: mlayers.cnn_apply_from_layers(p, topo, v))
    fused = jax.jit(
        lambda p, v: mlayers.cnn_apply_from_layers(p, topo, v,
                                                   fuse_plan=fplan))
    y_ref = per_layer(params, x)
    y_fus = fused(params, x)          # also the compile warmup
    bit_match = bool(jnp.array_equal(y_ref, y_fus))

    def _wall(fn):
        t0 = time.perf_counter()
        fn(params, x).block_until_ready()
        return time.perf_counter() - t0

    return dict(
        executed_ratio=fs["executed_ratio"],
        executed_bytes=fs["executed_bytes"],
        per_layer_bytes=fs["per_layer_bytes"],
        groups=fs["groups"], max_depth=fs["max_depth"],
        fused_layers=fs["fused_layers"],
        modeled_ratio=modeled_ratio,
        divergence=abs(fs["executed_ratio"] - modeled_ratio)
        / modeled_ratio,
        exec_scale=exec_scale, bit_match=bit_match,
        wall_per_layer_s=min(_wall(per_layer) for _ in range(2)),
        wall_fused_s=min(_wall(fused) for _ in range(2)))


def edge_rows(graphplan) -> list[dict]:
    """Per-edge residency decisions of a :class:`NetworkGraph` as JSON
    rows (``kind="edge"``): producer -> consumer, tensor bytes, the
    resident/spilled/refetch state and the liveness interval the edge
    occupies in the topological order."""
    return [dict(kind="edge", network=graphplan.name, **r)
            for r in graphplan.edge_rows()]


def executed_graph_eval(net: str, *, batch: int = 1,
                        exec_scale: int = 8) -> dict:
    """The executed traffic comparison for a DAG topology: each fusable
    linear segment between joins runs as fused megakernels
    (:class:`GraphFusePlan`), and the fused graph executor must
    bit-match the per-layer graph executor.  Byte accounting is
    full-scale; execution runs the ``exec_scale``-reduced graph."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import GraphFusePlan, NetworkGraph, scale_graph
    from repro.core.netplan import graph_nodes
    from repro.models import layers as mlayers
    from repro.models.base import init_params

    fs = GraphFusePlan.build(net, n=batch).summary()
    never = NetworkGraph.build(net, n=batch,
                               residency="never").hbm_bytes()["total"]
    auto = NetworkGraph.build(net, n=batch,
                              residency="auto").hbm_bytes()["total"]
    modeled_ratio = never / auto

    g = scale_graph(graph_nodes(net), exec_scale)
    src = next(nd for nd in g if not nd.inputs)
    params = init_params(mlayers.cnn_params_from_graph(g),
                         jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, src.layer.ifmap, src.layer.ifmap, src.layer.in_channels)),
        jnp.float32)
    fplan = GraphFusePlan.build(g, n=batch)

    per_layer = jax.jit(
        lambda p, v: mlayers.cnn_apply_from_graph(p, g, v))
    fused = jax.jit(
        lambda p, v: mlayers.cnn_apply_from_graph(p, g, v, fused=True,
                                                  fuse_plan=fplan))
    y_ref = per_layer(params, x)
    y_fus = fused(params, x)          # also the compile warmup
    bit_match = bool(jnp.array_equal(y_ref, y_fus))

    def _wall(fn):
        t0 = time.perf_counter()
        fn(params, x).block_until_ready()
        return time.perf_counter() - t0

    return dict(
        executed_ratio=fs["executed_ratio"],
        executed_bytes=fs["executed_bytes"],
        per_layer_bytes=fs["per_layer_bytes"],
        segments=fs["segments"], groups=fs["groups"],
        max_depth=fs["max_depth"], fused_layers=fs["fused_layers"],
        modeled_ratio=modeled_ratio,
        divergence=abs(fs["executed_ratio"] - modeled_ratio)
        / modeled_ratio,
        exec_scale=exec_scale, bit_match=bit_match,
        wall_per_layer_s=min(_wall(per_layer) for _ in range(2)),
        wall_fused_s=min(_wall(fused) for _ in range(2)))


def evaluate_graph(net: str, *, batch: int = 1, residency: str = "auto",
                   measured: bool = False,
                   use_autotune_cache: bool = False,
                   exec_scale: int = 8) -> dict:
    """Full evaluation of one DAG topology via :class:`NetworkGraph`:
    the same arch/plan/sim rows as the linear path, plus per-edge
    residency rows and the graph-fused executed comparison."""
    from repro.core import NetworkGraph
    from repro.core.roofline import network_roofline
    gp = NetworkGraph.build(net, n=batch, residency=residency,
                            use_autotune_cache=use_autotune_cache)
    a_rows, a_cmp = arch_rows(gp)
    p_rows, p_cmp = plan_rows(gp)
    rows = a_rows + p_rows + edge_rows(gp)
    if measured:
        rows += sim_rows(gp)
    terms = network_roofline(net, gp)
    t = gp.hbm_bytes()
    occ = gp.boundary_occupancy()
    summary = dict(
        network=net, batch=batch, residency=residency, shards=1,
        layers=len(gp.conv_steps), nodes=gp.n_nodes,
        edges=len(gp.edges),
        resident_edges=sum(1 for e in gp.edges if e.resident),
        spilled_edge_bytes=gp.spilled_edge_bytes,
        max_boundary_occupancy=max(occ) if occ else 0,
        residency_budget=gp.residency_budget,
        macs=gp.macs, ops=gp.ops,
        hbm_total=t["total"], halo=t["halo"],
        arch=dict(ops_per_macc=a_cmp["ops_per_macc"],
                  ops_per_macc_per_slice=a_cmp["ops_per_macc_per_slice"],
                  improvement=a_cmp["improvement"],
                  max_layer_improvement=max(
                      r["improvement"] for r in a_cmp["layers"])),
        plan=dict(ops_per_macc_3dtrim=p_cmp["ops_per_macc_3dtrim"],
                  ops_per_macc_trim=p_cmp["ops_per_macc_trim"],
                  improvement=p_cmp["improvement"]),
        roofline=dict(t_compute_s=terms.t_compute,
                      t_memory_s=terms.t_memory,
                      t_collective_s=terms.t_collective,
                      dominant=terms.dominant))
    if measured:
        summary["executed"] = executed_graph_eval(net, batch=batch,
                                                  exec_scale=exec_scale)
        summary["executed_ratio"] = summary["executed"]["executed_ratio"]
    return dict(rows=rows, summary=summary)


def energy_report(net: str) -> dict:
    """Modeled energy + TOPS/W of one inference in int8 (the paper's
    fixed-point silicon: 1-byte transfers, ``mac_int8``) vs f32 (4-byte
    transfers, ``mac_fp32``), from ``core.energy``'s Horowitz-style
    pricing of the SAME access counts the Ops/MAcc evaluation uses —
    the quantized path changes what a transfer and a MAC cost, not how
    many there are."""
    from repro.core import energy
    int8 = energy.energy_per_inference(net, dtype_bytes=1, mac="mac_int8")
    f32 = energy.energy_per_inference(net, dtype_bytes=4, mac="mac_fp32")
    return dict(
        network=net, hw=int8["hw"], int8=int8, f32=f32,
        f32_over_int8_energy=f32["total_uJ"] / int8["total_uJ"])


def evaluate(net: str, *, batch: int = 1, residency: str = "auto",
             shards: int = 1, measured: bool = False,
             use_autotune_cache: bool = False,
             exec_scale: int = 16) -> dict:
    """Full evaluation of one topology; returns rows + network summary.

    DAG nets (:data:`GRAPH_NETS`) route to :func:`evaluate_graph`; the
    linear nets additionally prove the NetworkGraph linear reduction —
    the chain re-planned as a DAG must reproduce the NetworkPlan's HBM
    bytes and paper-metric accesses exactly."""
    if net in GRAPH_NETS:
        if shards != 1:
            raise SystemExit(
                f"--shards is the linear ShardedConvPlan path; "
                f"{net} plans single-device (NetworkGraph)")
        return evaluate_graph(net, batch=batch, residency=residency,
                              measured=measured,
                              use_autotune_cache=use_autotune_cache,
                              exec_scale=exec_scale)
    from repro.core import NetworkGraph, NetworkPlan
    from repro.core.roofline import network_roofline
    netplan = NetworkPlan.build(
        net, n=batch, residency=residency, spatial_shards=shards,
        use_autotune_cache=use_autotune_cache)
    a_rows, a_cmp = arch_rows(netplan)
    p_rows, p_cmp = plan_rows(netplan)
    rows = a_rows + p_rows
    linear_reduction = None
    if shards == 1:
        gp = NetworkGraph.build(net, n=batch, residency=residency)
        linear_reduction = all(
            gp.hbm_bytes(m) == netplan.hbm_bytes(m)
            and gp.accesses(m) == netplan.accesses(m)
            for m in ("3dtrim", "trim"))
        assert linear_reduction, \
            (net, "NetworkGraph linear reduction != NetworkPlan")
    if measured:
        rows += sim_rows(netplan)
    terms = network_roofline(net, netplan)
    t = netplan.hbm_bytes()
    summary = dict(
        network=net, batch=batch, residency=residency, shards=shards,
        layers=netplan.n_layers, macs=netplan.macs, ops=netplan.ops,
        hbm_total=t["total"], halo=t["halo"],
        arch=dict(ops_per_macc=a_cmp["ops_per_macc"],
                  ops_per_macc_per_slice=a_cmp["ops_per_macc_per_slice"],
                  improvement=a_cmp["improvement"],
                  max_layer_improvement=max(
                      r["improvement"] for r in a_cmp["layers"])),
        plan=dict(ops_per_macc_3dtrim=p_cmp["ops_per_macc_3dtrim"],
                  ops_per_macc_trim=p_cmp["ops_per_macc_trim"],
                  improvement=p_cmp["improvement"]),
        roofline=dict(t_compute_s=terms.t_compute,
                      t_memory_s=terms.t_memory,
                      t_collective_s=terms.t_collective,
                      dominant=terms.dominant))
    if linear_reduction is not None:
        summary["linear_reduction_exact"] = linear_reduction
    if measured:
        summary["executed"] = executed_eval(net, batch=batch,
                                            exec_scale=exec_scale)
        summary["executed_ratio"] = summary["executed"]["executed_ratio"]
    return dict(rows=rows, summary=summary)


def render(summary: dict, rows: list[dict]) -> None:
    net = summary["network"]
    graph = "nodes" in summary
    head = (f"{summary['layers']} convs / {summary['nodes']} nodes / "
            f"{summary['edges']} edges" if graph
            else f"{summary['layers']} conv layers")
    print(f"\n== {net} ({head}, "
          f"{summary['macs']/1e9:.2f} GMAC, batch {summary['batch']}, "
          f"residency={summary['residency']}) ==")
    print("  per-layer Ops/MAcc (arch accounting, Fig. 6 / SV):")
    for r in rows:
        if r["kind"] != "arch":
            continue
        print(f"    {r['layer']:>7s} {r['label']:>18s}: "
              f"3D-TrIM {r['ops_per_macc_3dtrim']:8.1f}  "
              f"TrIM {r['ops_per_macc_trim']:8.1f}  "
              f"improvement {r['improvement']:.2f}x")
    a = summary["arch"]
    print(f"  whole-network Ops/MAcc: "
          f"3D-TrIM {a['ops_per_macc']['3d-trim']:.1f} vs "
          f"TrIM {a['ops_per_macc']['trim']:.1f}  ->  "
          f"{a['improvement']:.2f}x per slice "
          f"(max layer {a['max_layer_improvement']:.2f}x)")
    p = summary["plan"]
    print(f"  execution engine (ConvPlan strips): Ops/MAcc "
          f"3dtrim {p['ops_per_macc_3dtrim']:.1f} vs "
          f"trim {p['ops_per_macc_trim']:.1f} "
          f"({p['improvement']:.3f}x), HBM {summary['hbm_total']/1e6:.1f} MB"
          + (f", halo wire {summary['halo']/1e6:.2f} MB"
             if summary["halo"] else ""))
    if graph:
        edges = [r for r in rows if r["kind"] == "edge"]
        print(f"  per-edge residency ({summary['resident_edges']}/"
              f"{summary['edges']} resident, peak interval occupancy "
              f"{summary['max_boundary_occupancy']/1e6:.2f} MB of "
              f"{summary['residency_budget']/1e6:.0f} MB budget):")
        for r in edges:
            print(f"    {r['producer']:>12s} -> {r['consumer']:<12s} "
                  f"{r['bytes']/1e6:8.2f} MB  {r['state']:>7s}  "
                  f"span {r['span']}")
    rf = summary["roofline"]
    print(f"  network roofline: T_comp {rf['t_compute_s']*1e3:.2f} ms "
          f"T_mem {rf['t_memory_s']*1e3:.2f} ms -> {rf['dominant']}-bound")
    en = summary.get("energy")
    if en:
        print(f"  modeled energy ({en['hw']}, Horowitz pricing): "
              f"int8 {en['int8_total_uJ']:.0f} uJ "
              f"({en['int8_tops_per_watt']:.2f} TOPS/W) vs "
              f"f32 {en['f32_total_uJ']:.0f} uJ "
              f"({en['f32_tops_per_watt']:.2f} TOPS/W) -> "
              f"{en['f32_over_int8_energy']:.2f}x less energy quantized")
    sims = [r for r in rows if r["kind"] == "sim"]
    if sims:
        ok = all(r["exact"] for r in sims)
        print(f"  cycle-sim validation: {len(sims)} slice passes, "
              f"counted reads == analytical: {ok}")
    e = summary.get("executed")
    if e:
        seg = (f"{e['segments']} segments, " if "segments" in e else "")
        print(f"  EXECUTED traffic (fused megakernels vs per-layer "
              f"pallas_calls): {e['executed_bytes']/1e6:.1f} MB vs "
              f"{e['per_layer_bytes']/1e6:.1f} MB -> "
              f"{e['executed_ratio']:.2f}x less "
              f"({e['fused_layers']}/{summary['layers']} layers fused, "
              f"{seg}{e['groups']} groups, max depth {e['max_depth']})")
        print(f"    wall-clock @ 1/{e['exec_scale']} channels: fused "
              f"{e['wall_fused_s']*1e3:.0f} ms vs per-layer "
              f"{e['wall_per_layer_s']*1e3:.0f} ms; fused output "
              f"bit-matches per-layer: {e['bit_match']}")
        if e["divergence"] > 0.10:
            print(f"    NOTE: executed ratio {e['executed_ratio']:.2f}x "
                  f"diverges {e['divergence']*100:.0f}% from the modeled "
                  f"residency saving {e['modeled_ratio']:.2f}x — the "
                  f"fused engine also streams weights per strip and "
                  f"eliminates the pool round-trips NetworkPlan's "
                  f"residency model folds analytically; see DESIGN.md §8")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", action="append", default=None,
                    choices=["vgg16", "alexnet", "mobilenet",
                             "resnet18", "unet"],
                    help="topology to evaluate (repeatable; default "
                         "vgg16 + alexnet, the paper's networks; "
                         "resnet18/unet evaluate the DAG NetworkGraph "
                         "path with per-edge residency)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--residency", default="auto",
                    choices=["auto", "never", "always"])
    ap.add_argument("--shards", type=int, default=1,
                    help="spatial shards: plan layers as ShardedConvPlan "
                         "and report cross-device halo wire bytes")
    ap.add_argument("--measured", action="store_true",
                    help="run the cycle simulator per unique geometry "
                         "(counted reads == analytical) AND the fused "
                         "executor: executed trim-vs-3dtrim traffic "
                         "ratio, wall-clock and bit-match vs per-layer")
    ap.add_argument("--exec-scale", type=int, default=16,
                    help="channel divisor for the --measured executed "
                         "run (byte accounting stays full-scale)")
    ap.add_argument("--use-autotune-cache", action="store_true",
                    help="fill per-layer tile/dataflow knobs from the "
                         "persisted autotune records")
    ap.add_argument("--energy", action="store_true",
                    help="report modeled energy + TOPS/W per network "
                         "(int8 fixed-point vs f32, core.energy); with "
                         "--json also writes BENCH_energy.json next to "
                         "the main artifact")
    ap.add_argument("--json", default=None, metavar="OUT.json")
    args = ap.parse_args()
    nets = args.net or ["vgg16", "alexnet"]

    all_rows, summaries, energy_reports = [], [], []
    for net in nets:
        res = evaluate(net, batch=args.batch, residency=args.residency,
                       shards=args.shards, measured=args.measured,
                       use_autotune_cache=args.use_autotune_cache,
                       exec_scale=args.exec_scale)
        if args.energy:
            rep = energy_report(net)
            energy_reports.append(rep)
            res["summary"]["energy"] = dict(
                hw=rep["hw"],
                int8_total_uJ=rep["int8"]["total_uJ"],
                int8_tops_per_watt=rep["int8"]["tops_per_watt"],
                f32_total_uJ=rep["f32"]["total_uJ"],
                f32_tops_per_watt=rep["f32"]["tops_per_watt"],
                f32_over_int8_energy=rep["f32_over_int8_energy"])
        render(res["summary"], res["rows"])
        all_rows += res["rows"]
        summaries.append(res["summary"])

    # the acceptance gate of the reproduction: the 3dtrim/trim ratio must
    # sit in the paper's claimed range on every network evaluated
    for s in summaries:
        assert s["arch"]["improvement"] > 1.0, s
        assert s["plan"]["improvement"] >= 1.0, s
        if s["network"] not in GRAPH_NETS:
            # the "up to 3.37x" claim range is stated for the paper's
            # own (linear, 224x224) networks; the DAG nets' small-image
            # layers legitimately sit above it
            assert s["arch"]["max_layer_improvement"] < 3.6, s
        if s["network"] == "resnet18":
            # DAG gate (ISSUE 10): the whole-network 3dtrim/trim
            # architectural ratio on ResNet-18 must clear 2x
            assert s["arch"]["improvement"] > 2.0, s
        if "linear_reduction_exact" in s:
            assert s["linear_reduction_exact"], s
        e = s.get("executed")
        if e:
            # fused execution must be a pure perf transform...
            assert e["bit_match"], (s["network"], "fused != per-layer")
            if s["network"] == "vgg16":
                # ...and actually realize the residency saving (ISSUE 6
                # acceptance: >= 2x executed traffic reduction on VGG-16)
                assert e["executed_ratio"] >= 2.0, e
    linear = [s for s in summaries if s["network"] not in GRAPH_NETS]
    if linear:
        claimed = max(s["arch"]["max_layer_improvement"] for s in linear)
        print(f"\npaper claim check: best layer improvement "
              f"{claimed:.2f}x (paper: up to 3.37x), every network "
              f"ratio > 1  [OK]")
    if any(s["network"] == "resnet18" for s in summaries):
        r = next(s for s in summaries if s["network"] == "resnet18")
        print(f"DAG gate: resnet18 whole-network 3dtrim/trim "
              f"{r['arch']['improvement']:.2f}x (> 2x required)  [OK]")

    # energy gate: the quantized path must actually buy energy — the
    # modeled int8 inference must undercut f32 by > 2x on VGG-16
    for rep in energy_reports:
        if rep["network"] == "vgg16":
            assert rep["f32_over_int8_energy"] > 2.0, rep

    if args.json:
        payload = dict(rev=_git_rev(),
                       timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
                       nets=nets, batch=args.batch,
                       residency=args.residency, shards=args.shards,
                       summaries=summaries, rows=all_rows)
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(all_rows)} rows to {args.json}")
        if energy_reports:
            epath = os.path.join(
                os.path.dirname(os.path.abspath(args.json)),
                "BENCH_energy.json")
            with open(epath, "w") as f:
                json.dump(dict(rev=_git_rev(),
                               timestamp=time.strftime(
                                   "%Y-%m-%dT%H:%M:%S"),
                               nets=nets, reports=energy_reports), f,
                          indent=1)
            print(f"# wrote {len(energy_reports)} energy reports to "
                  f"{epath}")


if __name__ == "__main__":
    main()
