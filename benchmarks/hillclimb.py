"""Perf hillclimb runner: hypothesis -> change -> re-lower -> validate.

Each experiment is (cell, variant-overrides, hypothesis).  Variants change
sharding rules / plan knobs ONLY — model math is identical — and re-run
the dry-run analysis, producing a before/after roofline comparison that is
appended to artifacts/hillclimb.json and rendered for EXPERIMENTS.md §Perf.

``--conv <layer>`` hillclimbs the trim_conv2d ``ConvPlan`` knobs
(tile_h x tile_cout x dataflow) for one conv layer against the analytical
roofline — the same plan object the kernel executes, so the winning knobs
transfer directly to ``trim_conv2d(tile_h=..., tile_cout=...,
dataflow=...)``.  ``--measure`` additionally wall-clocks the top
candidates through the real kernel (slow in interpret mode; the true
refinement loop runs on TPU), and ``--write-cache`` persists the winner
into the autotune cache ``ops.conv2d`` consults by default — the sweep
seeds the cache.

  PYTHONPATH=src python -m benchmarks.hillclimb --exp <name> | --list
  PYTHONPATH=src python -m benchmarks.hillclimb --conv vgg16:conv2
  PYTHONPATH=src python -m benchmarks.hillclimb --conv mobilenet:dw1 \\
      --measure --write-cache
"""

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


@dataclasses.dataclass
class Experiment:
    name: str
    arch: str
    shape: str
    hypothesis: str
    plan_overrides: dict
    cfg_overrides: dict = dataclasses.field(default_factory=dict)


EXPERIMENTS: dict[str, Experiment] = {}


def _reg(e: Experiment):
    EXPERIMENTS[e.name] = e


# --- cell A: qwen2.5-3b train (worst train-cell roofline fraction; the
# baseline 16-way Megatron TP pays ~2 psums of (B_loc, S, D) per layer) ---
_reg(Experiment(
    "qwen25-dp-zero3", "qwen2.5-3b", "train_4k",
    "TP psums dominate T_coll (the model is only 3B: TP is overkill). "
    "Re-map to pure ZeRO-3 data parallelism over all 256 chips (batch on "
    "(data, model); params FSDP over both axes): activation psums vanish; "
    "collective cost becomes per-layer weight all-gathers + gradient "
    "reduce-scatter ~ 3 * params_bytes << TP psum bytes. Predict T_coll "
    "5.3s -> <0.5s, dominant flips to compute.",
    dict(n_micro=1, fsdp=True,
         rules_overrides={"batch": ("pod", "data", "model"),
                          "embed": ("data", "model"),
                          "tokens": ("pod", "data", "model"),
                          "mlp": None, "heads": None, "kv_heads": None,
                          "vocab": None, "seq": None}),
))
_reg(Experiment(
    "qwen25-tp4-like", "qwen2.5-3b", "train_4k",
    "Half-measure control: keep TP but sequence-shard the psum boundary "
    "activations (Megatron-SP) so each TP psum becomes reduce-scatter + "
    "all-gather at 1/16 the resident size. Predict ~2x T_coll reduction "
    "(wire cost of RS+AG == AR, but bwd re-gathers shrink).",
    dict(rules_overrides={"seq": "model"}),
))

# --- cell B: qwen3-moe train (most collective-bound cell) ---
_reg(Experiment(
    "qwen3-ep-data", "qwen3-moe-30b-a3b", "train_4k",
    "The dispatch all-to-all boundary (g on data x e on model) plus TP "
    "psums dominate. Variant: experts on the DATA axis (EP=16 over data, "
    "dense/attention TP unchanged): dispatch becomes a data-axis "
    "all-to-all among the same devices that hold the tokens. Predict "
    "lower T_coll if expert traffic < TP traffic.",
    dict(n_micro=16, fsdp=True,
         rules_overrides={"experts": "data"}),
))
_reg(Experiment(
    "qwen3-zero3", "qwen3-moe-30b-a3b", "train_4k",
    "As with the dense 3B: drop TP entirely; ZeRO-3 over 256 chips with "
    "experts sharded on model only for the expert einsum. d_ff=768 per "
    "expert is tiny -> TP on mlp was pure overhead. Predict T_coll "
    "reduction >3x; compute term unchanged.",
    dict(n_micro=4, fsdp=True,
         rules_overrides={"batch": ("pod", "data", "model"),
                          "embed": ("data", "model"),
                          "tokens": ("pod", "data", "model"),
                          "mlp": None, "heads": None, "kv_heads": None,
                          "vocab": None, "seq": None}),
))

# --- cell C: falcon-mamba train (paper-technique representative:
# trim_conv1d + selective-scan dataflow) ---
_reg(Experiment(
    "mamba-zero3", "falcon-mamba-7b", "train_4k",
    "Mamba blocks are elementwise-heavy (scan) with TP only on d_inner "
    "projections; the psum of (B,S,4096) per layer dominates T_coll. "
    "ZeRO-3 re-map removes it. Predict dominant flips collective->compute.",
    dict(n_micro=2, fsdp=True,
         rules_overrides={"batch": ("pod", "data", "model"),
                          "embed": ("data", "model"),
                          "tokens": ("pod", "data", "model"),
                          "mlp": None, "heads": None, "kv_heads": None,
                          "vocab": None, "seq": None}),
))
_reg(Experiment(
    "mamba-scan-chunk-512", "falcon-mamba-7b", "train_4k",
    "Control on the compute term: doubling the selective-scan chunk from "
    "256 to 512 halves the number of chunk-boundary corrections (fewer "
    "cumprod ops) at 2x the chunk working set. Predict a small (<5%) "
    "T_compute reduction — refutation expected (associative scan flops "
    "are chunk-size-insensitive to first order).",
    dict(n_micro=2),
    cfg_overrides=dict(scan_chunk=512),
))

# --- cell: llama3-405b train (most collective-bound in the baseline) ---
_reg(Experiment(
    "llama-train-noSP", "llama3-405b", "train_4k",
    "The baseline cell's T_coll=1744s is dominated by 73TB of all-gathers "
    "that only appear in the unrolled Δ-compiles: the seq->model "
    "activation constraint forces a reshard around every unrolled "
    "attention chunk (the production scanned path reuses the gathered "
    "copy). Re-measure with the SP constraint dropped: predict T_coll "
    "collapses to the weight-gather + grad-reduce scale (~tens of "
    "seconds), exposing the true schedule. (Memory without SP grows by "
    "the saved-activation factor - kept as a measurement variant only.)",
    dict(n_micro=16, fsdp=True, moment_dtype="bfloat16",
         accum_dtype="bfloat16", rules_overrides={}),
))
_reg(Experiment(
    "llama-train-zero3", "llama3-405b", "train_4k",
    "Drop TP entirely (ZeRO-3 over 256 chips): per-layer weight "
    "all-gathers cost ~2*810GB/dev wire (~32s) vs compute ~67s -> "
    "overlappable, compute-bound, frac ~0.7. Tradeoff: saved activations "
    "lose the TP shard (memory +16x) -> needs offload/more remat; "
    "recorded as the roofline-optimal design point.",
    dict(n_micro=16, fsdp=True, moment_dtype="bfloat16",
         accum_dtype="bfloat16",
         rules_overrides={"batch": ("pod", "data", "model"),
                          "embed": ("data", "model"),
                          "tokens": ("pod", "data", "model"),
                          "mlp": None, "heads": None, "kv_heads": None,
                          "vocab": None, "seq": None}),
))

# --- decode cell (worst absolute roofline fraction): llama3-405b decode ---
_reg(Experiment(
    "llama-decode-int8kv", "llama3-405b", "decode_32k",
    "Decode is bandwidth-bound: T_mem = (params + KV cache)/BW. An int8 "
    "KV cache halves the cache term. Predict T_mem reduction by "
    "cache/(params+cache) * 1/2.",
    dict(fsdp=True, rules_overrides={"seq": "model"}),
    cfg_overrides=dict(),   # int8 cache handled via kv_cache_dtype below
))


# ---------------------------------------------------------------------------
# Conv-kernel hillclimb: sweep ConvPlan knobs against the analytical roofline
# ---------------------------------------------------------------------------

def _conv_layer(name: str):
    from repro.core import alexnet_layers, mobilenet_layers, vgg16_layers
    nets = {"vgg16": vgg16_layers, "alexnet": alexnet_layers,
            "mobilenet": mobilenet_layers}
    net, _, lname = name.partition(":")
    if net not in nets:
        raise SystemExit(f"unknown network {net!r}; have {sorted(nets)}")
    layers = nets[net]()
    if not lname:
        return layers[0]
    for l in layers:
        if l.name == lname:
            return l
    raise SystemExit(f"unknown layer {lname!r} in {net}; "
                     f"have {[l.name for l in layers]}")


def conv_hillclimb(name: str, dataflows=("carry", "halo"), *,
                   measure: bool = False, measure_top_k: int = 4,
                   write_cache: bool = False) -> dict:
    """Grid-sweep (tile_h, tile_cout, dataflow) for one layer; score by
    the modeled step time max(T_comp, T_mem) — each dataflow billed its
    own traffic mode — with a VMEM feasibility constraint.

    ``measure=True`` wall-clocks the ``measure_top_k`` model-best
    candidates through the actual Pallas kernel and re-ranks by measured
    us.  ``write_cache=True`` persists the winner into the autotune cache
    under the key ``ops.conv2d`` looks up for this layer's input.
    """
    from repro.core import autotune
    from repro.core.conv_plan import STRIP_VMEM_BUDGET, ConvPlan
    from repro.core.roofline import conv_plan_roofline
    from repro.kernels.ops import kernel_input_shape
    layer = _conv_layer(name)
    w_shape = (layer.kernel, layer.kernel,
               layer.in_channels // layer.groups, layer.out_channels)
    # sweep (and key) the problem ops.conv2d actually runs: the 'same'
    # pre-pad folded into the input shape — asymmetric for stride > 1,
    # NOT the layer's symmetric paper padding — with residual pad 0
    x_shape, pad = kernel_input_shape(
        (1, layer.ifmap, layer.ifmap, layer.in_channels), layer.kernel,
        layer.stride, "same" if layer.padding else "valid")
    baseline = ConvPlan.build(x_shape, w_shape, stride=layer.stride,
                              pad=pad, groups=layer.groups)
    base_t = conv_plan_roofline(layer.name, baseline).step_time_s
    # same candidate generator and ranking the autotuner uses — the sweep
    # and `autotune.tune` cannot pick different winners for one layer
    plans = [p for p in autotune.candidate_knobs(
                 x_shape, w_shape, stride=layer.stride, pad=pad,
                 groups=layer.groups)
             if p.dataflow in dataflows]
    ranked = sorted(plans, key=autotune._model_score)

    def _row(p):
        return dict(tile_h=p.tile_h, tile_cout=p.tile_cout,
                    dataflow=p.dataflow,
                    step_time_s=conv_plan_roofline(layer.name,
                                                   p).step_time_s,
                    vmem_mib=p.vmem_resident_bytes / 2**20,
                    hbm_mb=p.hbm_bytes()["total"] / 1e6,
                    ai=p.arithmetic_intensity())

    rows = [_row(p) for p in ranked]
    if measure and rows:
        for plan, row in zip(ranked[:measure_top_k],
                             rows[:measure_top_k]):
            row["measured_us"] = autotune._measure_plan(
                plan, stride=layer.stride, pad=pad, groups=layer.groups)
        best = min(rows[:measure_top_k], key=lambda r: r["measured_us"])
    else:
        best = rows[0] if rows else None
    result = dict(experiment=f"conv:{name}",
                  dataflows=list(dataflows), measured=measure,
                  baseline=dict(tile_h=baseline.tile_h,
                                tile_cout=baseline.tile_cout,
                                dataflow=baseline.dataflow,
                                step_time_s=base_t,
                                budget=STRIP_VMEM_BUDGET),
                  best=best, n_candidates=len(rows), sweep=rows)
    if write_cache and best is not None:
        key = autotune.make_key(x_shape, w_shape, stride=layer.stride,
                                pad=pad, groups=layer.groups)
        path = autotune.store(key, dict(
            tile_h=best["tile_h"], tile_cout=best["tile_cout"],
            dataflow=best["dataflow"],
            source="measured" if measure else "model",
            model_step_time_s=best["step_time_s"],
            measured_us=best.get("measured_us")))
        result["cache_key"], result["cache_path"] = key, path
    return result


def run_variant(exp: Experiment) -> dict:
    from repro.configs import registry
    from repro.launch import dryrun
    mod = registry.get(exp.arch)
    plan = mod.PLANS[exp.shape]
    for k, v in exp.plan_overrides.items():
        plan = plan.replace(**{k: v})
    cfg = mod.CONFIG.replace(**exp.cfg_overrides) if exp.cfg_overrides \
        else mod.CONFIG

    # monkeypatch the registry entry the dryrun reads
    orig_cfg, orig_plans = mod.CONFIG, mod.PLANS
    try:
        mod.CONFIG = cfg
        mod.PLANS = dict(orig_plans)
        mod.PLANS[exp.shape] = plan
        row = dryrun.run_cell(exp.arch, exp.shape, multi_pod=False)
    finally:
        mod.CONFIG, mod.PLANS = orig_cfg, orig_plans
    row["experiment"] = exp.name
    row["hypothesis"] = exp.hypothesis
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None)
    ap.add_argument("--baseline", action="store_true",
                    help="run the unmodified cell for comparison")
    ap.add_argument("--arch"), ap.add_argument("--shape")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--conv", default=None, metavar="NET[:LAYER]",
                    help="hillclimb ConvPlan knobs, e.g. vgg16:conv2")
    ap.add_argument("--dataflow", default="both",
                    choices=["carry", "halo", "both"],
                    help="which conv dataflow(s) to sweep")
    ap.add_argument("--mode", default=None, choices=["3dtrim", "trim"],
                    help="legacy accounting alias: 3dtrim=carry, "
                         "trim=halo")
    ap.add_argument("--measure", action="store_true",
                    help="wall-clock the top conv candidates through the "
                         "real kernel (slow in interpret mode)")
    ap.add_argument("--write-cache", action="store_true",
                    help="persist the winning conv knobs into the "
                         "autotune cache ops.conv2d consults")
    args = ap.parse_args()
    if args.list:
        for name, e in EXPERIMENTS.items():
            print(f"{name}: {e.arch}/{e.shape}")
        return
    os.makedirs(ART, exist_ok=True)
    if args.conv:
        if args.mode is not None:
            dataflows = ("carry",) if args.mode == "3dtrim" else ("halo",)
        elif args.dataflow == "both":
            dataflows = ("carry", "halo")
        else:
            dataflows = (args.dataflow,)
        res = conv_hillclimb(args.conv, dataflows, measure=args.measure,
                             write_cache=args.write_cache)
        b, base = res["best"], res["baseline"]
        print(json.dumps(dict(experiment=res["experiment"],
                              baseline=base, best=b,
                              speedup=base["step_time_s"]
                              / max(b["step_time_s"], 1e-12)), indent=1))
        if "cache_path" in res:
            print(f"cached {res['cache_key']} -> {res['cache_path']}")
        out_path = os.path.join(ART, "conv_hillclimb.json")
        results = json.load(open(out_path)) if os.path.exists(out_path) \
            else []
        results.append(res)
        json.dump(results, open(out_path, "w"), indent=1)
        print("appended to", out_path)
        return
    # dry-run path only: the 512-device mesh must be configured before
    # the first jax backend initialization (--conv/--list never need it)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    out_path = os.path.join(ART, "hillclimb.json")
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    if args.baseline:
        from repro.launch import dryrun
        row = dryrun.run_cell(args.arch, args.shape, multi_pod=False)
        row["experiment"] = f"baseline:{args.arch}/{args.shape}"
    else:
        row = run_variant(EXPERIMENTS[args.exp])
    rf = row.get("roofline", {})
    print(json.dumps({k: rf.get(k) for k in
                      ("t_compute_s", "t_memory_s", "t_collective_s",
                       "dominant", "roofline_fraction")}, indent=1))
    results.append(row)
    json.dump(results, open(out_path, "w"), indent=1)
    print("appended to", out_path)


if __name__ == "__main__":
    main()
