"""Serving benchmark: p50/p99 latency + throughput under seeded Poisson
open-loop load (DESIGN.md §10), emitted as ``BENCH_serving.json``.

The measurement marries the two halves of the load harness: arrivals
are a *deterministic* seeded Poisson trace (``repro.testing.load``),
service times are the *measured* wall time of each real bucket forward
— so the batching dynamics are reproducible per seed while the compute
numbers are honest.  The arrival rate is auto-calibrated to ~2x the
max-bucket service capacity, which guarantees the trace exercises at
least two buckets: the first arrival lands on an idle queue (bucket 1)
and the backlog that builds behind each in-flight batch drains at the
largest bucket.

Three serving invariants are asserted on every run, not just reported:

* zero cold tunes after prewarm — a spy wrapped around the tuner counts
  any ``autotune.tune`` call during the serving phase (must be 0);
* at least two buckets actually served batches;
* every served row bit-matches the single-request tuned forward.

Run:

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json \
      artifacts/BENCH_serving.json
  PYTHONPATH=src python benchmarks/serve_bench.py --net vgg16 --scale 32
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                    # python benchmarks/serve_bench.py
    from run import _git_rev
except ImportError:                     # imported as benchmarks.serve_bench
    from benchmarks.run import _git_rev

import numpy as np


def _smoke_topology():
    from repro.core.model import ConvLayer
    return [ConvLayer("b0", ifmap=16, in_channels=3, out_channels=8,
                      kernel=3, stride=1, padding=1),
            ConvLayer("b1", ifmap=16, in_channels=8, out_channels=8,
                      kernel=3, stride=2, padding=1),
            ConvLayer("b2", ifmap=8, in_channels=8, out_channels=16,
                      kernel=3, stride=1, padding=1)]


def bench(*, net, scale, buckets, replicas, requests, seed, rate,
          fused) -> dict:
    import jax
    from repro.core import autotune, network_layers, scale_layers
    from repro.core.serving import ServingEngine, replay
    from repro.models import layers as mlayers
    from repro.models.base import init_params
    from repro.testing.load import poisson_arrivals

    if net:
        topo = scale_layers(network_layers(net), scale)
    else:
        topo = _smoke_topology()
    params = init_params(
        mlayers.cnn_params_from_layers(topo, n_classes=10),
        jax.random.PRNGKey(0))
    engine = ServingEngine.for_topology(topo, params, buckets=buckets,
                                        n_replicas=replicas, fused=fused,
                                        max_queue=max(1024, requests))

    t0 = time.perf_counter()
    engine.prewarm()
    t_prewarm = time.perf_counter() - t0

    # calibrate: median service time of the largest bucket, post-prewarm
    max_b = engine.grid.max_bucket
    shape = (max_b,) + engine.input_shape
    zeros = np.zeros(shape, np.float32)
    t_max = float(np.median([_timed(engine.replicas[0].fn, zeros)
                             for _ in range(3)]))
    if rate is None:
        rate = 2.0 * max_b / max(t_max, 1e-6)

    # spy: any tune during the serving phase is a cold tune (prewarm
    # coverage was incomplete) — the benchmark must see zero
    tunes_during_serving = []
    real_tune = autotune.tune

    def spy(*a, **kw):
        tunes_during_serving.append((a, kw))
        return real_tune(*a, **kw)

    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((requests,) + engine.input_shape)
    xs = xs.astype(np.float32)
    arrivals = poisson_arrivals(rate, requests, seed=seed)
    trace = [(arrivals[i], i, xs[i]) for i in range(requests)]

    autotune.tune = spy
    try:
        results, rejected = replay(engine, trace)
    finally:
        autotune.tune = real_tune

    # differential check: every served row == the single-request forward
    mismatches = [rid for rid, row in results.items()
                  if not np.array_equal(row, engine.forward_one(xs[rid]))]

    summary = engine.recorder.summary()
    stats = engine.stats()
    return {
        "net": net or "smoke-cnn", "scale": scale if net else None,
        "buckets": list(engine.grid.buckets), "replicas": replicas,
        "requests": requests, "seed": seed, "fused": fused,
        "rate_rps": float(rate), "t_prewarm_s": t_prewarm,
        "t_service_max_bucket_s": t_max,
        "cold_tunes": stats["cold_tunes"],
        "tunes_during_serving": len(tunes_during_serving),
        "bit_mismatches": len(mismatches),
        "rejected": len(rejected),
        "summary": summary, "stats": stats,
    }


def _timed(fn, x) -> float:
    t0 = time.perf_counter()
    fn(x)
    return time.perf_counter() - t0


def render(res: dict) -> list[dict]:
    s = res["summary"]
    print(f"\n== serving bench: {res['net']} buckets={res['buckets']} "
          f"replicas={res['replicas']} rate={res['rate_rps']:.0f} req/s "
          f"seed={res['seed']} ==")
    print(f"prewarm {res['t_prewarm_s']:.2f}s; max-bucket service "
          f"{res['t_service_max_bucket_s'] * 1e3:.2f}ms")
    hdr = f"{'bucket':>7} {'count':>6} {'p50_ms':>8} {'p99_ms':>8}"
    print(hdr + "\n" + "-" * len(hdr))
    rows = []
    for b, bs in s["buckets"].items():
        print(f"{b:>7} {bs['count']:>6} {bs['p50_s'] * 1e3:>8.2f} "
              f"{bs['p99_s'] * 1e3:>8.2f}")
        rows.append({"kind": "serving_bucket", "net": res["net"],
                     "bucket": int(b), "count": bs["count"],
                     "p50_s": bs["p50_s"], "p99_s": bs["p99_s"]})
    print(f"{'all':>7} {s['count']:>6} {s['p50_s'] * 1e3:>8.2f} "
          f"{s['p99_s'] * 1e3:>8.2f}   "
          f"throughput {s['throughput_rps']:.1f} req/s, "
          f"cold tunes {res['cold_tunes']}, "
          f"rejected {res['rejected']}")
    rows.append({"kind": "serving_total", "net": res["net"],
                 "count": s["count"], "p50_s": s["p50_s"],
                 "p99_s": s["p99_s"],
                 "throughput_rps": s["throughput_rps"],
                 "cold_tunes": res["cold_tunes"],
                 "rejected": res["rejected"]})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default=None,
                    choices=["vgg16", "alexnet", "mobilenet"],
                    help="serve a scaled paper topology (default: the "
                         "3-layer smoke CNN)")
    ap.add_argument("--scale", type=int, default=32,
                    help="channel divisor for --net")
    ap.add_argument("--fused", action="store_true",
                    help="serve fused residency-group megakernels")
    ap.add_argument("--buckets", default="1,2,4",
                    help="comma-separated batch bucket grid")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (req/s; default "
                         "auto-calibrates to 2x max-bucket capacity)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI configuration (smoke CNN, 48 "
                         "requests)")
    ap.add_argument("--json", default=None, metavar="OUT.json")
    args = ap.parse_args()
    if args.smoke:
        args.net = None
        args.requests = min(args.requests, 48)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    res = bench(net=args.net, scale=args.scale, buckets=buckets,
                replicas=args.replicas, requests=args.requests,
                seed=args.seed, rate=args.rate, fused=args.fused)
    rows = render(res)

    # acceptance gates (ISSUE 8): prewarm coverage is complete, the
    # calibrated trace exercises >= 2 buckets, responses bit-match the
    # single-request tuned forward
    assert res["cold_tunes"] == 0, res["cold_tunes"]
    assert res["tunes_during_serving"] == 0, res["tunes_during_serving"]
    assert len(res["summary"]["buckets"]) >= 2, res["summary"]["buckets"]
    assert res["bit_mismatches"] == 0, res["bit_mismatches"]
    print("serving gates: 0 cold tunes, "
          f"{len(res['summary']['buckets'])} buckets exercised, "
          "all responses bit-match the unbatched forward  [OK]")

    if args.json:
        payload = dict(rev=_git_rev(),
                       timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
                       smoke=args.smoke, result=res, rows=rows)
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
